"""Scenario: distributed PCA — the paper's block streaming lifted across a
mesh (covariance accumulated shard-wise with a single psum), plus the
TPU-native parallel-Jacobi schedule and the analytical fabric model.

    PYTHONPATH=src python examples/pca_pipeline.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import PCAConfig, fit_distributed
from repro.core.memory_model import ARTIX7, VIRTEX_US, pca_seconds

mesh = jax.make_mesh((len(jax.devices()),), ("data",))
rng = np.random.default_rng(1)
X = (rng.standard_normal((4096, 8)) @ rng.standard_normal((8, 64))
     ).astype(np.float32)

res = fit_distributed(jnp.asarray(X), mesh,
                      PCAConfig(T=128, S=8, pivot="parallel", sweeps=15))
print(f"devices: {len(jax.devices())}  eigenvalues[:5]:",
      np.round(np.asarray(res.eigenvalues[:5]), 1))
print(f"rel off-diag after 15 sweeps: {float(res.off_norm):.2e}")

print("\nfabric-model latency for this dataset (paper Sec. VII-A):")
for name, cfgf in (("MANOJAVAM(4,8)@Artix-7", ARTIX7),
                   ("MANOJAVAM(16,32)@Virtex-US+", VIRTEX_US)):
    est = pca_seconds(*X.shape, cfgf)
    print(f"  {name:28s} total={est['total_s']*1e3:8.2f} ms "
          f"energy={est['energy_j']*1e3:8.2f} mJ")
