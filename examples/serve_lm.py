"""Scenario: batched serving — prefill + KV-cache decode loop
(reduced granite-8b on CPU).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve

gen = serve.main(["--arch", "granite-8b", "--reduced", "--batch", "4",
                  "--prompt-len", "32", "--gen-len", "16",
                  "--temperature", "0.8"])
print("generated token matrix:\n", gen)
