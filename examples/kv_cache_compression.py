"""Scenario: long-context KV-cache PCA compression (beyond-paper).

Builds a prompt KV cache with a reduced model, fits per-head eigenbases
with the MANOJAVAM Jacobi engine, and reports the attention-output error
at several compression ranks plus the telemetry-suggested rank.

    PYTHONPATH=src python examples/kv_cache_compression.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models import kv_compression as kvc
from repro.models import transformer as tfm
from repro.parallel.sharding import REPLICATED

cfg = reduced_config("granite-8b", head_dim=32, n_layers=2)
params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(0), cfg))
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 96)), jnp.int32)
_, state = tfm.prefill(params, {"tokens": tokens}, cfg, REPLICATED)

# layer-0 cache of the first group (group-stacked leading dim)
cache = state.caches["l0"]
k = cache.k[0]
v = cache.v[0]
q = jnp.asarray(rng.standard_normal(
    (2, cfg.n_kv_heads, cfg.group_size, cfg.head_dim)), jnp.float32)
scale = cfg.head_dim ** -0.5

print(f"cache: {k.shape} (head_dim={cfg.head_dim})")
for rank in (4, 8, 16, 32):
    err, ratio = kvc.attention_error(
        q, k, v, kvc.KVCompressionConfig(rank=rank), scale)
    print(f"  rank {rank:2d}: memory x{ratio:.2f}, "
          f"attention-output rel err {float(err):.4f}")
r = kvc.suggest_rank(k, coverage=0.99)
print(f"telemetry-suggested rank for 99% spectral coverage: {r}")

# Random-init weights give a near-full-rank cache (suggested rank ~ hd) --
# an honest negative control.  Trained long-context caches concentrate
# spectrum; emulate that structure to show the regime the feature targets:
print("\nstructured (low-rank) cache -- the long-context regime:")
basis = jnp.asarray(rng.standard_normal((cfg.n_kv_heads, cfg.head_dim, 6)),
                    jnp.float32)
coef_k = jnp.asarray(rng.standard_normal((2, 96, cfg.n_kv_heads, 6)),
                     jnp.float32)
coef_v = jnp.asarray(rng.standard_normal((2, 96, cfg.n_kv_heads, 6)),
                     jnp.float32)
k_lr = jnp.einsum("bskr,kdr->bskd", coef_k, basis)
v_lr = jnp.einsum("bskr,kdr->bskd", coef_v, basis)
for rank in (4, 8, 16):
    err, ratio = kvc.attention_error(
        q, k_lr, v_lr, kvc.KVCompressionConfig(rank=rank), scale)
    print(f"  rank {rank:2d}: memory x{ratio:.2f}, rel err {float(err):.5f}")
print(f"suggested rank: {kvc.suggest_rank(k_lr, coverage=0.99)}")
