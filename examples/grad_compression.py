"""Scenario: PCA gradient compression (the paper's Jacobi engine as a
distributed-optimization trick) — train the same model with exact and
rank-4-compressed gradients and compare loss curves + exchanged bytes.

    PYTHONPATH=src python examples/grad_compression.py
"""
import numpy as np

from repro.launch import train

base = ["--arch", "olmo-1b", "--reduced", "--steps", "30",
        "--global-batch", "8", "--seq-len", "64", "--lr", "1e-2",
        "--log-every", "10"]
print("== exact gradients ==")
exact = train.main(base)
print("== PCA rank-4 compressed gradients (error feedback) ==")
comp = train.main(base + ["--compress-grads", "4"])

print(f"\nfinal loss: exact={exact[-1]:.4f}  compressed={comp[-1]:.4f}")
assert comp[-1] < exact[0] - 0.5, "compressed run failed to learn"
print("compressed run converges (see EXPERIMENTS §Perf cell 3 for the "
      "measured 76x pod-link byte reduction)")
