"""Scenario: multi-tenant PCA/SVD serving — the paper's S systolic arrays
plus Matrix Padding Unit as a request-batching service.

Mixed-shape traffic from several "tenants" (different feature dims and ops)
flows into one PCAServer: requests are padded into T-multiple shape buckets,
up to S same-bucket requests ride one vmapped device batch, and the compiled
executable for each (op, bucket, S) is reused across flushes.

    PYTHONPATH=src python examples/pca_service.py
"""
import numpy as np

from repro.core import PCAConfig
from repro.core.memory_model import VIRTEX_US
from repro.serving import BucketPolicy, PCAServer

rng = np.random.default_rng(0)
server = PCAServer(
    PCAConfig(T=16, S=4, sweeps=15),
    policy=BucketPolicy(T=16, mode="tile"),
    max_delay_s=0.05,
)

# tenant A: covariance matrices of several sensor arrays (eigh requests)
tenantA = []
for n in (12, 29, 17, 24):
    a = rng.standard_normal((n, n)).astype(np.float32)
    tenantA.append(server.submit((a + a.T) / 2, op="eigh"))

# tenant B: raw data matrices for full PCA fits
tenantB = [server.submit(rng.standard_normal((64, d)).astype(np.float32),
                         op="pca")
           for d in (9, 22, 13, 30)]

# tenant C: thin SVDs
tenantC = [server.submit(rng.standard_normal((48, d)).astype(np.float32),
                         op="svd")
           for d in (11, 27, 11, 27)]

server.drain()

print("tenant A (eigh): top eigenvalue per request:",
      [round(float(t.result().eigenvalues[0]), 2) for t in tenantA])
print("tenant B (pca):  components to reach 95% CVCR:",
      [int(np.searchsorted(t.result().cvcr, 0.95) + 1) for t in tenantB])
print("tenant C (svd):  leading singular value:",
      [round(float(t.result().S[0]), 2) for t in tenantC])

s = server.stats.summary()
print(f"\nserved {s['requests']} requests in {s['wall_s']*1e3:.1f} ms "
      f"({s['requests_per_s']:.0f} req/s), p50 latency "
      f"{s['latency_p50_ms']:.2f} ms, mean batch {s['mean_batch']:.1f}, "
      f"padding waste {s['mean_padding_waste']:.0%}, "
      f"cache hit rate {s['cache_hit_rate']:.0%}")

pvm = server.stats.predicted_vs_measured(VIRTEX_US)
med = np.median([r["ratio"] for r in pvm])
print(f"measured service latency is {med:.0f}x the MANOJAVAM(16,32) "
      f"fabric-model prediction (queueing + batching + CPU dispatch)")

# --- a fresh burst through a depth-4 pipeline -------------------------------
# max_inflight=4 lets up to 3 flushes stay on the device while the host
# batches the next one (the paper's keep-the-arrays-busy overlap).  The
# pipeline only reorders work -- it runs the same cached executables, so
# results match the synchronous engine bit-for-bit (pinned by
# `serve_pca --selftest` and tests/test_serving.py).
pipelined = PCAServer(PCAConfig(T=16, S=4, sweeps=15),
                      policy=BucketPolicy(T=16, mode="tile"),
                      max_delay_s=0.05, max_inflight=4)
tickets = [pipelined.submit((lambda a: (a + a.T) / 2)(
               rng.standard_normal((n, n)).astype(np.float32)), op="eigh")
           for n in (12, 29, 17, 24, 21, 14, 26, 19)]
pipelined.drain()
a = pipelined.stats.summary()
print(f"\nasync pipeline: {a['requests']} requests, max in-flight depth "
      f"{a['max_inflight_depth']}, host/device overlap "
      f"{a['overlap_frac']:.0%} of the dispatch-to-retire span")
