"""Scenario: end-to-end LM training (reduced olmo-1b on CPU) with
checkpointing and simulated preemption + elastic resume.

    PYTHONPATH=src python examples/train_lm.py
"""
import tempfile

from repro.launch import train

with tempfile.TemporaryDirectory() as d:
    ck = f"{d}/ckpt"
    args = ["--arch", "olmo-1b", "--reduced", "--steps", "40",
            "--global-batch", "8", "--seq-len", "64", "--lr", "1e-2",
            "--ckpt-dir", ck, "--log-every", "10"]
    print("== run until simulated preemption at step 20 ==")
    train.main(args + ["--preempt-at", "20"])
    print("== elastic resume from the checkpoint ==")
    losses = train.main(args)
    assert losses[-1] < 5.0
    print("resumed and finished; final loss", round(losses[-1], 3))
