"""Quickstart: MANOJAVAM PCA on the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (PAPER_CONFIG_VUS, PCAConfig, fit, select_k,
                        transform)

# a dataset with structure: 4 latent factors in 32 features
rng = np.random.default_rng(0)
X = (rng.standard_normal((2000, 4)) @ rng.standard_normal((4, 32))
     + 0.1 * rng.standard_normal((2000, 32))).astype(np.float32)

# --- hardware-faithful configuration: DLE max-pivot + CORDIC angles +
#     rotations through the MM-Engine, fixed 50-sweep schedule ----------
cfg = PCAConfig(T=16, S=32, pivot="paper", rotation="matmul",
                angle="cordic", sweeps=50)
res = fit(X, cfg)
k = int(select_k(res.cvcr, variance_target=0.95))
O = transform(X, res, k, cfg)

print("top-8 eigenvalues :", np.round(np.asarray(res.eigenvalues[:8]), 2))
print("EVCR (top-8)      :", np.round(np.asarray(res.evcr[:8]), 4))
print(f"k for 95% variance: {k}")
print(f"projected shape   : {O.shape}")
print(f"final rel off-diag: {float(res.off_norm):.2e}")

# cross-check against numpy
from repro.core import covariance, standardize
Xs, _, _ = standardize(jnp.asarray(X))
ref = np.linalg.eigh(np.asarray(covariance(Xs)))[0][::-1]
err = np.max(np.abs(np.asarray(res.eigenvalues) - ref)) / ref[0]
print(f"max eig err vs numpy.linalg.eigh: {err:.2e}")
assert err < 1e-4
print("OK")
