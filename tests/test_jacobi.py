"""Jacobi eigensolver: agreement with numpy.linalg.eigh across pivot /
rotation / angle modes + hypothesis property tests on the invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core import (jacobi_eigh, jacobi_svd, offdiag_frobenius,
                        relative_offdiag, round_robin_rounds)


def _sym(n, seed=0, cond=None):
    rng = np.random.default_rng(seed)
    if cond is None:
        a = rng.standard_normal((n, n)).astype(np.float32)
        return (a + a.T) / 2
    eigs = np.geomspace(1.0, 1.0 / cond, n)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return (q * eigs) @ q.T


@pytest.mark.parametrize("pivot", ["parallel", "cyclic", "paper"])
@pytest.mark.parametrize("rotation", ["rowcol", "matmul"])
def test_matches_numpy(pivot, rotation):
    n = 24
    c = jnp.asarray(_sym(n, 1))
    sweeps = 30 if pivot == "paper" else 12
    res = jacobi_eigh(c, sweeps=sweeps, pivot=pivot, rotation=rotation)
    ref = np.linalg.eigh(np.asarray(c))
    np.testing.assert_allclose(np.asarray(res.eigenvalues),
                               ref[0][::-1], rtol=1e-4, atol=1e-4)
    # eigenvector correctness up to sign: C v = lambda v
    v = np.asarray(res.eigenvectors)
    lhs = np.asarray(c) @ v
    rhs = v * np.asarray(res.eigenvalues)[None, :]
    np.testing.assert_allclose(lhs, rhs, atol=5e-4)


@pytest.mark.parametrize("angle", ["atan2", "rutishauser", "cordic"])
def test_angle_modes(angle):
    c = jnp.asarray(_sym(16, 2))
    res = jacobi_eigh(c, sweeps=10, angle=angle)
    ref = np.linalg.eigh(np.asarray(c))[0][::-1]
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref,
                               rtol=1e-3, atol=1e-3)


def test_odd_dimension_padding():
    c = jnp.asarray(_sym(17, 3))
    res = jacobi_eigh(c, sweeps=12, pivot="parallel")
    ref = np.linalg.eigh(np.asarray(c))[0][::-1]
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref,
                               rtol=1e-4, atol=1e-4)
    assert res.eigenvectors.shape == (17, 17)


def test_fixed_50_sweep_schedule_ill_conditioned():
    """Paper Sec. VII-D: the 50-sweep factor of safety covers clustered
    spectra; well-conditioned data converges in 10-15."""
    c = jnp.asarray(_sym(32, 4, cond=1e6).astype(np.float32))
    res = jacobi_eigh(c, sweeps=50, track_history=True)
    hist = np.asarray(res.history)
    assert hist[-1] < 1e-6
    # noise floor reached well before the safety bound
    assert (hist < 1e-6).argmax() <= 15


def test_early_exit_tolerance():
    c = jnp.asarray(_sym(20, 5))
    res = jacobi_eigh(c, sweeps=50, tol=1e-5)
    assert float(res.off_norm) <= 1e-5


def test_round_robin_covers_all_pairs():
    for n in (4, 8, 14):
        rounds = round_robin_rounds(n)
        assert rounds.shape == (n - 1, n // 2, 2)
        seen = set()
        for rnd in rounds:
            cols = set()
            for p, q in rnd:
                assert p != q
                cols.update((int(p), int(q)))
                seen.add((int(p), int(q)))
            assert len(cols) == n  # disjoint within a round
        assert len(seen) == n * (n - 1) // 2


def test_jacobi_svd():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.standard_normal((40, 12)), jnp.float32)
    u, s, vt = jacobi_svd(a, sweeps=12)
    ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), ref, rtol=1e-4, atol=1e-4)
    recon = np.asarray(u) * np.asarray(s)[None, :] @ np.asarray(vt)
    np.testing.assert_allclose(recon, np.asarray(a), atol=1e-3)


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

def _invariants_case(n, seed):
    c = jnp.asarray(_sym(n, seed))
    res = jacobi_eigh(c, sweeps=14)
    v = np.asarray(res.eigenvectors)
    w = np.asarray(res.eigenvalues)
    # V orthogonal
    np.testing.assert_allclose(v.T @ v, np.eye(n), atol=5e-4)
    # reconstruction C = V diag(w) V^T
    np.testing.assert_allclose(v @ np.diag(w) @ v.T, np.asarray(c),
                               atol=5e-3)
    # eigenvalues sorted descending
    assert np.all(np.diff(w) <= 1e-5)
    # trace preserved by similarity transforms
    np.testing.assert_allclose(w.sum(), np.trace(np.asarray(c)), rtol=1e-4,
                               atol=1e-3)


@settings(max_examples=4, deadline=None)
@given(n=st.integers(3, 20), seed=st.integers(0, 2 ** 16))
def test_property_invariants_fast(n, seed):
    _invariants_case(n, seed)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 20), seed=st.integers(0, 2 ** 16))
def test_property_invariants(n, seed):
    _invariants_case(n, seed)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 16), seed=st.integers(0, 2 ** 16))
def test_property_offdiag_monotone_to_floor(n, seed):
    """Off-diagonal energy decreases (weak monotonicity modulo the
    numerical floor) and ends at the floor."""
    c = jnp.asarray(_sym(n, seed))
    res = jacobi_eigh(c, sweeps=12, track_history=True)
    hist = np.asarray(res.history)
    assert hist[-1] < 1e-5
    # each sweep reduces off-norm until the floor (allow tiny noise)
    above = hist > 1e-6
    deltas = np.diff(hist)
    assert np.all(deltas[above[:-1]] < 1e-3)
