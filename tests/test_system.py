"""End-to-end behaviour tests: training converges, checkpoints resume
bit-exactly, serving generates, gradient compression trains."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return env


def test_training_loss_decreases():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--reduced", "--steps", "25",
        "--global-batch", "8", "--seq-len", "64", "--lr", "1e-2",
        "--log-every", "100"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_training_with_compression_converges():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--reduced", "--steps", "25",
        "--global-batch", "8", "--seq-len", "64", "--lr", "1e-2",
        "--compress-grads", "4", "--log-every", "100"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.4


def test_checkpoint_resume_is_exact(tmp_path):
    """Train 10 steps straight vs 5 + resume + 5: identical final loss
    (deterministic pipeline + saved cursor)."""
    from repro.launch import train as train_mod
    base = ["--arch", "olmo-1b", "--reduced", "--global-batch", "4",
            "--seq-len", "32", "--lr", "5e-3", "--log-every", "100"]
    straight = train_mod.main(base + ["--steps", "10"])

    ck = str(tmp_path / "ck")
    # same schedule (--steps 10), preempted after 5 steps
    train_mod.main(base + ["--steps", "10", "--ckpt-dir", ck,
                           "--ckpt-every", "100", "--preempt-at", "5"])
    resumed = train_mod.main(base + ["--steps", "10", "--ckpt-dir", ck,
                                     "--ckpt-every", "100"])
    assert straight[-1] == pytest.approx(resumed[-1], rel=1e-4)


def test_serve_generates():
    from repro.launch import serve as serve_mod
    gen = serve_mod.main(["--arch", "granite-8b", "--reduced",
                          "--batch", "2", "--prompt-len", "12",
                          "--gen-len", "6"])
    assert gen.shape == (2, 6)
    assert gen.dtype == np.int32


def test_int8_moments_training():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--reduced", "--steps", "15",
        "--global-batch", "4", "--seq-len", "32", "--lr", "5e-3",
        "--moments", "int8", "--log-every", "100"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
