"""End-to-end behaviour tests: training converges, checkpoints resume
bit-exactly, serving generates, gradient compression trains."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return env


def test_training_loss_decreases():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--reduced", "--steps", "25",
        "--global-batch", "8", "--seq-len", "64", "--lr", "1e-2",
        "--log-every", "100"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_training_with_compression_converges():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--reduced", "--steps", "25",
        "--global-batch", "8", "--seq-len", "64", "--lr", "1e-2",
        "--compress-grads", "4", "--log-every", "100"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.4


@pytest.mark.slow
def test_checkpoint_resume_is_exact(tmp_path):
    """Train 10 steps straight vs 5 + resume + 5: identical final loss
    (deterministic pipeline + saved cursor)."""
    _resume_roundtrip(tmp_path, steps=10, preempt_at=5)


def test_checkpoint_resume_is_exact_fast(tmp_path):
    """Reduced variant of the resume test: 4 = 2 + 2 steps."""
    _resume_roundtrip(tmp_path, steps=4, preempt_at=2)


def _resume_roundtrip(tmp_path, steps: int, preempt_at: int):
    from repro.launch import train as train_mod
    base = ["--arch", "olmo-1b", "--reduced", "--global-batch", "4",
            "--seq-len", "32", "--lr", "5e-3", "--log-every", "100"]
    straight = train_mod.main(base + ["--steps", str(steps)])

    ck = str(tmp_path / "ck")
    # same schedule (--steps N), preempted partway
    train_mod.main(base + ["--steps", str(steps), "--ckpt-dir", ck,
                           "--ckpt-every", "100",
                           "--preempt-at", str(preempt_at)])
    resumed = train_mod.main(base + ["--steps", str(steps), "--ckpt-dir", ck,
                                     "--ckpt-every", "100"])
    assert straight[-1] == pytest.approx(resumed[-1], rel=1e-4)


def test_serve_generates():
    from repro.launch import serve as serve_mod
    gen = serve_mod.main(["--arch", "granite-8b", "--reduced",
                          "--batch", "2", "--prompt-len", "12",
                          "--gen-len", "6"])
    assert gen.shape == (2, 6)
    assert gen.dtype == np.int32


def test_int8_moments_training():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "olmo-1b", "--reduced", "--steps", "15",
        "--global-batch", "4", "--seq-len", "32", "--lr", "5e-3",
        "--moments", "int8", "--log-every", "100"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
