"""Mixed-precision policy: bf16-streamed / fp32 paths vs the fp64
subprocess oracle, held to the documented ``ERROR_BUDGETS``; and the
bitwise fp32 contract between fused and unfused paths (the budget for
fp32-vs-fp32 is zero, so it is asserted as array_equal, not a norm).

The oracle runs ``JAX_ENABLE_X64=1`` in a child process (the x64 switch
is global and import-time, so this process never flips it); one oracle
run per op is shared across tests via module-scoped fixtures.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PCAConfig, precision as prec
from repro.core.covariance import blocked_covariance
from repro.core.jacobi import jacobi_eigh, jacobi_svd
from repro.kernels import ops as kops

M, N, SWEEPS = 256, 12, 20


@pytest.fixture(scope="module")
def X():
    rng = np.random.default_rng(42)
    # mild conditioning spread so precision differences are visible but
    # the Jacobi solve still converges well inside SWEEPS
    base = rng.standard_normal((M, N))
    return (base * np.logspace(0, -2, N)[None, :]).astype(np.float32)


@pytest.fixture(scope="module")
def oracle_cov(X):
    return prec.run_fp64_oracle(X, "covariance")


@pytest.fixture(scope="module")
def oracle_eigh(X):
    return prec.run_fp64_oracle(X, "eigh", sweeps=SWEEPS)


@pytest.fixture(scope="module")
def oracle_svd(X):
    return prec.run_fp64_oracle(X, "svd", sweeps=SWEEPS)


# ---------------------------------------------------------------------------
# policy plumbing
# ---------------------------------------------------------------------------

def test_policy_dtypes():
    assert prec.operand_dtype("fp32") == jnp.float32
    assert prec.operand_dtype("bf16_fp32acc") == jnp.bfloat16
    assert prec.acc_dtype("bf16_fp32acc") == jnp.float32
    with pytest.raises(ValueError):
        prec.validate("fp16")


def test_serving_process_is_not_x64():
    """The whole point of the subprocess oracle: this process is fp32."""
    assert not prec.supports_x64()


# ---------------------------------------------------------------------------
# budgets vs the fp64 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["fp32", "bf16_fp32acc"])
def test_covariance_budget(X, oracle_cov, precision):
    C = kops.covariance(X, block_m=64, precision=precision,
                        backend="interpret")
    err = prec.rel_frobenius(np.asarray(C), oracle_cov["C"])
    budget = prec.ERROR_BUDGETS[precision]["covariance"]
    assert err < budget, f"{precision} covariance err {err} >= {budget}"


@pytest.mark.parametrize("precision", ["fp32", "bf16_fp32acc"])
def test_eigh_budget(X, oracle_eigh, precision):
    C = kops.covariance(X, block_m=64, precision=precision,
                        backend="interpret")
    res = jacobi_eigh(np.asarray(C), sweeps=SWEEPS)
    err = prec.rel_frobenius(np.asarray(res.eigenvalues),
                             oracle_eigh["eigenvalues"])
    budget = prec.ERROR_BUDGETS[precision]["eigh"]
    assert err < budget, f"{precision} eigh err {err} >= {budget}"


@pytest.mark.parametrize("precision", ["fp32", "bf16_fp32acc"])
def test_svd_budget(X, oracle_svd, precision):
    _, s, _ = jacobi_svd(X, sweeps=SWEEPS, fused=True,
                         fused_backend="interpret", precision=precision)
    err = prec.rel_frobenius(np.asarray(s), oracle_svd["S"])
    budget = prec.ERROR_BUDGETS[precision]["svd"]
    assert err < budget, f"{precision} svd err {err} >= {budget}"


# ---------------------------------------------------------------------------
# fp32 fused-vs-unfused is bitwise (budget zero, asserted exactly)
# ---------------------------------------------------------------------------

def test_fp32_fused_covariance_bitwise(X):
    fused = blocked_covariance(X, block_m=64, fused=True,
                               backend="interpret", precision="fp32")
    unfused = jax.jit(lambda a: blocked_covariance(a, block_m=64))(X)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


def test_fp32_fused_eigh_bitwise():
    rng = np.random.default_rng(7)
    a = rng.standard_normal((10, 10)).astype(np.float32)
    C = (a + a.T) / 2
    u = jacobi_eigh(C, sweeps=8, fused=False)
    f = jacobi_eigh(C, sweeps=8, fused=True, fused_backend="interpret")
    np.testing.assert_array_equal(np.asarray(u.eigenvalues),
                                  np.asarray(f.eigenvalues))
    np.testing.assert_array_equal(np.asarray(u.eigenvectors),
                                  np.asarray(f.eigenvectors))


def test_bf16_halves_streamed_bytes():
    """The policy's entire value: the operand panels stream at 2 bytes."""
    assert jnp.dtype(prec.operand_dtype("bf16_fp32acc")).itemsize == 2
    assert jnp.dtype(prec.acc_dtype("bf16_fp32acc")).itemsize == 4
