"""Open-loop traffic frontend: seeded arrival generators (determinism,
rate, process shape), token-bucket quotas, WFQ virtual-finish-time
scheduling with a priority lane, admission control (shed / degrade -- the
degraded request runs the *same* relaxed ``SolverKey`` executable a server
configured at the reduced sweep count would build), the bit-deterministic
virtual-clock run, and the tenant-labeled metric families."""
import numpy as np
import pytest

from repro.core import PCAConfig
from repro.obs import MetricRegistry, TenantAccounting
from repro.serving import (AdmissionController, BucketPolicy, CostModel,
                           FairQueue, PCAServer, TenantSpec, TokenBucket,
                           TrafficFrontend, VirtualClock, arrival_times,
                           generate, materialize, merge, parse_tenants,
                           profile_of)


def _server(clock=None, sweeps=6, **kw):
    kw.setdefault("config", PCAConfig(T=8, S=4, sweeps=sweeps))
    kw.setdefault("policy", BucketPolicy(T=8))
    kw.setdefault("max_delay_s", 0.01)
    if clock is not None:
        kw["clock"] = clock
    return PCAServer(**kw)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["poisson", "diurnal", "bursty"])
def test_arrival_times_deterministic_and_monotone(kind):
    a = arrival_times(kind, rate=50.0, n=300, seed=4)
    b = arrival_times(kind, rate=50.0, n=300, seed=4)
    assert a == b                            # bit-identical, seeded
    assert a != arrival_times(kind, rate=50.0, n=300, seed=5)
    assert len(a) == 300
    assert all(t2 >= t1 for t1, t2 in zip(a, a[1:]))


@pytest.mark.parametrize("kind", ["poisson", "diurnal", "bursty"])
def test_arrival_times_hit_the_mean_rate(kind):
    """All three processes are rate-parameterized by their *long-run
    mean*: measured rate over a long stream lands near the asked-for
    one (thinning and on-off modulation change the shape, not the mean)."""
    rate, n = 80.0, 4000
    # short modulation cycles so the stream covers many of them -- over a
    # fraction of one, the phase *should* skew the measured mean
    times = arrival_times(kind, rate=rate, n=n, seed=1, period_s=5.0,
                          on_s=0.1, off_s=0.3)
    measured = n / times[-1]
    assert measured == pytest.approx(rate, rel=0.15)


def test_bursty_is_burstier_than_poisson():
    """The Markov-modulated process concentrates arrivals: its
    inter-arrival squared coefficient of variation exceeds the Poisson
    stream's (which sits near 1)."""
    def cv2(kind):
        t = np.asarray(arrival_times(kind, rate=50.0, n=3000, seed=2))
        gaps = np.diff(t)
        return float(np.var(gaps) / np.mean(gaps) ** 2)
    assert cv2("bursty") > 1.5 * cv2("poisson")


def test_arrival_times_rejects_bad_args():
    with pytest.raises(ValueError, match="unknown arrival kind"):
        arrival_times("uniform", rate=10.0, n=5)
    with pytest.raises(ValueError, match="rate"):
        arrival_times("poisson", rate=0.0, n=5)
    assert arrival_times("poisson", rate=10.0, n=0) == []


def test_generate_tenants_shapes_and_merge():
    whale = TenantSpec("whale", share=0.75)
    mouse = TenantSpec("mouse", share=0.25)
    stream = generate("poisson", rate=100.0, n=800,
                      tenants=(whale, mouse), seed=3, trace="uniform",
                      lo=4, hi=8)
    frac = sum(a.tenant == "whale" for a in stream) / len(stream)
    assert frac == pytest.approx(0.75, abs=0.05)
    assert all(4 <= a.shape[0] <= 8 and a.shape[0] == a.shape[1]
               for a in stream)
    svd = generate("poisson", rate=100.0, n=10, op="svd", seed=3,
                   trace="uniform", lo=4, hi=8)
    assert all(a.shape == (4 * a.shape[1], a.shape[1]) for a in svd)
    merged = merge(stream[:5], svd[:5])
    assert [a.rid for a in merged] == list(range(10))
    assert all(x.t <= y.t for x, y in zip(merged, merged[1:]))


def test_materialize_is_order_independent():
    a = generate("poisson", rate=10.0, n=4, seed=0, lo=4, hi=8)
    m2 = materialize(a[2], seed=9)
    _ = materialize(a[0], seed=9)            # interleave other requests
    np.testing.assert_array_equal(materialize(a[2], seed=9), m2)


def test_profile_of_measures_the_stream():
    stream = generate("poisson", rate=50.0, n=400, seed=1, trace="uniform",
                      lo=4, hi=8)
    prof = profile_of(stream)
    assert prof.requests == 400
    span = stream[-1].t - stream[0].t
    assert prof.arrival_rate == pytest.approx(400 / span)
    assert prof.duration_s == pytest.approx(span)


def test_parse_tenants():
    ts = parse_tenants("whale:0.9,mouse:0.1")
    assert [(t.name, t.share) for t in ts] == [("whale", 0.9),
                                               ("mouse", 0.1)]
    rt, batch = parse_tenants("rt:0.2:2:p, batch:0.8:1")
    assert rt.priority and rt.weight == 2.0
    assert not batch.priority
    with pytest.raises(ValueError):
        parse_tenants(",")


# ---------------------------------------------------------------------------
# token bucket
# ---------------------------------------------------------------------------

def test_token_bucket_enforces_rate_under_injected_clock():
    b = TokenBucket(rate=2.0, burst=2.0)
    assert b.try_take(0.0) and b.try_take(0.0)   # burst depth
    assert not b.try_take(0.0)                   # empty
    assert not b.try_take(0.4)                   # 0.8 tokens refilled
    assert b.try_take(0.5)                       # 1.0 -- one full token
    assert not b.try_take(0.5)


def test_token_bucket_caps_at_burst_and_unlimited_rate():
    b = TokenBucket(rate=10.0, burst=3.0)
    b.try_take(0.0)
    for _ in range(3):                           # long idle refills to burst,
        assert b.try_take(100.0)                 # not rate * idle
    assert not b.try_take(100.0)
    assert all(TokenBucket(rate=0.0).try_take(0.0) for _ in range(100))


# ---------------------------------------------------------------------------
# fair queue
# ---------------------------------------------------------------------------

def test_wfq_serves_in_weight_proportion():
    q = FairQueue({"a": 3.0, "b": 1.0}, mode="wfq")
    for i in range(12):
        q.push("a", ("a", i), work=1.0)
        q.push("b", ("b", i), work=1.0)
    got = [q.pop()[0] for _ in range(8)]
    assert got.count("a") == 6 and got.count("b") == 2   # 3:1


def test_wfq_idle_tenant_rejoins_at_current_vtime():
    """SFQ rule: an idle tenant must not bank virtual time -- after ``b``
    sat out, its items compete from current vtime (interleaving 1:1 with
    ``a``), not from tag 0 (which would drain b's whole burst first)."""
    q = FairQueue({"a": 1.0, "b": 1.0}, mode="wfq")
    for i in range(6):
        q.push("a", f"a{i}", work=1.0)       # tags 0..5
    for _ in range(4):
        q.pop()                              # vtime advances to 3.0
    q.push("b", "b0", work=1.0)              # tag max(3.0, 0) = 3.0
    q.push("b", "b1", work=1.0)              # tag 4.0
    assert [q.pop()[0] for _ in range(4)] == ["b", "a", "b", "a"]


def test_priority_lane_bypasses_wfq():
    q = FairQueue({"a": 1.0, "rt": 1.0}, mode="wfq")
    for i in range(5):
        q.push("a", i, work=1.0)
    q.push("rt", "now", work=1.0, priority=True)
    assert q.pop() == ("rt", 1.0, "now")
    assert q.priority_work() == 0.0
    assert q.pop()[0] == "a"


def test_fifo_mode_is_arrival_order():
    q = FairQueue({"a": 100.0, "b": 1.0}, mode="fifo")
    q.push("b", 0, work=5.0)
    q.push("a", 1, work=0.1)
    assert [q.pop()[2] for _ in range(2)] == [0, 1]


def test_fair_queue_work_accounting():
    q = FairQueue({"a": 1.0, "b": 1.0}, mode="wfq")
    q.push("a", 0, work=2.0)
    q.push("b", 1, work=3.0)
    assert q.queued_work() == pytest.approx(5.0)
    assert q.queued_work("a") == pytest.approx(2.0)
    assert q.depth("b") == 1 and len(q) == 2
    assert q.weight_share("a") == pytest.approx(0.5)
    q.pop()
    assert len(q) == 1
    with pytest.raises(IndexError):
        FairQueue(mode="fifo").pop()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def _admission(mode, slo_ms=50.0, **kw):
    return AdmissionController(CostModel(device_work_per_s=1e6),
                               BucketPolicy(T=8), slo_ms / 1e3,
                               mode=mode, **kw)


def test_admission_shed_vs_admit_on_backlog():
    adm = _admission("shed")
    svc = adm.service_s("eigh", (8, 8))
    assert 0 < svc < 0.05
    assert adm.decide("eigh", (8, 8), backlog_s=0.0).outcome == "admit"
    d = adm.decide("eigh", (8, 8), backlog_s=10.0)
    assert d.outcome == "shed" and d.backlog_s == 10.0


def test_admission_none_admits_everything():
    adm = _admission("none")
    assert adm.decide("eigh", (8, 8), backlog_s=1e9).outcome == "admit"


def test_admission_degrade_when_relaxed_variant_fits():
    adm = _admission("degrade", degrade_frac=0.5)
    full = adm.service_s("eigh", (8, 8))
    deg = adm.service_s("eigh", (8, 8), sweeps_frac=0.5)
    assert deg < full
    # backlog placed so full misses the SLO but the relaxed variant fits
    backlog = 0.05 - (full + deg) / 2
    d = adm.decide("eigh", (8, 8), backlog_s=backlog)
    assert d.outcome == "degrade" and d.predicted_s == pytest.approx(deg)
    # and even the relaxed variant infeasible -> shed
    assert adm.decide("eigh", (8, 8), backlog_s=10.0).outcome == "shed"


def test_admission_rejects_unknown_mode():
    with pytest.raises(ValueError, match="admission mode"):
        _admission("maybe")


# ---------------------------------------------------------------------------
# the frontend, virtual-clock mode
# ---------------------------------------------------------------------------

def _virtual_run(stream, tenants, scheduler="wfq", admission="shed",
                 slo_ms=40.0, model=None, **fe_kw):
    clk = VirtualClock()
    srv = _server(clock=clk)
    fe = TrafficFrontend(srv, tenants, slo_ms=slo_ms, scheduler=scheduler,
                         admission=admission,
                         model=model or CostModel(device_work_per_s=1e5),
                         seed=1, **fe_kw)
    return fe.run(stream, pace=False)


def test_virtual_run_is_bit_deterministic():
    stream = generate("poisson", rate=400.0, n=60, seed=2, trace="uniform",
                      lo=4, hi=8)
    a = _virtual_run(stream, (TenantSpec("t0"),))
    b = _virtual_run(stream, (TenantSpec("t0"),))
    assert a.digest == b.digest
    assert a.outcomes == b.outcomes
    assert a.shed > 0                        # saturating stream did shed
    assert a.served + a.degraded + a.shed + a.throttled == a.requests == 60


def test_virtual_run_requires_virtual_clock():
    srv = _server()                          # wall clock
    fe = TrafficFrontend(srv, (TenantSpec("t0"),), slo_ms=40.0)
    stream = generate("poisson", rate=10.0, n=3, seed=0, lo=4, hi=8)
    with pytest.raises(TypeError, match="VirtualClock"):
        fe.run(stream, pace=False)
    with pytest.raises(ValueError, match="empty"):
        TrafficFrontend(_server(clock=VirtualClock()),
                        (TenantSpec("t0"),)).run([], pace=False)


def test_degraded_request_matches_relaxed_config_server():
    """The degrade path's whole claim: fewer sweeps through the *live*
    server equals a server configured at that sweep count -- same
    ``SolverKey``, bitwise-identical results."""
    a = generate("poisson", rate=10.0, n=1, seed=0, trace="uniform",
                 lo=6, hi=6)[0]
    mat = materialize(a, seed=1)

    live = _server(sweeps=6)
    t1 = live.submit(mat, sweeps=3)          # the frontend's degrade submit
    live.drain()
    assert t1.record.sweeps == 3
    relaxed = _server(sweeps=3)
    t2 = relaxed.submit(mat)
    relaxed.drain()
    r1, r2 = t1.result(), t2.result()
    np.testing.assert_array_equal(r1.eigenvalues, r2.eigenvalues)
    np.testing.assert_array_equal(r1.eigenvectors, r2.eigenvectors)


def test_frontend_degrade_mode_produces_degraded_outcomes():
    """Under a deliberately slow cost model every request misses at full
    sweeps; degrade admission keeps serving (relaxed variant fits), so
    the run reports degraded completions instead of sheds."""
    stream = generate("poisson", rate=20.0, n=12, seed=2, trace="uniform",
                      lo=4, hi=8)
    model = CostModel(device_work_per_s=1e6)
    fe_slo = 1e3 * 1.2 * model.request_service_s("eigh", (8, 8), batch=4,
                                                 sweeps_frac=0.5)
    rep = _virtual_run(stream, (TenantSpec("t0"),), admission="degrade",
                       slo_ms=fe_slo, model=model, degrade_frac=0.5)
    assert rep.degraded > 0
    assert rep.degraded + rep.served + rep.shed == rep.requests
    assert set(rep.outcomes.values()) <= {"served", "degraded", "shed"}


def test_frontend_throttles_over_quota_tenant():
    spec = TenantSpec("t0", rate_limit=5.0, burst=2.0)
    stream = generate("poisson", rate=500.0, n=40, seed=1, trace="uniform",
                      lo=4, hi=8, tenants=(spec,))
    rep = _virtual_run(stream, (spec,), admission="none", slo_ms=None)
    assert rep.throttled > 0
    assert rep.throttled + rep.served == rep.requests


def test_wfq_backlog_is_tenant_local_fifo_is_global():
    """The scheduler-aware admission seam: a whale's queue must not count
    against a mouse under WFQ, but does under FIFO."""
    clk = VirtualClock()
    srv = _server(clock=clk)
    model = CostModel(device_work_per_s=1e6)
    for scheduler, expect_light in (("wfq", True), ("fifo", False)):
        fe = TrafficFrontend(srv, (TenantSpec("whale"), TenantSpec("mouse")),
                             slo_ms=40.0, scheduler=scheduler, model=model)
        fe.queue.push("whale", None, work=50.0)
        mouse_backlog = fe._backlog_s("mouse", residual_s=0.0)
        if expect_light:
            assert mouse_backlog == pytest.approx(0.0)
        else:
            assert mouse_backlog == pytest.approx(50.0)


def test_priority_tenant_sees_only_priority_backlog():
    srv = _server(clock=VirtualClock())
    fe = TrafficFrontend(srv, (TenantSpec("batch"),
                               TenantSpec("rt", priority=True)),
                         slo_ms=40.0, model=CostModel())
    fe.queue.push("batch", None, work=50.0)
    assert fe._backlog_s("rt", residual_s=0.1) == pytest.approx(0.1)
    fe.queue.push("rt", None, work=2.0, priority=True)
    assert fe._backlog_s("rt", residual_s=0.1) == pytest.approx(2.1)


# ---------------------------------------------------------------------------
# tenant-labeled metrics
# ---------------------------------------------------------------------------

def test_tenant_accounting_families_and_summary():
    t = [0.0]
    acct = TenantAccounting(MetricRegistry(clock=lambda: t[0]),
                            clock=lambda: t[0])
    acct.outcome("whale", "served")
    acct.outcome("whale", "shed")
    acct.outcome("mouse", "served")
    acct.served("whale", 0.010, slo_ok=True)
    acct.served("mouse", 0.200, slo_ok=False)
    with pytest.raises(ValueError, match="unknown outcome"):
        acct.outcome("whale", "vanished")
    text = acct.registry.to_prometheus()
    assert ('frontend_requests_total{tenant="whale",outcome="shed"} 1'
            in text)
    assert ('frontend_tenant_slo_total{tenant="mouse",status="miss"} 1'
            in text)
    doc = acct.summary(span_s=2.0)
    assert doc["whale"]["slo_ok"] == 1
    assert doc["whale"]["goodput_rps"] == pytest.approx(0.5)
    assert doc["mouse"]["latency_p99_ms"] == pytest.approx(200.0)
    assert acct.tenants() == ["mouse", "whale"]


def test_frontend_mirrors_outcomes_into_accounting():
    acct = TenantAccounting()
    stream = generate("poisson", rate=400.0, n=50, seed=2, trace="uniform",
                      lo=4, hi=8)
    rep = _virtual_run(stream, (TenantSpec("t0"),), accounting=acct)
    doc = acct.summary()
    assert doc["t0"]["served"] == rep.served
    assert doc["t0"]["shed"] == rep.shed
    text = acct.registry.to_prometheus()
    assert 'frontend_tenant_goodput_rps{tenant="t0"}' in text
    assert 'frontend_tenant_latency_seconds' in text
