"""Perf-regression gate: row identity matching (exact and widened),
regression detection, and the added/missing-row tolerance -- driven through
``compare_docs`` so no git state or benchmark re-run is needed."""
import importlib.util
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "check_bench", REPO_ROOT / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_spec)
sys.modules["check_bench"] = check_bench
_spec.loader.exec_module(check_bench)


def _doc(rows, section="rows"):
    return {section: rows}


def test_exact_identity_match_flags_regression():
    base = _doc([{"T": 16, "S": 4, "policy": "tile",
                  "requests_per_s": 100.0}])
    ok_doc = _doc([{"T": 16, "S": 4, "policy": "tile",
                    "requests_per_s": 90.0}])
    lines, ok = check_bench.compare_docs("x.json", base, ok_doc, tol=0.25)
    assert ok and any("ok" in ln for ln in lines)
    bad_doc = _doc([{"T": 16, "S": 4, "policy": "tile",
                     "requests_per_s": 50.0}])
    lines, ok = check_bench.compare_docs("x.json", base, bad_doc, tol=0.25)
    assert not ok and any("REGRESSION" in ln for ln in lines)


def test_widened_identity_still_gates_against_predecessor():
    """A sweep that grows a new identity axis (e.g. ``inflight``) keeps
    gating: the fresh row whose identity strictly extends the committed
    row's compares against it; extra fan-out rows are added, not errors."""
    base = _doc([{"T": 16, "S": 1, "policy": "tile",
                  "requests_per_s": 100.0}])
    fresh = _doc([
        {"T": 16, "S": 1, "policy": "tile", "inflight": 1,
         "requests_per_s": 40.0},                      # would-be regression
        {"T": 16, "S": 1, "policy": "tile", "inflight": 2,
         "requests_per_s": 150.0},                     # new fan-out row
    ])
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert not ok
    text = "\n".join(lines)
    assert "identity widened" in text and "REGRESSION" in text
    assert any(ln.strip().startswith("NEW") and "inflight=2" in ln
               for ln in lines)
    # a healthy widened row passes
    fresh["rows"][0]["requests_per_s"] = 95.0
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert ok


def test_exact_match_claims_baseline_before_widened_rows():
    """A widened row must never steal the baseline an exact fresh row
    still matches -- exact matches claim first, regardless of emission
    order, so the exact row's regression stays gated."""
    base = _doc([{"T": 16, "requests_per_s": 100.0}])
    fresh = _doc([
        {"T": 16, "inflight": 2, "requests_per_s": 150.0},  # widened, first
        {"T": 16, "requests_per_s": 40.0},                  # exact, regressed
    ])
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert not ok
    assert any("REGRESSION" in ln and "identity widened" not in ln
               for ln in lines)
    assert any(ln.strip().startswith("NEW") and "inflight=2" in ln
               for ln in lines)


def test_identity_less_base_row_is_never_a_subset_match():
    """A committed row with no identity fields at all (all floats) would be
    a 'subset' of everything; it must stay unmatched instead of gating an
    unrelated widened row."""
    base = _doc([{"requests_per_s": 100.0}])
    fresh = _doc([{"T": 16, "inflight": 2, "requests_per_s": 10.0}])
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert ok
    text = "\n".join(lines)
    assert "NEW" in text and "MISSING" in text


def test_ambiguous_subset_match_stays_unmatched():
    """Two committed candidates for one widened row: refuse to guess."""
    base = _doc([
        {"T": 16, "policy": "tile", "requests_per_s": 100.0},
        {"S": 4, "policy": "tile", "requests_per_s": 100.0},
    ])
    fresh = _doc([{"T": 16, "S": 4, "policy": "tile", "inflight": 1,
                   "requests_per_s": 10.0}])
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert ok                       # unmatched rows never fail the gate
    assert any(ln.strip().startswith("NEW") for ln in lines)
    assert sum("MISSING" in ln for ln in lines) == 2


def test_added_and_missing_rows_never_fail():
    base = _doc([{"backend": "pallas", "us_per_call": 10.0}])
    fresh = _doc([{"backend": "interpret", "us_per_call": 900.0}])
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert ok
    text = "\n".join(lines)
    assert "NEW" in text and "MISSING" in text


def test_lower_is_better_metrics():
    base = _doc([{"name": "mm", "us_per_call": 100.0}])
    slower = _doc([{"name": "mm", "us_per_call": 200.0}])
    lines, ok = check_bench.compare_docs("x.json", base, slower, tol=0.25)
    assert not ok
    faster = _doc([{"name": "mm", "us_per_call": 50.0}])
    lines, ok = check_bench.compare_docs("x.json", base, faster, tol=0.25)
    assert ok


def test_metric_must_be_shared_by_both_sides():
    """A row that grew a preferred metric the committed copy predates is
    compared on the first metric both rows carry."""
    base = _doc([{"name": "mm", "us_per_call": 100.0}])
    fresh = _doc([{"name": "mm", "requests_per_s": 1.0,
                   "us_per_call": 90.0}])
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert ok and any("us_per_call" in ln for ln in lines)


# ---------------------------------------------------------------------------
# the intra-file autotune gate (BENCH_autotune_gain.json)
# ---------------------------------------------------------------------------

def _autotune_doc(default_rps, tuned):
    rows = [{"plan": "default", "T": 16, "requests_per_s": default_rps}]
    rows += [{"plan": label, "T": 16, "requests_per_s": rps}
             for label, rps in tuned]
    return _doc(rows)


def test_autotune_gate_tuned_above_default_passes():
    doc = _autotune_doc(100.0, [("analytic", 140.0), ("measured", 150.0)])
    lines, ok = check_bench.autotune_gate("a.json", doc, tol=0.25)
    assert ok and sum("ok" in ln for ln in lines) == 2


def test_autotune_gate_tuned_within_tolerance_passes():
    """Tuned may sit slightly below default (measurement noise) as long as
    it stays within the tolerance band."""
    doc = _autotune_doc(100.0, [("measured", 80.0)])
    lines, ok = check_bench.autotune_gate("a.json", doc, tol=0.25)
    assert ok


def test_autotune_gate_tuned_losing_to_default_fails():
    doc = _autotune_doc(100.0, [("analytic", 130.0), ("measured", 60.0)])
    lines, ok = check_bench.autotune_gate("a.json", doc, tol=0.25)
    assert not ok
    assert any("BELOW-DEFAULT" in ln and "measured" in ln for ln in lines)


def test_autotune_gate_without_default_row_skips():
    doc = _doc([{"plan": "measured", "T": 16, "requests_per_s": 10.0}])
    lines, ok = check_bench.autotune_gate("a.json", doc, tol=0.25)
    assert ok and any("skipped" in ln for ln in lines)


# ---------------------------------------------------------------------------
# the intra-file cold-start gate (BENCH_cold_start.json)
# ---------------------------------------------------------------------------

def _cold_start_doc(cold_ms, warm):
    rows = [{"mode": "cold", "ttfr_ms": cold_ms}]
    rows += [{"mode": mode, "ttfr_ms": ms} for mode, ms in warm]
    return _doc(rows)


def test_cold_start_gate_warm_fast_passes():
    doc = _cold_start_doc(650.0, [("warm_disk", 45.0), ("warmup", 3.0)])
    lines, ok = check_bench.cold_start_gate("c.json", doc, tol=0.25)
    assert ok and sum(ln.strip().startswith("ok") for ln in lines) == 2


def test_cold_start_gate_warm_within_slack_passes():
    """80% reduction required, tolerance as slack on the remainder: at tol
    0.25 a warm TTFR up to 45% of cold still passes."""
    doc = _cold_start_doc(100.0, [("warm_disk", 44.0)])
    lines, ok = check_bench.cold_start_gate("c.json", doc, tol=0.25)
    assert ok


def test_cold_start_gate_still_cold_warm_row_fails():
    doc = _cold_start_doc(100.0, [("warm_disk", 90.0), ("warmup", 3.0)])
    lines, ok = check_bench.cold_start_gate("c.json", doc, tol=0.25)
    assert not ok
    assert any("STILL-COLD" in ln and "warm_disk" in ln for ln in lines)


def test_cold_start_gate_without_cold_row_skips():
    doc = _doc([{"mode": "warmup", "ttfr_ms": 3.0}])
    lines, ok = check_bench.cold_start_gate("c.json", doc, tol=0.25)
    assert ok and any("skipped" in ln for ln in lines)


def test_ttfr_rows_gate_lower_is_better():
    """The cold_start rows' ttfr_ms is a first-class (lower-is-better)
    metric for the row-vs-HEAD diff too."""
    base = _doc([{"mode": "warm_disk", "ttfr_ms": 40.0}])
    fresh = _doc([{"mode": "warm_disk", "ttfr_ms": 90.0}])
    lines, ok = check_bench.compare_docs("c.json", base, fresh, tol=0.25)
    assert not ok and any("REGRESSION" in ln for ln in lines)


# ---------------------------------------------------------------------------
# the intra-file goodput gate (BENCH_goodput.json) + the shed_frac band
# ---------------------------------------------------------------------------

def _goodput_doc(loads, fairness):
    """loads: {load_pct: (shed_rps, none_rps)};
    fairness: (wfq_worst, fifo_worst) or None."""
    rows = []
    for load, (shed, none) in sorted(loads.items()):
        rows.append({"suite": "load", "admission": "shed",
                     "load_pct": load, "goodput_rps": shed})
        rows.append({"suite": "load", "admission": "none",
                     "load_pct": load, "goodput_rps": none})
    if fairness is not None:
        wfq, fifo = fairness
        rows.append({"suite": "fairness", "scheduler": "wfq",
                     "goodput_rps": 300.0,
                     "worst_tenant_goodput_rps": wfq})
        rows.append({"suite": "fairness", "scheduler": "fifo",
                     "goodput_rps": 300.0,
                     "worst_tenant_goodput_rps": fifo})
    return _doc(rows)


def test_goodput_gate_healthy_rows_pass():
    doc = _goodput_doc({60: (230.0, 235.0), 150: (360.0, 220.0),
                        250: (380.0, 120.0)}, fairness=(16.0, 4.0))
    lines, ok = check_bench.goodput_gate("g.json", doc, tol=0.25)
    assert ok
    # sub-saturation pairs are exempt: shed ~ none there by design
    assert not any("load[60%]" in ln for ln in lines)
    assert any("load[150%]" in ln for ln in lines)
    assert any("fairness" in ln for ln in lines)


def test_goodput_gate_admission_not_winning_fails():
    """Past saturation, admission must beat unbounded queueing by 1.3x
    (minus slack; 0.975x at tol 0.25) -- a shed row that *loses* to the
    none row fails."""
    doc = _goodput_doc({250: (110.0, 120.0)}, fairness=None)
    lines, ok = check_bench.goodput_gate("g.json", doc, tol=0.25)
    assert not ok
    assert any("NO-ADMISSION-WIN" in ln for ln in lines)


def test_goodput_gate_admission_within_slack_passes():
    """1.3x floor with tol 0.25 as multiplicative slack -> 0.975x floor:
    a near-tie passes, leaving headroom for noisy hosts."""
    doc = _goodput_doc({150: (118.0, 120.0)}, fairness=None)
    lines, ok = check_bench.goodput_gate("g.json", doc, tol=0.25)
    assert ok


def test_goodput_gate_unfair_wfq_fails():
    doc = _goodput_doc({}, fairness=(5.0, 4.0))
    lines, ok = check_bench.goodput_gate("g.json", doc, tol=0.25)
    assert not ok
    assert any("UNFAIR" in ln for ln in lines)


def test_goodput_gate_without_rows_skips():
    lines, ok = check_bench.goodput_gate("g.json", _doc([]), tol=0.25)
    assert ok and any("skipped" in ln for ln in lines)


def test_goodput_rows_gate_higher_is_better():
    base = _doc([{"suite": "load", "admission": "shed", "load_pct": 150,
                  "goodput_rps": 360.0}])
    fresh = _doc([{"suite": "load", "admission": "shed", "load_pct": 150,
                   "goodput_rps": 100.0}])
    lines, ok = check_bench.compare_docs("g.json", base, fresh, tol=0.25)
    assert not ok and any("REGRESSION" in ln for ln in lines)


def test_shed_frac_band_growth_beyond_5pp_fails():
    """Goodput can hold steady while the server sheds ever more traffic;
    the shed_frac band catches that even when the rps diff passes."""
    base = _doc([{"suite": "load", "admission": "shed", "load_pct": 150,
                  "goodput_rps": 360.0, "shed_frac": 0.19}])
    fresh = _doc([{"suite": "load", "admission": "shed", "load_pct": 150,
                   "goodput_rps": 360.0, "shed_frac": 0.40}])
    lines, ok = check_bench.compare_docs("g.json", base, fresh, tol=0.25)
    assert not ok
    assert any("SHED-GREW" in ln for ln in lines)


def test_shed_frac_band_small_growth_and_shrink_pass():
    base = _doc([{"suite": "load", "admission": "shed", "load_pct": 150,
                  "goodput_rps": 360.0, "shed_frac": 0.19}])
    for frac in (0.22, 0.05):       # +3pp and a shrink both pass
        fresh = _doc([{"suite": "load", "admission": "shed",
                       "load_pct": 150, "goodput_rps": 360.0,
                       "shed_frac": frac}])
        lines, ok = check_bench.compare_docs("g.json", base, fresh,
                                             tol=0.25)
        assert ok, frac


# ---------------------------------------------------------------------------
# provenance metadata (benchmarks/common.emit_json stamps it; the gate
# must ignore it)
# ---------------------------------------------------------------------------

def test_provenance_block_is_not_a_row_source():
    """The provenance block describes the run (git SHA, emission time, jax
    version), not a measurement: it must never enter the row diff, so two
    docs differing only in provenance compare clean."""
    rows = [{"T": 16, "S": 4, "policy": "tile", "requests_per_s": 100.0}]
    base = {"rows": rows,
            "provenance": {"git_sha": "aaa", "emitted_at": "2026-01-01",
                           "jax_version": "0.4", "device_count": 1}}
    fresh = {"rows": rows,
             "provenance": {"git_sha": "bbb", "emitted_at": "2026-08-07",
                            "jax_version": "0.5", "device_count": 8}}
    assert [s for s, _ in check_bench.iter_rows(base)] == ["rows"]
    lines, ok = check_bench.compare_docs("x.json", base, fresh, tol=0.25)
    assert ok
    text = "\n".join(lines)
    assert "git_sha" not in text and "REGRESSION" not in text
    # even a list-of-dicts-shaped provenance block stays out of the diff
    weird = {"rows": rows, "provenance": [{"git_sha": "ccc"}]}
    assert [s for s, _ in check_bench.iter_rows(weird)] == ["rows"]


def test_emit_json_stamps_provenance(tmp_path, monkeypatch):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_common", REPO_ROOT / "benchmarks" / "common.py")
    common = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(common)
    monkeypatch.setattr(common, "REPO_ROOT", tmp_path)
    path = common.emit_json("provtest", {"rows": [{"T": 8, "x_us": 1.0}]})
    doc = __import__("json").loads(path.read_text())
    prov = doc["provenance"]
    assert set(prov) == {"git_sha", "emitted_at", "jax_version", "backend",
                        "device_count"}
    assert prov["jax_version"] and prov["device_count"] >= 1
    assert prov["emitted_at"].startswith("20")


# ---------------------------------------------------------------------------
# the intra-file roofline gate (BENCH_roofline.json)
# ---------------------------------------------------------------------------

def _roofline_row(variant, backend, precision, us, bucket="large",
                  bf16=False, gflops=None):
    if gflops is None:
        gflops = 1e6 / us          # any consistent flops/time stand-in
    return {"op": "covariance", "bucket": bucket, "variant": variant,
            "backend": backend, "precision": precision,
            "bf16_supported": bf16, "us_per_call": us,
            "achieved_flops": gflops * 1e9}


def test_roofline_gate_fused_win_passes():
    doc = _doc([
        _roofline_row("unfused", "xla", "fp32", 8000.0),
        _roofline_row("unfused", "interpret", "fp32", 30000.0),
        _roofline_row("fused", "interpret", "fp32", 12000.0),
        _roofline_row("fused", "ref", "fp32", 6000.0),
    ])
    lines, ok = check_bench.roofline_gate("r.json", doc, tol=0.25)
    assert ok
    assert sum(ln.strip().startswith("ok") for ln in lines) == 2


def test_roofline_gate_pairs_fused_with_same_backend_baseline():
    """The interpret fused row gates against the interpret unfused scan,
    not the faster plain-XLA one; a kernel-less backend (ref) falls back
    to the xla baseline."""
    doc = _doc([
        _roofline_row("unfused", "xla", "fp32", 5000.0),
        _roofline_row("unfused", "interpret", "fp32", 30000.0),
        # 12000us loses to xla (0.42x) but beats interpret (2.5x): ok
        _roofline_row("fused", "interpret", "fp32", 12000.0),
    ])
    lines, ok = check_bench.roofline_gate("r.json", doc, tol=0.25)
    assert ok


def test_roofline_gate_fusion_lost_fails():
    doc = _doc([
        _roofline_row("unfused", "interpret", "fp32", 10000.0),
        _roofline_row("fused", "interpret", "fp32", 15000.0),
    ])
    lines, ok = check_bench.roofline_gate("r.json", doc, tol=0.25)
    assert not ok
    assert any("FUSION-LOST" in ln for ln in lines)


def test_roofline_gate_bf16_win_required_only_where_native():
    base = [
        _roofline_row("unfused", "interpret", "fp32", 30000.0),
        _roofline_row("fused", "interpret", "fp32", 10000.0, gflops=50.0),
    ]
    # emulated bf16 (bf16_supported false): slower than fp32, still ok
    doc = _doc(base + [_roofline_row("fused", "interpret", "bf16_fp32acc",
                                     12000.0, gflops=40.0)])
    lines, ok = check_bench.roofline_gate("r.json", doc, tol=0.25)
    assert ok and any("skipped" in ln and "bf16" in ln for ln in lines)
    # native bf16 must hold the 1.3x achieved-FLOPs floor (0.975x with
    # the 25% slack -- bf16 merely *matching* fp32 within noise passes,
    # clearly losing to it does not)
    doc = _doc(base + [_roofline_row("fused", "interpret", "bf16_fp32acc",
                                     12000.0, bf16=True, gflops=42.0)])
    lines, ok = check_bench.roofline_gate("r.json", doc, tol=0.25)
    assert not ok and any("NO-BF16-WIN" in ln for ln in lines)
    doc = _doc(base + [_roofline_row("fused", "interpret", "bf16_fp32acc",
                                     6000.0, bf16=True, gflops=85.0)])
    lines, ok = check_bench.roofline_gate("r.json", doc, tol=0.25)
    assert ok


def test_roofline_gate_without_rows_skips():
    lines, ok = check_bench.roofline_gate("r.json", _doc([]), tol=0.25)
    assert ok


def test_achieved_flops_gates_higher_is_better():
    base = _doc([_roofline_row("fused", "interpret", "fp32", 10000.0,
                               gflops=50.0)])
    fresh = _doc([_roofline_row("fused", "interpret", "fp32", 25000.0,
                                gflops=20.0)])
    lines, ok = check_bench.compare_docs("r.json", base, fresh, tol=0.25)
    assert not ok and any("REGRESSION" in ln for ln in lines)


# ---------------------------------------------------------------------------
# the intra-file controller gate (BENCH_controller_regret.json)
# ---------------------------------------------------------------------------

def _controller_doc(regret, swaps, measured=18, grid=72, budget=0.25):
    return _doc([
        {"suite": "regret", "scenario": "regime_shift",
         "regret_frac": regret, "swaps": swaps, "requests_per_s": 100.0},
        {"suite": "prune", "scenario": "bimodal",
         "measured_evals": measured, "grid_size": grid,
         "budget_frac": budget, "measured_frac": measured / grid},
    ])


def test_controller_gate_healthy_rows_pass():
    doc = _controller_doc(regret=0.01, swaps=3)
    lines, ok = check_bench.controller_gate("k.json", doc, tol=0.25)
    assert ok
    assert any("regret[regime_shift]" in ln for ln in lines)
    assert any("prune[bimodal]" in ln for ln in lines)


def test_controller_gate_high_regret_fails():
    """Ceiling is 0.10 with multiplicative slack: 0.125 at tol 0.25."""
    doc = _controller_doc(regret=0.12, swaps=2)
    lines, ok = check_bench.controller_gate("k.json", doc, tol=0.25)
    assert ok                               # inside the slack band
    doc = _controller_doc(regret=0.13, swaps=2)
    lines, ok = check_bench.controller_gate("k.json", doc, tol=0.25)
    assert not ok and any("HIGH-REGRET" in ln for ln in lines)


def test_controller_gate_thrashing_fails_without_slack():
    doc = _controller_doc(regret=0.01, swaps=4)
    lines, ok = check_bench.controller_gate("k.json", doc, tol=0.25)
    assert not ok and any("THRASHING" in ln for ln in lines)


def test_controller_gate_unpruned_search_fails_without_slack():
    doc = _controller_doc(regret=0.01, swaps=2, measured=19)
    lines, ok = check_bench.controller_gate("k.json", doc, tol=0.25)
    assert not ok and any("NO-PRUNING" in ln for ln in lines)
    # exactly at the budget cap passes
    doc = _controller_doc(regret=0.01, swaps=2, measured=18)
    lines, ok = check_bench.controller_gate("k.json", doc, tol=0.25)
    assert ok


def test_controller_gate_without_rows_skips():
    lines, ok = check_bench.controller_gate("k.json", _doc([]), tol=0.25)
    assert ok and any("skipped" in ln for ln in lines)


def test_regret_frac_gates_lower_is_better():
    """regret_frac is a first-class lower-is-better metric for the
    row-vs-HEAD diff: a fresh copy with triple the regret regresses even
    when it still clears the intra-file ceiling."""
    base = _doc([{"suite": "regret", "scenario": "regime_shift",
                  "regret_frac": 0.01}])
    fresh = _doc([{"suite": "regret", "scenario": "regime_shift",
                   "regret_frac": 0.03}])
    lines, ok = check_bench.compare_docs("k.json", base, fresh, tol=0.25)
    assert not ok and any("REGRESSION" in ln for ln in lines)
    better = _doc([{"suite": "regret", "scenario": "regime_shift",
                    "regret_frac": 0.005}])
    lines, ok = check_bench.compare_docs("k.json", base, better, tol=0.25)
    assert ok
