"""Sharded serving: MeshExecutor parity with the single-device flush path,
executor-qualified cache keys, partial-flush padding to the data-axis
multiple, and graceful degradation when fewer devices are visible.

Multi-device cases follow the ``test_distributed.py`` recipe -- a
subprocess forcing ``--xla_force_host_platform_device_count=8`` -- so they
exercise a real 8-way mesh no matter how the main pytest process was
launched.  In-process cases that genuinely need >= 2 devices carry a
``skipif`` guard and only light up under the mesh-8 CI matrix job (or any
launch with multiple visible devices); everything else runs anywhere,
down to a single device.
"""
import dataclasses

import numpy as np
import pytest
import jax

from _mesh import run_in_mesh_subprocess as _run
from repro.core import PCAConfig
from repro.serving import (BucketPolicy, InFlightFlush, LocalExecutor,
                           MeshExecutor, PCAServer, host_mesh, mesh_executor)


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


# ---------------------------------------------------------------------------
# executor seam (single-device safe)
# ---------------------------------------------------------------------------

def test_default_executor_is_local():
    srv = PCAServer()
    assert isinstance(srv.executor, LocalExecutor)
    assert not isinstance(srv.executor, MeshExecutor)
    assert srv.executor.n_shards == 1
    assert srv.executor.round_batch(3) == 3
    assert srv.executor.cache_token() is None


def test_mesh_executor_single_device_parity_all_ops():
    """A 1-device mesh is the degenerate shard: results must equal the
    LocalExecutor path for all three ops (placement-invariance base case)."""
    rng = np.random.default_rng(2)
    cfg = PCAConfig(T=8, S=4, sweeps=14)
    mesh_srv = PCAServer(cfg, policy=BucketPolicy(T=8), max_delay_s=1e9,
                         executor=MeshExecutor(mesh=host_mesh(1)))
    local_srv = PCAServer(cfg, policy=BucketPolicy(T=8), max_delay_s=1e9)
    eigh_in = [_sym(n, seed=n) for n in (5, 7, 6, 8)]
    rect_in = [rng.standard_normal((24, d)).astype(np.float32)
               for d in (5, 7, 6, 4)]
    for op, mats in (("eigh", eigh_in), ("svd", rect_in), ("pca", rect_in)):
        got = mesh_srv.solve_many(mats, op=op)
        want = local_srv.solve_many(mats, op=op)
        for g, w in zip(got, want):
            fields = [f.name for f in dataclasses.fields(g)]
            assert fields, op
            for field in fields:
                np.testing.assert_allclose(
                    np.asarray(getattr(g, field)),
                    np.asarray(getattr(w, field)), rtol=1e-5, atol=1e-6,
                    err_msg=f"{op}.{field}")
    assert {r.n_shards for r in mesh_srv.stats.records} == {1}


@pytest.mark.parametrize("make_executor", [
    LocalExecutor, lambda: MeshExecutor(mesh=host_mesh(1))])
def test_executor_submit_is_nonblocking_run_is_submit_result(make_executor):
    """The dispatch-stage seam: ``submit`` hands back an InFlightFlush whose
    ``ready``/``block_until_ready``/``result`` drive the pipeline, and
    ``run`` is exactly the blocking composition of the two."""
    ex = make_executor()
    cfg = PCAConfig(T=8, S=2, sweeps=14)
    fn = ex.compile("eigh", cfg, (8, 8), 2)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((2, 8, 8)).astype(np.float32)
    batch = (a + np.swapaxes(a, 1, 2)) / 2
    n_active = np.full((2, 2), 8, np.int32)
    flush = ex.submit(fn, batch, n_active)
    assert isinstance(flush, InFlightFlush)
    assert flush.n_shards == ex.n_shards
    assert flush.block_until_ready() is flush and flush.ready()
    out = flush.result()
    assert isinstance(out.eigenvalues, np.ndarray)       # host, not device
    assert out.eigenvalues.shape == (2, 8)
    want = ex.run(fn, batch, n_active)
    np.testing.assert_array_equal(out.eigenvalues, want.eigenvalues)
    np.testing.assert_array_equal(out.eigenvectors, want.eigenvectors)
    # an executor-level flush has no engine attached: retire() must refuse
    with pytest.raises(RuntimeError, match="not attached"):
        ex.submit(fn, batch, n_active).retire()


def test_mesh_executor_rejects_foreign_axis():
    with pytest.raises(ValueError, match="data_axis"):
        MeshExecutor(mesh=host_mesh(1), data_axis="model")


def test_mesh_executor_rounds_and_validates_batch():
    ex = MeshExecutor(mesh=host_mesh(1))
    assert ex.round_batch(0) == 1 and ex.round_batch(3) == 3
    n = jax.device_count()
    ex_all = mesh_executor("auto")
    for b in range(1, 2 * max(n, 1) + 1):
        assert ex_all.round_batch(b) % ex_all.n_shards == 0
        assert ex_all.round_batch(b) >= b
    if ex_all.n_shards > 1:
        with pytest.raises(ValueError, match="multiple"):
            ex_all.compile("eigh", PCAConfig(T=8, S=4), (8, 8),
                           ex_all.n_shards + 1)


def test_mesh_executor_spec_degrades_to_visible_devices():
    """Asking for more devices than visible clamps instead of raising, so
    one launch line works from a laptop to the 8-device CI job."""
    ex = mesh_executor(str(jax.device_count() * 4))
    assert isinstance(ex, MeshExecutor)
    assert ex.n_shards == jax.device_count()
    assert mesh_executor("none").n_shards == 1
    assert mesh_executor("1").n_shards == 1
    assert not isinstance(mesh_executor("1"), MeshExecutor)


def test_executor_cache_token_distinguishes_mesh_shapes():
    tokens = {LocalExecutor().cache_token(),
              MeshExecutor(mesh=host_mesh(1)).cache_token()}
    assert len(tokens) == 2
    if jax.device_count() >= 2:
        tokens.add(MeshExecutor(mesh=host_mesh(2)).cache_token())
        assert len(tokens) == 3


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 visible devices (mesh-8 CI job runs "
                           "this in-process; single-device hosts rely on "
                           "the subprocess parity tests)")
def test_multi_device_flush_in_process():
    """Under a multi-device launch (e.g. the mesh-8 matrix job) the main
    process itself can shard a flush; records must carry the shard count."""
    ex = mesh_executor("auto")
    assert ex.n_shards == jax.device_count() > 1
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=14), policy=BucketPolicy(T=8),
                    max_batch=2 * ex.n_shards, max_delay_s=1e9, executor=ex)
    mats = [_sym(6, seed=i) for i in range(2 * ex.n_shards)]
    for m, r in zip(mats, srv.solve_many(mats)):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    assert {r.n_shards for r in srv.stats.records} == {ex.n_shards}
    assert srv.stats.summary()["max_shards"] == ex.n_shards


# ---------------------------------------------------------------------------
# real 8-way mesh (subprocess, forced host devices)
# ---------------------------------------------------------------------------

def test_sharded_flush_matches_single_device_all_ops():
    """Sharded parity -- and, since the sharded server runs a deep
    pipeline (max_inflight=3), async-over-mesh parity: in-flight sharded
    flushes must retire to exactly the synchronous local results."""
    out = _run("""
        from repro.core import PCAConfig
        from repro.serving import (BucketPolicy, MeshExecutor, PCAServer,
                                   host_mesh)
        rng = np.random.default_rng(0)
        cfg = PCAConfig(T=8, S=8, sweeps=14)
        sharded = PCAServer(cfg, policy=BucketPolicy(T=8), max_batch=8,
                            max_delay_s=1e9, max_inflight=3,
                            executor=MeshExecutor(mesh=host_mesh(8)))
        local = PCAServer(cfg, policy=BucketPolicy(T=8), max_batch=8,
                          max_delay_s=1e9)
        sym = [0.5 * (a + a.T) for a in
               [rng.standard_normal((6, 6)).astype(np.float32)
                for _ in range(8)]]
        rect = [rng.standard_normal((16, d)).astype(np.float32)
                for d in (5, 7, 6, 4, 5, 7, 6, 4)]
        import dataclasses
        errs = {}
        for op, mats in (("eigh", sym), ("svd", rect), ("pca", rect)):
            got = sharded.solve_many(mats, op=op)
            want = local.solve_many(mats, op=op)
            err = 0.0
            for g, w in zip(got, want):
                fields = [f.name for f in dataclasses.fields(g)]
                assert fields, op
                for f in fields:
                    err = max(err, float(np.max(np.abs(
                        np.asarray(getattr(g, f), np.float64)
                        - np.asarray(getattr(w, f), np.float64)))))
            errs[op] = err
        errs["n_shards"] = sorted({r.n_shards
                                   for r in sharded.stats.records})
        errs["inflight_left"] = sharded.inflight()
        print(json.dumps(errs))
    """)
    assert out["n_shards"] == [8]
    assert out["inflight_left"] == 0
    for op in ("eigh", "svd", "pca"):
        assert out[op] < 1e-5, (op, out)


def test_cache_isolation_across_mesh_shapes_and_partial_flush():
    out = _run("""
        from repro.core import PCAConfig
        from repro.serving import (BucketPolicy, MeshExecutor, PCAServer,
                                   host_mesh)
        rng = np.random.default_rng(1)
        sym = [0.5 * (a + a.T) for a in
               [rng.standard_normal((6, 6)).astype(np.float32)
                for _ in range(8)]]
        ref = [np.linalg.eigh(m)[0][::-1] for m in sym]
        srv = PCAServer(PCAConfig(T=8, S=8, sweeps=14),
                        policy=BucketPolicy(T=8), max_batch=8,
                        max_delay_s=1e9)
        ok = []
        # same server, three executors: local, 2-wide, 4-wide.  Each mesh
        # shape must compile its own executable (no placement reuse) and
        # still produce the right answers.
        for ex in (None, MeshExecutor(mesh=host_mesh(2)),
                   MeshExecutor(mesh=host_mesh(4))):
            if ex is not None:
                srv.executor = ex
            res = srv.solve_many(sym)
            ok.append(all(
                np.allclose(r.eigenvalues, e, rtol=1e-3, atol=1e-3)
                for r, e in zip(res, ref)))
        n_execs = len(srv._cache)

        # partial flush on an 8-wide mesh with pad_batches=False: 3 live
        # requests must pad up to the data-axis multiple (8), not crash
        # with a ragged shard
        srv8 = PCAServer(PCAConfig(T=8, S=8, sweeps=14),
                         policy=BucketPolicy(T=8), pad_batches=False,
                         max_delay_s=1e9,
                         executor=MeshExecutor(mesh=host_mesh(8)))
        tickets = [srv8.submit(m) for m in sym[:3]]
        srv8.drain()
        ok_partial = all(
            np.allclose(t.result().eigenvalues, e, rtol=1e-3, atol=1e-3)
            for t, e in zip(tickets, ref))
        compiled_batches = sorted(k[2] for k in srv8._cache)
        batch_sizes = sorted({r.batch_size
                              for r in srv8.stats.records})
        print(json.dumps({
            "ok": ok, "n_execs": n_execs, "ok_partial": ok_partial,
            "compiled_batches": compiled_batches,
            "batch_sizes": batch_sizes}))
    """)
    assert out["ok"] == [True, True, True]
    assert out["n_execs"] == 3          # one executable per mesh shape
    assert out["ok_partial"]
    assert out["compiled_batches"] == [8]   # 3 requests padded up to 8
    assert out["batch_sizes"] == [3]        # telemetry reports live batch
