"""Fused hot-path kernels: one-HBM-pass covariance and the one-launch
Jacobi sweep step (ISSUE 9 tentpole).

The contract under test is *bitwise* identity at fp32: the fused kernels
reorder no floating-point operation relative to the unfused jitted path,
so every assertion here is array_equal, not allclose.  Interpret mode
stands in for the Pallas backend on CPU hosts (same lowering, same
arithmetic); the ref backend is the plain-XLA oracle.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PCAConfig, pca
from repro.core.covariance import blocked_covariance
from repro.core.jacobi import (cyclic_pairs, jacobi_eigh, round_robin_rounds)
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _data(m=64, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, n)).astype(np.float32)


def _sym(n=10, seed=0):
    a = _data(n, n, seed)
    return (a + a.T) / 2


# ---------------------------------------------------------------------------
# fused covariance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64, 12), (96, 8), (128, 16)])
def test_covariance_interpret_matches_ref(shape):
    x = _data(*shape)
    got = kops.covariance(x, block_m=32, backend="interpret")
    ref = kops.covariance(x, block_m=32, backend="ref")
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    assert got.dtype == jnp.float32


def test_covariance_bitwise_vs_blocked_at_same_block():
    """The fused streaming kernel accumulates panel Grams in the same
    order as ``blocked_covariance`` at the same block_m -> bitwise."""
    x = _data(128, 16, seed=1)
    fused = blocked_covariance(x, block_m=32, fused=True,
                               backend="interpret")
    unfused = jax.jit(lambda a: blocked_covariance(a, block_m=32))(x)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


@pytest.mark.parametrize("shape", [(5, 3), (33, 7), (1, 4)])
def test_covariance_odd_shapes_pad_exactly(shape):
    """Zero-row padding adds exact zeros to the Gram: odd shapes agree
    with the plain oracle to fp32 roundoff."""
    x = _data(*shape, seed=2)
    got = kops.covariance(x, block_m=64, backend="interpret")
    np.testing.assert_allclose(got, x.T @ x, rtol=1e-5, atol=1e-5)


def test_covariance_normalize_divides_by_m_minus_1():
    x = _data(64, 8, seed=3)
    c = kops.covariance(x, block_m=32, backend="interpret", normalize=True)
    ref = kops.covariance(x, block_m=32, backend="interpret") / 63.0
    np.testing.assert_allclose(c, ref, rtol=1e-6)


def test_covariance_bf16_within_budget():
    from repro.core import precision as prec
    x = _data(256, 16, seed=4)
    lo = kops.covariance(x, block_m=64, backend="interpret",
                         precision="bf16_fp32acc")
    hi = kops.covariance(x, block_m=64, backend="ref")
    assert lo.dtype == jnp.float32          # fp32 accumulator out
    err = prec.rel_frobenius(np.asarray(lo), np.asarray(hi))
    assert err < prec.ERROR_BUDGETS["bf16_fp32acc"]["covariance"]


def test_covariance_vmaps():
    xb = np.stack([_data(32, 6, seed=i) for i in range(3)])
    got = jax.vmap(lambda x: kops.covariance(x, backend="interpret"))(xb)
    ref = np.einsum("bij,bik->bjk", xb, xb)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused Jacobi sweep step
# ---------------------------------------------------------------------------

def _pair_sets(n):
    rr = np.asarray(round_robin_rounds(n))      # parallel: disjoint pivots
    cyc = np.asarray(cyclic_pairs(n))           # cyclic: one pivot per round
    return {"parallel": rr[0], "cyclic": cyc[0],
            "parallel_last": rr[-1], "cyclic_mid": cyc[len(cyc) // 2]}


@pytest.mark.parametrize("angle", ["rutishauser", "atan2", "cordic"])
@pytest.mark.parametrize("pairs_name",
                         ["parallel", "cyclic", "parallel_last"])
def test_sweep_step_bitwise_vs_ref(angle, pairs_name):
    """One fused launch == the unfused gather/rotate chain, bitwise, for
    every angle mode and both pivot-strategy pair shapes.  Both sides
    jitted: that is how production runs them."""
    n = 10
    C = jnp.asarray(_sym(n, seed=5))
    V = jnp.eye(n, dtype=jnp.float32)
    pairs = jnp.asarray(_pair_sets(n)[pairs_name])
    Cf, Vf = jax.jit(lambda c, v, p: kops.jacobi_sweep(
        c, v, p, angle=angle, backend="interpret"))(C, V, pairs)
    Cr, Vr = jax.jit(lambda c, v, p: kref.jacobi_sweep_step(
        c, v, p, angle=angle))(C, V, pairs)
    np.testing.assert_array_equal(np.asarray(Cf), np.asarray(Cr))
    np.testing.assert_array_equal(np.asarray(Vf), np.asarray(Vr))


def test_sweep_step_null_pivot_guard():
    """A zero off-diagonal pivot must pass through as identity (the
    padding-exactness guarantee the bucketed server leans on)."""
    n = 8
    C = jnp.zeros((n, n), jnp.float32).at[:4, :4].set(jnp.asarray(_sym(4)))
    V = jnp.eye(n, dtype=jnp.float32)
    pairs = jnp.asarray([[0, 1], [4, 5], [6, 7]], jnp.int32)  # 2 dead pivots
    C2, V2 = kops.jacobi_sweep(C, V, pairs, backend="interpret")
    np.testing.assert_array_equal(np.asarray(C2[4:]), 0.0)
    np.testing.assert_array_equal(np.asarray(V2[4:, 4:]), np.eye(4))


@pytest.mark.parametrize("pivot", ["parallel", "cyclic"])
@pytest.mark.parametrize("angle", ["rutishauser", "cordic"])
def test_jacobi_eigh_fused_bitwise(pivot, angle):
    """Full solve, fused vs unfused, over all sweeps: bitwise."""
    C = _sym(8, seed=7)
    kw = dict(sweeps=6, pivot=pivot, angle=angle)
    a = jacobi_eigh(C, fused=False, **kw)
    b = jacobi_eigh(C, fused=True, fused_backend="interpret", **kw)
    np.testing.assert_array_equal(np.asarray(a.eigenvalues),
                                  np.asarray(b.eigenvalues))
    np.testing.assert_array_equal(np.asarray(a.eigenvectors),
                                  np.asarray(b.eigenvectors))


def test_jacobi_eigh_fused_paper_pivot_falls_back():
    """The paper max-pivot strategy has no fused kernel; fused=True must
    silently take the unfused path and still be bitwise with fused=False."""
    C = _sym(6, seed=8)
    a = jacobi_eigh(C, sweeps=4, pivot="paper", fused=False)
    b = jacobi_eigh(C, sweeps=4, pivot="paper", fused=True,
                    fused_backend="interpret")
    np.testing.assert_array_equal(np.asarray(a.eigenvalues),
                                  np.asarray(b.eigenvalues))


def test_jacobi_eigh_fused_converges():
    C = _sym(12, seed=9)
    res = jacobi_eigh(C, sweeps=12, fused=True, fused_backend="interpret")
    w = np.sort(np.asarray(res.eigenvalues))
    ref = np.sort(np.linalg.eigvalsh(C))
    np.testing.assert_allclose(w, ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end PCA threading
# ---------------------------------------------------------------------------

def test_pca_fit_fused_matches_unfused():
    X = _data(96, 10, seed=10)
    cfg = dict(sweeps=10, T=32)
    ru = pca.fit(X, PCAConfig(**cfg))
    rf = pca.fit(X, PCAConfig(fused=True, backend="interpret", **cfg))
    np.testing.assert_allclose(np.asarray(ru.eigenvalues),
                               np.asarray(rf.eigenvalues),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ru.cvcr), np.asarray(rf.cvcr),
                               rtol=1e-4, atol=1e-6)


def test_batched_pca_fused_vmaps():
    from repro.serving import solver as S
    Xb = np.stack([_data(64, 8, seed=i) for i in range(3)])
    cfg = dict(sweeps=8, T=32)
    bu = S.pca_fit_batched(Xb, config=PCAConfig(**cfg))
    bf = S.pca_fit_batched(
        Xb, config=PCAConfig(fused=True, backend="interpret", **cfg))
    np.testing.assert_allclose(np.asarray(bu.eigenvalues),
                               np.asarray(bf.eigenvalues),
                               rtol=1e-4, atol=1e-6)
