"""Multi-device SPMD tests, run in subprocesses with
--xla_force_host_platform_device_count=8 (shared harness in tests/_mesh.py)
so they see a real 8-way mesh no matter how the main pytest process was
launched."""
import pytest

from _mesh import run_in_mesh_subprocess as _run


def test_distributed_covariance_matches_local():
    out = _run("""
        from repro.core import covariance, distributed_covariance
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((256, 24)), jnp.float32)
        c_dist = distributed_covariance(x, mesh, block_m=16)
        c_ref = covariance(x)
        err = float(jnp.max(jnp.abs(c_dist - c_ref)))
        print(json.dumps({"err": err}))
    """)
    assert out["err"] < 1e-3


def test_distributed_pca_matches_numpy():
    out = _run("""
        from repro.core import PCAConfig, fit_distributed
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        x = (rng.standard_normal((256, 4)) @
             rng.standard_normal((4, 12))).astype(np.float32)
        res = fit_distributed(jnp.asarray(x), mesh,
                              PCAConfig(T=32, sweeps=15))
        from repro.core import standardize, covariance
        xs, _, _ = standardize(jnp.asarray(x))
        ref = np.linalg.eigh(np.asarray(covariance(xs)))[0][::-1]
        err = float(np.max(np.abs(np.asarray(res.eigenvalues) - ref)))
        print(json.dumps({"err": err}))
    """)
    assert out["err"] < 1e-2


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """2x4 mesh (DP x TP with FSDP) vs single-device: one train step on a
    reduced dense model must agree.  Slow tier: two full train-step
    compiles in a subprocess; the fast tier keeps the sharded-forward
    coverage via the MoE/ring/decode tests."""
    out = _run("""
        import dataclasses
        from repro.configs import reduced_config
        from repro.configs.shapes import ShapeCell
        from repro.launch import steps as steps_mod
        from repro.models import transformer as tfm
        from repro.optim import adamw
        from repro.parallel.sharding import REPLICATED

        cfg = dataclasses.replace(reduced_config("granite-8b"), tp=4,
                                  n_layers=2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeCell("t", 32, 4, "train")
        step, in_sh, out_sh, _, rules = steps_mod.build_train_step(
            cfg, mesh, shape)
        params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(0), cfg))
        opt_cfg = adamw.AdamWConfig()
        state = steps_mod.TrainState(params, adamw.init(params, opt_cfg),
                                     jnp.int32(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}
        with mesh:
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            new_state, metrics = jitted(state, batch)
            loss_sharded = float(metrics["loss"])

        # single-device reference
        def loss_fn(p):
            return tfm.loss_fn(p, batch, cfg, REPLICATED)
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        newp, _, _ = adamw.update(g, adamw.init(params, opt_cfg), params,
                                  opt_cfg)
        loss_ref = float(l)
        # param update agreement on a sample leaf
        a = np.asarray(jax.device_get(new_state.params["norm_f"]["scale"]))
        b = np.asarray(newp["norm_f"]["scale"])
        print(json.dumps({
            "loss_sharded": loss_sharded, "loss_ref": loss_ref,
            "param_err": float(np.max(np.abs(a - b)))}))
    """)
    assert out["loss_sharded"] == pytest.approx(out["loss_ref"], rel=2e-3)
    assert out["param_err"] < 5e-4


def test_moe_shard_map_matches_single_device():
    out = _run("""
        import dataclasses
        from repro.configs import reduced_config
        from repro.models import moe, transformer as tfm
        from repro.parallel.sharding import REPLICATED, rules_for_mesh

        cfg = dataclasses.replace(reduced_config("arctic-480b"), tp=4,
                                  n_layers=1, n_experts=8,
                                  capacity_factor=4.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = rules_for_mesh(mesh)
        key = jax.random.PRNGKey(0)
        p = jax.tree.map(lambda x: x.v if hasattr(x, "v") else x,
                         moe.init_moe(key, cfg),
                         is_leaf=lambda x: hasattr(x, "v"))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 16, cfg.d_model)),
                        jnp.float32)
        with mesh:
            y_sh, aux_sh = jax.jit(
                lambda p, x: moe.apply_moe(p, x, cfg, rules))(p, x)
            y_sh = jax.device_get(y_sh)
        y_ref, aux_ref = jax.jit(
            lambda p, x: moe.apply_moe(p, x, cfg, REPLICATED))(p, x)
        err = float(np.max(np.abs(np.asarray(y_sh) - np.asarray(y_ref))))
        print(json.dumps({"err": err, "aux_sh": float(aux_sh),
                          "aux_ref": float(aux_ref)}))
    """)
    # capacity is applied per data shard in the sharded path, so token drop
    # patterns can differ only when capacity binds; capacity_factor=4 makes
    # it non-binding -> results must match.
    assert out["err"] < 1e-3
    assert out["aux_sh"] == pytest.approx(out["aux_ref"], rel=1e-3)


@pytest.mark.slow
def test_seq_sharded_decode_matches_replicated():
    out = _run("""
        import dataclasses
        from repro.configs import reduced_config
        from repro.models import transformer as tfm
        from repro.parallel.sharding import REPLICATED, Rules

        cfg = dataclasses.replace(reduced_config("granite-8b"), n_layers=2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = Rules(mesh_axes=("data", "model"), mesh=mesh,
                      seq_over_data=False)
        params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(0), cfg))
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)),
                             jnp.int32)
        batch = {"tokens": tokens[:, :8]}
        with mesh:
            _, state = jax.jit(lambda p, b: tfm.prefill(
                p, b, cfg, rules, cache_len=16))(params, batch)
            logits, _ = jax.jit(lambda p, s, t: tfm.decode_step(
                p, s, t, cfg, rules))(params, state, tokens[:, 8])
            logits = jax.device_get(logits)
        full = tfm.forward(params, {"tokens": tokens}, cfg, REPLICATED,
                           "train")[0][:, -1, :]
        err = float(np.max(np.abs(np.asarray(logits) -
                                  np.asarray(full))))
        print(json.dumps({"err": err}))
    """)
    assert out["err"] < 5e-3


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written under a (4,2) mesh restores onto (2,2) with
    reshard-on-load (elastic restart)."""
    out = _run(f"""
        import pathlib
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpointer

        d = pathlib.Path({str(tmp_path)!r})
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = NamedSharding(mesh_a, P("data", "model"))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh_a)
        checkpointer.save(d, 3, {{"w": w}}, metadata={{"step": 3}})

        mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
        sh_b = NamedSharding(mesh_b, P("model", "data"))
        restored, meta = checkpointer.restore(
            d, {{"w": jnp.zeros((8, 8))}}, shardings={{"w": sh_b}})
        ok_values = bool(jnp.all(restored["w"] ==
                                 jnp.arange(64.0).reshape(8, 8)))
        ok_sharding = restored["w"].sharding == sh_b
        print(json.dumps({{"ok_values": ok_values,
                           "ok_sharding": bool(ok_sharding),
                           "step": meta["step"]}}))
    """)
    assert out["ok_values"] and out["ok_sharding"] and out["step"] == 3


@pytest.mark.slow
def test_moe_fused_dense_residual_matches_single_device():
    """arctic-style fused (MoE + dense residual in one shard_map psum)
    against the single-device path.  Slow tier: the plain
    test_moe_shard_map_matches_single_device keeps MoE dispatch covered
    fast."""
    out = _run("""
        import dataclasses
        from repro.configs import reduced_config
        from repro.models import moe, transformer as tfm
        from repro.models.layers import init_mlp
        from repro.parallel.sharding import REPLICATED, rules_for_mesh

        cfg = dataclasses.replace(reduced_config("arctic-480b"), tp=4,
                                  n_layers=1, n_experts=8,
                                  capacity_factor=4.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = rules_for_mesh(mesh)
        strip = lambda t: jax.tree.map(
            lambda x: x.v if hasattr(x, "v") else x, t,
            is_leaf=lambda x: hasattr(x, "v"))
        p = strip(moe.init_moe(jax.random.PRNGKey(0), cfg))
        p_mlp = strip(init_mlp(jax.random.PRNGKey(1), cfg))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 16, cfg.d_model)),
                        jnp.float32)
        with mesh:
            y_sh, aux_sh = jax.jit(lambda p, m, x: moe.apply_moe(
                p, x, cfg, rules, mlp_res=m))(p, p_mlp, x)
            y_sh = jax.device_get(y_sh)
        y_ref, aux_ref = jax.jit(lambda p, m, x: moe.apply_moe(
            p, x, cfg, REPLICATED, mlp_res=m))(p, p_mlp, x)
        err = float(np.max(np.abs(np.asarray(y_sh) - np.asarray(y_ref))))
        print(json.dumps({"err": err, "aux_sh": float(aux_sh),
                          "aux_ref": float(aux_ref)}))
    """)
    assert out["err"] < 2e-3
    assert out["aux_sh"] == pytest.approx(out["aux_ref"], rel=1e-3)


def test_ring_attention_matches_dense():
    """Sequence-parallel ring attention == dense attention, with a head
    count NOT divisible by the mesh axis (the case TP head-sharding cannot
    handle without padding)."""
    out = _run("""
        from repro.parallel.ring_attention import ring_attention
        from repro.parallel.sharding import use_mesh
        from repro.models.attention import _dense_attention
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        B, S, H, D = 4, 64, 6, 16
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        errs = {}
        for causal in (True, False):
            with use_mesh(mesh):
                o = jax.jit(lambda q, k, v: ring_attention(
                    q, k, v, mesh, causal=causal))(q, k, v)
                o = jax.device_get(o)
            ref = _dense_attention(q, k, v, causal, D ** -0.5)
            errs[str(causal)] = float(jnp.max(jnp.abs(o - np.asarray(ref))))
        print(json.dumps(errs))
    """)
    assert out["True"] < 2e-6 and out["False"] < 2e-6


def test_ring_mode_model_matches_chunked():
    """attn_impl='ring' on a 2x4 mesh == chunked single-device model with
    identical weights (qwen reduced: MHA, heads % mesh != 0)."""
    out = _run("""
        import dataclasses
        from repro.configs import reduced_config
        from repro.models import transformer as tfm
        from repro.parallel.sharding import REPLICATED, rules_for_mesh, use_mesh

        cfg_r = dataclasses.replace(reduced_config("qwen1.5-32b"), tp=4,
                                    n_layers=2, attn_impl="ring")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = rules_for_mesh(mesh)
        params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(0),
                                                 cfg_r))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg_r.vocab_size, (4, 32)), jnp.int32)}
        with use_mesh(mesh):
            lr = jax.device_get(jax.jit(lambda p, b: tfm.forward(
                p, b, cfg_r, rules, "train")[0])(params, batch))
        cfg_c = dataclasses.replace(cfg_r, tp=1, attn_impl="chunked")
        ref = tfm.forward(params, batch, cfg_c, REPLICATED, "train")[0]
        err = float(jnp.max(jnp.abs(lr - np.asarray(ref))))
        print(json.dumps({"err": err}))
    """)
    assert out["err"] < 5e-3


def test_ring_attention_gqa_rotates_true_kv():
    """GQA ring: q has 8 heads, KV only 2 -- output must equal dense
    attention with expanded KV."""
    out = _run("""
        from repro.parallel.ring_attention import ring_attention
        from repro.parallel.sharding import use_mesh
        from repro.models.attention import _dense_attention
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(1)
        B, S, H, KV, D = 2, 64, 8, 2, 16
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
        with use_mesh(mesh):
            o = jax.jit(lambda q, k, v: ring_attention(
                q, k, v, mesh, causal=True))(q, k, v)
            o = jax.device_get(o)
        kx = jnp.repeat(k, H // KV, axis=2)
        vx = jnp.repeat(v, H // KV, axis=2)
        ref = _dense_attention(q, kx, vx, True, D ** -0.5)
        err = float(jnp.max(jnp.abs(o - np.asarray(ref))))
        print(json.dumps({"err": err}))
    """)
    assert out["err"] < 2e-6
