"""Declarative server construction: ``ServerSpec`` validation, the
JSON / CLI-args / kwargs round trips, flag-conflict rejection, the
13-kwarg compatibility shim's deprecation contract, and the parity
claim -- a spec-built server serves bitwise-identically to the
kwarg-built server it replaces."""
import dataclasses
import json
import types
import warnings

import numpy as np
import pytest

from repro.core import PCAConfig
from repro.serving import (BucketPolicy, CacheSpec, ControllerSpec,
                           ExecutionSpec, ObsSpec, PCAServer,
                           SchedulingSpec, ServerSpec, SpecConflictError,
                           build_server, resolve_spec, validate_args)


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


# the serve_pca parser defaults for the flags validate_args inspects
DEFAULTS = {"tile": 16, "bucket_policy": "tile", "max_batch": 4,
            "timeout_ms": 10.0, "inflight": 1, "mesh": "none",
            "sweeps": 12, "cache_dir": None, "warmup": None,
            "slo_ms": None, "trace_out": None, "metrics_out": None,
            "jax_profile": None, "controller": "off",
            "profile_window": 5.0, "reprofile_every": 1.0,
            "hysteresis": 0.15, "min_dwell": 2.0, "spec": None,
            "autotune": "off", "arrivals": None, "profile_in": None,
            "degrade_frac": 0.5, "admission": "shed",
            "measure_top_k": 3}


def _ns(**kw):
    ns = types.SimpleNamespace(**DEFAULTS)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


# ---------------------------------------------------------------------------
# the spec itself
# ---------------------------------------------------------------------------

def test_spec_is_frozen_and_validates():
    spec = ServerSpec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.scheduling = SchedulingSpec(T=8)
    with pytest.raises(ValueError, match="unknown bucket mode"):
        ServerSpec(scheduling=SchedulingSpec(mode="fib")).validate()
    with pytest.raises(ValueError, match="must be >= 1"):
        ServerSpec(scheduling=SchedulingSpec(T=0)).validate()
    with pytest.raises(ValueError, match="hysteresis"):
        ServerSpec(controller=ControllerSpec(
            enabled=True, hysteresis=1.5)).validate()
    # controller guards only apply when the controller is on
    ServerSpec(controller=ControllerSpec(hysteresis=1.5)).validate()


def test_spec_derives_config():
    spec = ServerSpec(scheduling=SchedulingSpec(T=8, max_batch=2),
                      execution=ExecutionSpec(sweeps=7, precision="fp32"))
    cfg = spec.config()
    assert cfg.T == 8 and cfg.S == 2 and cfg.sweeps == 7
    pol = spec.scheduling.policy()
    assert isinstance(pol, BucketPolicy) and pol.T == 8


def test_spec_json_round_trip(tmp_path):
    spec = ServerSpec(
        scheduling=SchedulingSpec(mode="pow2", T=8, pow2_cap=32,
                                  max_batch=2, max_delay_s=0.5,
                                  max_inflight=3),
        execution=ExecutionSpec(mesh="auto", sweeps=9),
        cache=CacheSpec(cache_dir=str(tmp_path / "cache")),
        obs=ObsSpec(slo_ms=250.0, trace_out="trace.json"),
        controller=ControllerSpec(enabled=True, window_s=2.0,
                                  hysteresis=0.05,
                                  meshes=("none", "auto")))
    assert ServerSpec.from_json(spec.to_json()) == spec
    doc = json.loads(spec.to_json())           # valid JSON with a format tag
    assert doc["server_spec"] == 1
    path = tmp_path / "server.json"
    spec.save(path)
    assert ServerSpec.load(path) == spec
    # partial documents fill defaults, unknown sub-keys are ignored
    partial = ServerSpec.from_json('{"scheduling": {"T": 8}}')
    assert partial.scheduling.T == 8
    assert partial.execution == ExecutionSpec()


def test_spec_from_args_and_cli_round_trip():
    ns = _ns(tile=8, bucket_policy="pow2", max_batch=2, timeout_ms=20.0,
             inflight=2, sweeps=9, controller="on", profile_window=2.0,
             reprofile_every=0.5, hysteresis=0.1, min_dwell=1.0,
             slo_ms=100.0)
    spec = ServerSpec.from_args(ns)
    assert spec.scheduling == SchedulingSpec(mode="pow2", T=8, max_batch=2,
                                             max_delay_s=0.02,
                                             max_inflight=2)
    assert spec.execution.sweeps == 9
    assert spec.obs.slo_ms == 100.0 and spec.obs.armed
    assert spec.controller == ControllerSpec(
        enabled=True, window_s=2.0, reprofile_every_s=0.5, hysteresis=0.1,
        min_dwell_s=1.0)
    # args -> spec -> JSON -> spec is lossless
    assert ServerSpec.from_json(spec.to_json()) == spec
    # a bare namespace resolves to the defaults
    assert ServerSpec.from_args(types.SimpleNamespace()) == ServerSpec()


def test_spec_from_args_grows_mesh_axis():
    assert ServerSpec.from_args(_ns()).controller.meshes == ("none",)
    spec = ServerSpec.from_args(_ns(mesh="auto"))
    assert spec.execution.mesh == "auto"
    assert spec.controller.meshes == ("none", "auto")


# ---------------------------------------------------------------------------
# flag-conflict validation
# ---------------------------------------------------------------------------

def test_spec_file_conflicts_with_explicit_flags(tmp_path):
    path = tmp_path / "server.json"
    ServerSpec().save(path)
    with pytest.raises(SpecConflictError, match="--tile.*scheduling.T"):
        validate_args(_ns(spec=str(path), tile=8), DEFAULTS)
    # a flag at its parser default is not "explicitly set"
    validate_args(_ns(spec=str(path)), DEFAULTS)
    # and resolve_spec prefers the file when given
    assert resolve_spec(_ns(spec=str(path)), DEFAULTS) == ServerSpec()


def test_controller_flag_conflicts():
    with pytest.raises(SpecConflictError, match="--autotune"):
        validate_args(_ns(controller="on", autotune="analytic"), DEFAULTS)
    with pytest.raises(SpecConflictError, match="--hysteresis"):
        validate_args(_ns(hysteresis=0.05), DEFAULTS)
    with pytest.raises(SpecConflictError, match="--min-dwell"):
        validate_args(_ns(min_dwell=1.0), DEFAULTS)
    # the same knobs are fine once the controller is on
    validate_args(_ns(controller="on", hysteresis=0.05, min_dwell=1.0),
                  DEFAULTS)


def test_open_loop_and_mode_scoped_conflicts():
    with pytest.raises(SpecConflictError, match="--warmup.*--arrivals"):
        validate_args(_ns(arrivals="poisson", warmup="p.json"), DEFAULTS)
    with pytest.raises(SpecConflictError, match="--autotune.*--arrivals"):
        validate_args(_ns(arrivals="poisson", autotune="analytic"),
                      DEFAULTS)
    with pytest.raises(SpecConflictError, match="--degrade-frac"):
        validate_args(_ns(degrade_frac=0.25), DEFAULTS)
    validate_args(_ns(degrade_frac=0.25, admission="degrade"), DEFAULTS)
    with pytest.raises(SpecConflictError, match="--measure-top-k"):
        validate_args(_ns(measure_top_k=5), DEFAULTS)
    validate_args(_ns(measure_top_k=5, autotune="measured"), DEFAULTS)


# ---------------------------------------------------------------------------
# the deprecation shim
# ---------------------------------------------------------------------------

def test_kwarg_soup_warns_and_points_at_the_spec():
    cfg = PCAConfig(T=8, S=2, sweeps=6)
    with pytest.warns(DeprecationWarning, match="PCAServer.from_spec"):
        PCAServer(cfg, policy=BucketPolicy(T=8), max_batch=2,
                  max_delay_s=10.0)
    # one or two kwargs is a tweak, not a configuration: no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        PCAServer(cfg, max_delay_s=10.0, max_batch=2)
    # the spec path builds with the same kwargs internally, silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        build_server(ServerSpec(
            scheduling=SchedulingSpec(T=8, max_batch=2, max_delay_s=10.0)))


# ---------------------------------------------------------------------------
# construction parity
# ---------------------------------------------------------------------------

def _burst():
    return [_sym(n, seed=n) for n in (5, 9, 12, 7)]


def test_spec_built_server_matches_kwarg_built_bitwise():
    spec = ServerSpec(
        scheduling=SchedulingSpec(mode="tile", T=8, max_batch=2,
                                  max_delay_s=10.0),
        execution=ExecutionSpec(sweeps=8))
    a = build_server(spec)
    assert a.spec == spec
    with pytest.warns(DeprecationWarning):
        b = PCAServer(PCAConfig(T=8, S=2, sweeps=8),
                      policy=BucketPolicy(T=8), max_batch=2,
                      max_delay_s=10.0)
    for ra, rb in zip(a.solve_many(_burst()), b.solve_many(_burst())):
        np.testing.assert_array_equal(ra.eigenvalues, rb.eigenvalues)
        np.testing.assert_array_equal(ra.eigenvectors, rb.eigenvectors)
    assert a.describe_plan() == b.describe_plan()


def test_from_spec_classmethod_is_build_server():
    spec = ServerSpec(scheduling=SchedulingSpec(T=8, max_delay_s=10.0))
    srv = PCAServer.from_spec(spec)
    assert srv.spec == spec and srv.policy.T == 8
    assert srv.max_delay_s == 10.0


def test_build_server_arms_obs_and_controller_only_when_asked():
    plain = build_server(ServerSpec())
    assert plain.obs is None
    assert plain.controller is None
    armed = build_server(ServerSpec(obs=ObsSpec(slo_ms=100.0)))
    assert armed.obs is not None and armed.obs.slo is not None
    steered = build_server(ServerSpec(
        controller=ControllerSpec(enabled=True, window_s=1.0)))
    assert steered.controller.server is steered
    assert steered.controller.window_s == 1.0


def test_build_server_injects_shared_clock():
    t = [7.0]
    srv = build_server(ServerSpec(obs=ObsSpec(slo_ms=100.0)),
                       clock=lambda: t[0])
    assert srv.clock() == 7.0
    assert srv.obs.clock() == 7.0               # obs rides the same clock
