"""PCA pipeline: correctness vs numpy, EVCR/CVCR properties, selection,
projection variance, paper-faithful (DLE+CORDIC+MM-engine) configuration."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core import (PCAConfig, covariance, evcr_cvcr, find_pivot,
                        find_pivot_tilewise, fit, fit_transform, select_k,
                        standardize, transform)


def _data(m=200, d=16, seed=0):
    rng = np.random.default_rng(seed)
    # correlated features -> meaningful spectrum
    base = rng.standard_normal((m, 4))
    mix = rng.standard_normal((4, d))
    return (base @ mix + 0.1 * rng.standard_normal((m, d))).astype(np.float32)


def test_pca_matches_numpy_eigh():
    x = _data()
    res = fit(x, PCAConfig(T=32, sweeps=15))
    xs, _, _ = standardize(jnp.asarray(x))
    ref_w = np.linalg.eigh(np.asarray(covariance(xs)))[0][::-1]
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref_w,
                               rtol=1e-4, atol=1e-3)


def test_paper_faithful_configuration():
    """pivot='paper' (DLE max-pivot) + CORDIC angles + matmul rotations
    through the MM-Engine: the full unified datapath."""
    x = _data(m=120, d=10, seed=3)
    res = fit(x, PCAConfig(T=16, sweeps=40, pivot="paper", rotation="matmul",
                           angle="cordic"))
    xs, _, _ = standardize(jnp.asarray(x))
    ref_w = np.linalg.eigh(np.asarray(covariance(xs)))[0][::-1]
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref_w,
                               rtol=1e-3, atol=1e-2)


def test_projection_variance_equals_topk_eigenvalues():
    x = _data(seed=5)
    out, res = fit_transform(x, k=4, config=PCAConfig(T=32, sweeps=15))
    proj_var = np.var(np.asarray(out), axis=0, ddof=0) * x.shape[0]
    np.testing.assert_allclose(np.sort(proj_var)[::-1],
                               np.asarray(res.eigenvalues[:4]),
                               rtol=1e-3)


def test_evcr_cvcr_and_selection():
    lam = jnp.asarray([5.0, 3.0, 1.0, 0.5, 0.5])
    evcr, cvcr = evcr_cvcr(lam)
    np.testing.assert_allclose(float(evcr.sum()), 1.0, rtol=1e-6)
    assert np.all(np.diff(np.asarray(cvcr)) >= -1e-7)
    assert float(cvcr[-1]) == pytest.approx(1.0, rel=1e-6)
    assert int(select_k(cvcr, 0.8)) == 2
    assert int(select_k(cvcr, 0.95)) == 4


def test_transform_shape_and_centering():
    x = _data(seed=6)
    res = fit(x, PCAConfig(T=32, sweeps=15))
    out = transform(x, res, k=3)
    assert out.shape == (x.shape[0], 3)
    np.testing.assert_allclose(np.asarray(out).mean(0), 0.0, atol=1e-3)


def _dle_tilewise_case(n, t, rng):
    c = rng.standard_normal((n, n)).astype(np.float32)
    c = c + c.T
    a = find_pivot(jnp.asarray(c))
    b = find_pivot_tilewise(jnp.asarray(c), t)
    assert abs(float(a.apq)) == pytest.approx(abs(float(b.apq)))


def test_dle_tilewise_matches_flat_fast():
    _dle_tilewise_case(32, 8, np.random.default_rng(11))


@pytest.mark.slow
def test_dle_tilewise_matches_flat():
    rng = np.random.default_rng(11)
    for n, t in ((32, 8), (50, 16), (64, 64)):
        _dle_tilewise_case(n, t, rng)


def _property_pca_case(m, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, d)).astype(np.float32)
    res = fit(x, PCAConfig(T=16, sweeps=12))
    w = np.asarray(res.eigenvalues)
    # PSD covariance -> non-negative eigenvalues (numerical floor)
    assert w.min() > -1e-2
    # total variance of standardized data = d * m (X^T X convention)
    np.testing.assert_allclose(w.sum(), d * m, rtol=1e-2)
    evcr = np.asarray(res.evcr)
    assert abs(evcr.sum() - 1.0) < 1e-4
    v = np.asarray(res.components)
    np.testing.assert_allclose(v.T @ v, np.eye(d), atol=1e-3)


@settings(max_examples=4, deadline=None)
@given(m=st.integers(20, 100), d=st.integers(2, 12),
       seed=st.integers(0, 1000))
def test_property_pca_fast(m, d, seed):
    _property_pca_case(m, d, seed)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(m=st.integers(20, 100), d=st.integers(2, 12),
       seed=st.integers(0, 1000))
def test_property_pca(m, d, seed):
    _property_pca_case(m, d, seed)
