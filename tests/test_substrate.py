"""Substrate tests: data pipeline determinism, checkpoint atomicity +
reshard-on-load, AdamW math (incl. int8 moments), PCA gradient compression,
watchdog accounting."""
import json
import os
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.data import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.optim import compression as comp
from repro.runtime import Watchdog


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restorable():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=128, seed=3)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    a = [next(p1) for _ in range(5)]
    b = [next(p2) for _ in range(5)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # cursor restore replays exactly
    state = p1.state()
    nxt = next(p1)
    p2.restore(state)
    np.testing.assert_array_equal(next(p2), nxt)
    assert a[0].shape == (4, 33)
    assert a[0].max() < 128 and a[0].min() >= 0


def test_pipeline_host_sharding_partitions_batch():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=64, seed=1)
    whole = TokenPipeline(cfg).batch_at(7)
    parts = [TokenPipeline(cfg, process_index=i, process_count=4).batch_at(7)
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    state = {"w": jnp.arange(12.0).reshape(3, 4),
             "opt": {"m": jnp.ones((3, 4)), "count": jnp.int32(5)}}
    for step in (1, 2, 3, 4):
        checkpointer.save(tmp_path, step, state, metadata={"step": step},
                          keep=2)
    assert checkpointer.all_steps(tmp_path) == [3, 4]
    restored, meta = checkpointer.restore(tmp_path, state)
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    np.testing.assert_array_equal(np.asarray(restored["opt"]["count"]), 5)


def test_checkpoint_shape_validation(tmp_path):
    checkpointer.save(tmp_path, 1, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        checkpointer.restore(tmp_path, {"w": jnp.ones((3, 3))})


def test_checkpoint_atomicity_no_partial_dir(tmp_path):
    """A .tmp dir never satisfies latest_step (commit is the rename)."""
    (tmp_path / "step_9.tmp").mkdir(parents=True)
    assert checkpointer.latest_step(tmp_path) is None
    checkpointer.save(tmp_path, 1, {"x": jnp.zeros(3)})
    assert checkpointer.latest_step(tmp_path) == 1


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _adam_ref(p, g, m, v, t, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    lr = float(adamw.lr_schedule(cfg, jnp.int32(t)))
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adamw_matches_reference_math():
    cfg = adamw.AdamWConfig(lr=1e-2, grad_clip=1e9, warmup_steps=1,
                            decay_steps=1000)
    rng = np.random.default_rng(0)
    p = {"a": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)}
    g = {"a": jnp.asarray(0.01 * rng.standard_normal((5, 3)), jnp.float32)}
    state = adamw.init(p, cfg)
    newp, state, _ = adamw.update(g, state, p, cfg)
    ref, _, _ = _adam_ref(np.asarray(p["a"]), np.asarray(g["a"]),
                          np.zeros((5, 3)), np.zeros((5, 3)), 1, cfg)
    np.testing.assert_allclose(np.asarray(newp["a"]), ref, rtol=1e-5,
                               atol=1e-6)


def test_adamw_grad_clipping():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    p = {"a": jnp.zeros((4,))}
    g = {"a": jnp.full((4,), 100.0)}
    state = adamw.init(p, cfg)
    _, _, metrics = adamw.update(g, state, p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
def test_adamw_compact_moments_track_fp32(dtype):
    cfg32 = adamw.AdamWConfig(lr=1e-2, warmup_steps=1, decay_steps=100)
    cfgq = adamw.AdamWConfig(lr=1e-2, warmup_steps=1, decay_steps=100,
                             moment_dtype=dtype)
    rng = np.random.default_rng(1)
    p = {"w": jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)}
    s32, sq = adamw.init(p, cfg32), adamw.init(p, cfgq)
    p32, pq = p, p
    for t in range(5):
        g = {"w": jnp.asarray(0.1 * rng.standard_normal((16, 256)),
                              jnp.float32)}
        p32, s32, _ = adamw.update(g, s32, p32, cfg32)
        pq, sq, _ = adamw.update(g, sq, pq, cfgq)
    rel = (np.abs(np.asarray(pq["w"]) - np.asarray(p32["w"])).mean()
           / np.abs(np.asarray(p32["w"])).mean())
    assert rel < 0.02  # quantised moments stay close to exact Adam


# ---------------------------------------------------------------------------
# PCA gradient compression
# ---------------------------------------------------------------------------

def test_compression_low_rank_exact_for_low_rank_grad():
    cfg = comp.CompressionConfig(rank=4, min_size=1)
    rng = np.random.default_rng(2)
    u = rng.standard_normal((64, 4)).astype(np.float32)
    v = rng.standard_normal((4, 32)).astype(np.float32)
    g = {"w": jnp.asarray(u @ v)}
    state = comp.init_state(g, cfg, jax.random.PRNGKey(0))
    out, state, _ = comp.compress_tree(g, state, cfg)
    # one subspace iteration on an exactly-rank-4 matrix is near-exact
    rel = float(jnp.linalg.norm(out["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 1e-2


def test_compression_error_feedback_recovers_signal():
    """Error feedback: a persistent gradient direction dropped by the
    low-rank projection is recovered over repeated steps."""
    cfg = comp.CompressionConfig(rank=1, min_size=1)
    rng = np.random.default_rng(3)
    g_true = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    state = comp.init_state({"w": g_true}, cfg, jax.random.PRNGKey(1))
    acc = jnp.zeros_like(g_true)
    rels = []
    for i in range(30):
        out, state, _ = comp.compress_tree({"w": g_true}, state, cfg)
        acc = acc + out["w"]
        rels.append(float(jnp.linalg.norm(acc / (i + 1) - g_true)
                          / jnp.linalg.norm(g_true)))
    # the average applied update converges toward the true gradient:
    # without error feedback a rank-1 sketch of a full-rank gradient
    # would stall at a constant error
    assert rels[-1] < 0.5
    assert rels[-1] < 0.6 * rels[0]
    assert rels[-1] < rels[9] < rels[0]


def test_compression_small_params_exact():
    cfg = comp.CompressionConfig(rank=2, min_size=10_000)
    g = {"b": jnp.ones((8,)), "w": jnp.ones((4, 4))}
    state = comp.init_state(g, cfg, jax.random.PRNGKey(0))
    out, _, m = comp.compress_tree(g, state, cfg)
    np.testing.assert_array_equal(np.asarray(out["b"]), np.ones((8,)))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_straggler_accounting():
    wd = Watchdog(stall_factor=1e9, straggler_factor=1.5)
    for i in range(5):
        wd.start_step(i)
        time.sleep(0.01)
        wd.end_step()
    wd.start_step(5)
    time.sleep(0.08)
    wd.end_step()
    assert len(wd.stragglers) == 1
    assert wd.stragglers[0].step == 5
    assert wd.summary()["n_stragglers"] == 1


def test_watchdog_stall_fires():
    fired = []
    wd = Watchdog(stall_factor=1.0, floor_s=0.02,
                  on_stall=lambda: fired.append(1))
    wd.start_step(0)
    time.sleep(0.08)
    wd.end_step()
    assert fired and wd.stalled


# ---------------------------------------------------------------------------
# spectral telemetry
# ---------------------------------------------------------------------------

def test_spectral_telemetry_detects_low_rank():
    import jax
    import jax.numpy as jnp
    from repro.optim import spectral
    rng = np.random.default_rng(5)
    u = rng.standard_normal((512, 3)).astype(np.float32)
    v = rng.standard_normal((3, 256)).astype(np.float32)
    grads = {"w_lowrank": jnp.asarray(u @ v),
             "w_fullrank": jnp.asarray(rng.standard_normal((512, 256)),
                                       jnp.float32)}
    cfg = spectral.SpectralConfig(probe_dim=16, min_size=1)
    spectra = spectral.tree_spectra(grads, cfg)
    eff_low = float(spectra["['w_lowrank']"]["effective_rank"])
    eff_full = float(spectra["['w_fullrank']"]["effective_rank"])
    assert eff_low < 4.0 < eff_full
    # rank suggestion covers the low-rank signal
    r = spectral.suggest_compression_rank(
        {"w": spectra["['w_lowrank']"]}, coverage=0.95)
    assert 1 <= r <= 4
