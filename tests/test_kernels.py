"""Per-kernel allclose validation against the pure-jnp oracles (ref.py),
sweeping shapes and dtypes, in interpret mode (CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (130, 70, 150),
                                   (1, 257, 33), (128, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mm_engine(m, k, n, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    out = ops.mm_engine_matmul(a, b, block=64)
    want = ref.mm_engine(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,tile", [(64, 32), (100, 32), (256, 128),
                                    (33, 16)])
def test_dle_scan(n, tile):
    rng = np.random.default_rng(n)
    c = rng.standard_normal((n, n)).astype(np.float32)
    c = c + c.T
    piv = ops.dle_find_pivot(jnp.asarray(c), tile=tile)
    val, idx = ref.dle_scan(jnp.asarray(c))
    assert abs(float(jnp.abs(piv.apq)) - float(val)) < 1e-6
    # the pivot must be the true max off-diagonal element
    mask = np.abs(c) * (1 - np.eye(n))
    assert np.isclose(np.abs(c[int(piv.p), int(piv.q)]), mask.max())
    assert int(piv.p) != int(piv.q)


@pytest.mark.parametrize("k", [1, 5, 64, 300])
def test_cordic_kernel(k):
    rng = np.random.default_rng(k)
    apq = jnp.asarray(rng.uniform(-3, 3, k), jnp.float32)
    app = jnp.asarray(rng.uniform(-3, 3, k), jnp.float32)
    aqq = jnp.asarray(rng.uniform(-3, 3, k), jnp.float32)
    th, c, s = ops.cordic_rotation_params(apq, app, aqq, block=64)
    th_r, c_r, s_r = ref.cordic_rotation_params(apq, app, aqq)
    np.testing.assert_allclose(np.asarray(th), np.asarray(th_r), atol=3e-7)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_r), atol=3e-7)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), atol=3e-7)
    # rotation must zero the pivot: apq' = sc(app-aqq) + (c^2-s^2)apq
    apq2 = (np.asarray(s) * np.asarray(c) * (np.asarray(app - aqq))
            + (np.asarray(c) ** 2 - np.asarray(s) ** 2) * np.asarray(apq))
    np.testing.assert_allclose(apq2, 0.0, atol=1e-5)


@pytest.mark.parametrize("bh,sq,skv,d", [(2, 64, 64, 32), (4, 96, 96, 64),
                                         (1, 128, 256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(bh, sq, skv, d, causal):
    if causal and sq != skv and skv % 32:
        pytest.skip("padding requires causal")
    rng = np.random.default_rng(bh * sq)
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    off = skv - sq if causal else 0
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              q_offset=off)
    want = ref.flash_attention(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,l,d,n,chunk", [(2, 50, 16, 8, 16),
                                           (1, 128, 32, 16, 32),
                                           (3, 33, 8, 4, 8)])
def test_mamba_scan(b, l, d, n, chunk):
    rng = np.random.default_rng(l)
    u = jnp.asarray(rng.standard_normal((b, l, d)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, d)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (d, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    y = ops.mamba_scan(u, dt, A, B, C, D, chunk=chunk)
    want = ref.mamba_scan(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_mm_engine_is_blocked_covariance_backend():
    """The unified-datapath property: covariance through the mm_engine
    matches the jnp oracle."""
    from repro.core import blocked_covariance
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((300, 96)), jnp.float32)
    c_pallas = blocked_covariance(
        x, block_m=64,
        matmul_fn=lambda a, b: ops.mm_engine_matmul(a, b, block=32))
    c_ref = np.asarray(x).T @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(c_pallas), c_ref, rtol=2e-4,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 2 ** 16))
def test_property_mm_engine_any_shape(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = ops.mm_engine_matmul(a, b, block=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 120), tile=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 2 ** 16))
def test_property_dle_always_finds_max(n, tile, seed):
    rng = np.random.default_rng(seed)
    c = rng.standard_normal((n, n)).astype(np.float32)
    c = c + c.T
    piv = ops.dle_find_pivot(jnp.asarray(c), tile=tile)
    mask = np.abs(c) * (1 - np.eye(n))
    assert np.isclose(np.abs(c[int(piv.p), int(piv.q)]), mask.max(),
                      rtol=1e-6)
    assert int(piv.p) != int(piv.q)
