"""Serving subsystem: bucket policies, batched-solver equivalence with the
per-matrix path, exactness of bucket padding, and PCAServer microbatching
(flush-on-full / flush-on-timeout / executable-cache reuse)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import PCAConfig, fit, jacobi_eigh, jacobi_svd
from repro.serving import (BucketPolicy, PCAServer, jacobi_eigh_batched,
                           jacobi_svd_batched, pad_to_bucket, padding_waste,
                           pca_fit_batched, stack_requests)


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_policy_tile():
    pol = BucketPolicy(T=16, mode="tile")
    assert pol.bucket_dim(1) == 16
    assert pol.bucket_dim(16) == 16
    assert pol.bucket_dim(17) == 32
    assert pol.bucket_shape((10, 50)) == (16, 64)


def test_bucket_policy_pow2():
    pol = BucketPolicy(T=16, mode="pow2")
    # tile counts round to powers of two: 1, 2, 4, 8 tiles
    assert pol.bucket_dim(16) == 16
    assert pol.bucket_dim(33) == 64
    assert pol.bucket_dim(70) == 128


def test_pad_and_stack():
    mats = [np.ones((3, 5), np.float32), np.ones((4, 2), np.float32)]
    batch, n_active = stack_requests(mats, (8, 8))
    assert batch.shape == (2, 8, 8)
    np.testing.assert_array_equal(n_active, [[3, 4], [5, 2]])
    assert batch[0, 3:, :].sum() == 0 and batch[0, :, 5:].sum() == 0
    with pytest.raises(ValueError):
        pad_to_bucket(np.ones((9, 2)), (8, 8))
    assert padding_waste((4, 4), (8, 8)) == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# batched solvers vs the per-matrix path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pivot", [
    "parallel", "cyclic",
    pytest.param("paper", marks=pytest.mark.slow)])  # 30-sweep DLE solve
def test_eigh_batched_matches_loop(pivot):
    mats = [_sym(12, seed=i) for i in range(4)]
    sweeps = 30 if pivot == "paper" else 12
    res = jacobi_eigh_batched(jnp.asarray(np.stack(mats)), sweeps=sweeps,
                              pivot=pivot)
    for i, m in enumerate(mats):
        ref = jacobi_eigh(jnp.asarray(m), sweeps=sweeps, pivot=pivot)
        np.testing.assert_allclose(np.asarray(res.eigenvalues[i]),
                                   np.asarray(ref.eigenvalues),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.abs(np.asarray(res.eigenvectors[i])),
                                   np.abs(np.asarray(ref.eigenvectors)),
                                   atol=1e-4)


@pytest.mark.parametrize("angle", ["rutishauser", "atan2", "cordic"])
def test_bucket_padding_is_exact(angle):
    """A problem embedded in a zero-padded bucket: padded coordinates stay
    *exactly* unmixed (null-pivot guard), so padded eigenvalues are exact
    zeros, the padded block of V is exactly basis vectors, and the live
    eigenpairs match the un-padded solve."""
    n, nb = 11, 24
    a = _sym(n, seed=3)
    padded = np.zeros((1, nb, nb), np.float32)
    padded[0, :n, :n] = a
    res = jacobi_eigh_batched(jnp.asarray(padded), n_active=np.array([n]),
                              sweeps=14, angle=angle)
    w = np.asarray(res.eigenvalues[0])
    v = np.asarray(res.eigenvectors[0])
    assert np.all(w[n:] == 0.0)
    assert np.all(v[n:, :n] == 0.0)        # live eigenvectors: no padded mass
    assert np.all(v[:n, n:] == 0.0)        # padded eigenvectors: no live mass
    ref = np.linalg.eigh(a)[0][::-1]
    np.testing.assert_allclose(w[:n], ref, rtol=1e-4, atol=1e-4)


def test_bucket_padding_matches_native_solve():
    n, nb = 13, 32
    a = _sym(n, seed=7)
    padded = np.zeros((1, nb, nb), np.float32)
    padded[0, :n, :n] = a
    res = jacobi_eigh_batched(jnp.asarray(padded), n_active=np.array([n]),
                              sweeps=14)
    native = jacobi_eigh(jnp.asarray(a), sweeps=14)
    np.testing.assert_allclose(np.asarray(res.eigenvalues[0, :n]),
                               np.asarray(native.eigenvalues),
                               rtol=1e-4, atol=1e-4)


def test_svd_batched_mixed_shapes():
    rng = np.random.default_rng(5)
    shapes = [(20, 6), (17, 9), (24, 4)]
    bucket = (24, 16)
    mats = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    batch, (nr, nc) = stack_requests(mats, bucket)
    res = jacobi_svd_batched(jnp.asarray(batch), n_rows=nr, n_cols=nc,
                             sweeps=14)
    for i, (a, (m, d)) in enumerate(zip(mats, shapes)):
        ref = np.linalg.svd(a, compute_uv=False)
        np.testing.assert_allclose(np.asarray(res.S[i, :d]), ref,
                                   rtol=1e-4, atol=1e-4)
        u = np.asarray(res.U[i, :m, :d])
        s = np.asarray(res.S[i, :d])
        vt = np.asarray(res.Vt[i, :d, :d])
        np.testing.assert_allclose(u * s[None, :] @ vt, a, atol=2e-3)


def test_pca_fit_batched_matches_unbatched():
    rng = np.random.default_rng(6)
    cfg = PCAConfig(T=8, sweeps=15)
    shapes = [(40, 6), (50, 11)]
    bucket = (56, 16)
    mats = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    batch, (nr, nc) = stack_requests(mats, bucket)
    res = pca_fit_batched(jnp.asarray(batch), n_rows=nr, n_cols=nc,
                          config=cfg)
    for i, (x, (m, d)) in enumerate(zip(mats, shapes)):
        ref = fit(x, cfg)
        np.testing.assert_allclose(np.asarray(res.eigenvalues[i, :d]),
                                   np.asarray(ref.eigenvalues),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(res.mean[i, :d]),
                                   np.asarray(ref.mean), atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.cvcr[i, :d]),
                                   np.asarray(ref.cvcr), atol=1e-4)


def test_svd_matmul_fn_is_used_everywhere():
    """core satellite: the Gram product and U back-projection must route
    through the injected matmul."""
    calls = []

    def counting_mm(a, b):
        calls.append((a.shape, b.shape))
        return jnp.matmul(a, b)

    a = jnp.asarray(np.random.default_rng(0).standard_normal((10, 4)),
                    jnp.float32)
    jacobi_svd(a, matmul_fn=counting_mm, sweeps=4, rotation="rowcol")
    shapes = set(calls)
    assert ((4, 10), (10, 4)) in shapes     # Gram A^T A
    assert ((10, 4), (4, 4)) in shapes      # U = A V


# ---------------------------------------------------------------------------
# engine behavior
# ---------------------------------------------------------------------------

def _server(clock=None, **kw):
    kw.setdefault("config", PCAConfig(T=8, S=4, sweeps=12))
    kw.setdefault("policy", BucketPolicy(T=8))
    if clock is not None:
        kw["clock"] = clock
    return PCAServer(**kw)


def test_engine_flush_on_full():
    srv = _server(max_delay_s=1e9)   # deadline can never fire
    tickets = [srv.submit(_sym(6, seed=i)) for i in range(4)]
    assert all(t.done for t in tickets)          # S-full flush, no poll needed
    assert srv.pending() == 0
    for i, t in enumerate(tickets):
        ref = np.linalg.eigh(_sym(6, seed=i))[0][::-1]
        np.testing.assert_allclose(t.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)


def test_engine_flush_on_timeout():
    t = [0.0]
    srv = _server(clock=lambda: t[0], max_delay_s=0.5)
    ticket = srv.submit(_sym(6))
    assert not ticket.done
    assert srv.poll() == 0                       # deadline not reached
    t[0] = 0.49
    assert srv.poll() == 0
    t[0] = 0.51
    assert srv.poll() == 1 and ticket.done       # deadline flush
    rec = ticket.record
    assert rec.batch_size == 1 and rec.queue_s == pytest.approx(0.51)


def test_engine_executable_cache_hits_on_repeated_shapes():
    srv = _server(max_delay_s=1e9)
    [srv.submit(_sym(6, seed=i)) for i in range(4)]
    assert srv.stats.cache_misses == 1 and srv.stats.cache_hits == 0
    [srv.submit(_sym(6, seed=10 + i)) for i in range(4)]
    assert srv.stats.cache_hits == 1             # same (op, bucket, batch)
    # timeout-style partial flush pads the batch -> same executable, still hit
    srv.submit(_sym(7, seed=20))
    srv.drain()
    assert srv.stats.cache_hits == 2
    assert len(srv._cache) == 1


def test_engine_mixed_buckets_separate_queues():
    srv = _server(max_delay_s=1e9)
    small = srv.submit(_sym(6))                  # bucket (8, 8)
    big = srv.submit(_sym(12))                   # bucket (16, 16)
    assert not small.done and not big.done and srv.pending() == 2
    srv.drain()
    assert small.done and big.done
    assert small.record.bucket == (8, 8) and big.record.bucket == (16, 16)


def test_engine_stats_summary():
    srv = _server(max_delay_s=1e9)
    srv.solve_many([_sym(6, seed=i) for i in range(8)])
    s = srv.stats.summary()
    assert s["requests"] == 8 and s["flushes"] == 2
    assert s["mean_batch"] == 4.0
    assert 0.0 <= s["mean_padding_waste"] < 1.0
    assert s["latency_p99_ms"] >= s["latency_p50_ms"] > 0.0
    pvm = srv.stats.predicted_vs_measured()
    assert len(pvm) == 8 and all(r["predicted_s"] > 0 for r in pvm)
