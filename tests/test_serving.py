"""Serving subsystem: bucket policies, batched-solver equivalence with the
per-matrix path, exactness of bucket padding, PCAServer microbatching
(flush-on-full / flush-on-timeout / executable-cache reuse), and the
dispatch / in-flight / retire pipeline (sync-vs-async parity, back-pressure,
out-of-order retirement, synchronous degradation at max_inflight=1)."""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import PCAConfig, fit, jacobi_eigh, jacobi_svd
from repro.serving import (BucketPolicy, PCAServer, jacobi_eigh_batched,
                           jacobi_svd_batched, pad_to_bucket, padding_waste,
                           pca_fit_batched, stack_requests)


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_policy_tile():
    pol = BucketPolicy(T=16, mode="tile")
    assert pol.bucket_dim(1) == 16
    assert pol.bucket_dim(16) == 16
    assert pol.bucket_dim(17) == 32
    assert pol.bucket_shape((10, 50)) == (16, 64)


def test_bucket_policy_pow2():
    pol = BucketPolicy(T=16, mode="pow2")
    # tile counts round to powers of two: 1, 2, 4, 8 tiles
    assert pol.bucket_dim(16) == 16
    assert pol.bucket_dim(33) == 64
    assert pol.bucket_dim(70) == 128


def test_pad_and_stack():
    mats = [np.ones((3, 5), np.float32), np.ones((4, 2), np.float32)]
    batch, n_active = stack_requests(mats, (8, 8))
    assert batch.shape == (2, 8, 8)
    np.testing.assert_array_equal(n_active, [[3, 4], [5, 2]])
    assert batch[0, 3:, :].sum() == 0 and batch[0, :, 5:].sum() == 0
    with pytest.raises(ValueError):
        pad_to_bucket(np.ones((9, 2)), (8, 8))
    assert padding_waste((4, 4), (8, 8)) == pytest.approx(0.75)


def test_pad_to_bucket_error_paths_and_exact_fit():
    a = np.ones((4, 6), np.float32)
    # rank mismatch names both ranks
    with pytest.raises(ValueError, match="bucket rank 3 != matrix rank 2"):
        pad_to_bucket(a, (8, 8, 8))
    with pytest.raises(ValueError, match="rank 1"):
        pad_to_bucket(a, (8,))
    # per-dim overflow: either axis exceeding its bucket edge raises
    with pytest.raises(ValueError, match="dim 6 exceeds bucket dim 4"):
        pad_to_bucket(a, (8, 4))
    with pytest.raises(ValueError, match="exceeds"):
        pad_to_bucket(a, (3, 8))
    # exact fit is a no-op passthrough (no copy)
    assert pad_to_bucket(a, (4, 6)) is a
    padded = pad_to_bucket(a, (4, 8))
    assert padded.shape == (4, 8) and padded[:, 6:].sum() == 0


def test_bucket_dim_validation():
    with pytest.raises(ValueError, match="unknown bucket mode"):
        BucketPolicy(T=16, mode="fib")
    with pytest.raises(ValueError, match=">= 1"):
        BucketPolicy(T=0)
    with pytest.raises(ValueError, match=">= 1"):
        BucketPolicy(T=16).bucket_dim(0)


@pytest.mark.parametrize("T", [1, 3, 16])
def test_pow2_bucket_dim_properties(T):
    """Geometric bucketing invariants: every bucket edge covers its input,
    is idempotent (a bucket is its own bucket), is monotone in the input,
    and holds a power-of-two number of T-tiles."""
    pol = BucketPolicy(T=T, mode="pow2")
    dims = [pol.bucket_dim(n) for n in range(1, 6 * T + 2)]
    for n, d in zip(range(1, 6 * T + 2), dims):
        assert d >= n                           # covers
        assert d % T == 0                       # tile-aligned
        tiles = d // T
        assert tiles & (tiles - 1) == 0         # power-of-two tile count
        assert pol.bucket_dim(d) == d           # idempotent
    assert dims == sorted(dims)                 # monotone
    # pow2 coarsens tile counts, never refines them
    tile = BucketPolicy(T=T, mode="tile")
    assert all(pol.bucket_dim(n) >= tile.bucket_dim(n)
               for n in range(1, 6 * T + 2))


# ---------------------------------------------------------------------------
# batched solvers vs the per-matrix path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pivot", [
    "parallel", "cyclic",
    pytest.param("paper", marks=pytest.mark.slow)])  # 30-sweep DLE solve
def test_eigh_batched_matches_loop(pivot):
    mats = [_sym(12, seed=i) for i in range(4)]
    sweeps = 30 if pivot == "paper" else 12
    res = jacobi_eigh_batched(jnp.asarray(np.stack(mats)), sweeps=sweeps,
                              pivot=pivot)
    for i, m in enumerate(mats):
        ref = jacobi_eigh(jnp.asarray(m), sweeps=sweeps, pivot=pivot)
        np.testing.assert_allclose(np.asarray(res.eigenvalues[i]),
                                   np.asarray(ref.eigenvalues),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.abs(np.asarray(res.eigenvectors[i])),
                                   np.abs(np.asarray(ref.eigenvectors)),
                                   atol=1e-4)


@pytest.mark.parametrize("angle", ["rutishauser", "atan2", "cordic"])
def test_bucket_padding_is_exact(angle):
    """A problem embedded in a zero-padded bucket: padded coordinates stay
    *exactly* unmixed (null-pivot guard), so padded eigenvalues are exact
    zeros, the padded block of V is exactly basis vectors, and the live
    eigenpairs match the un-padded solve."""
    n, nb = 11, 24
    a = _sym(n, seed=3)
    padded = np.zeros((1, nb, nb), np.float32)
    padded[0, :n, :n] = a
    res = jacobi_eigh_batched(jnp.asarray(padded), n_active=np.array([n]),
                              sweeps=14, angle=angle)
    w = np.asarray(res.eigenvalues[0])
    v = np.asarray(res.eigenvectors[0])
    assert np.all(w[n:] == 0.0)
    assert np.all(v[n:, :n] == 0.0)        # live eigenvectors: no padded mass
    assert np.all(v[:n, n:] == 0.0)        # padded eigenvectors: no live mass
    ref = np.linalg.eigh(a)[0][::-1]
    np.testing.assert_allclose(w[:n], ref, rtol=1e-4, atol=1e-4)


def test_bucket_padding_matches_native_solve():
    n, nb = 13, 32
    a = _sym(n, seed=7)
    padded = np.zeros((1, nb, nb), np.float32)
    padded[0, :n, :n] = a
    res = jacobi_eigh_batched(jnp.asarray(padded), n_active=np.array([n]),
                              sweeps=14)
    native = jacobi_eigh(jnp.asarray(a), sweeps=14)
    np.testing.assert_allclose(np.asarray(res.eigenvalues[0, :n]),
                               np.asarray(native.eigenvalues),
                               rtol=1e-4, atol=1e-4)


def test_svd_batched_mixed_shapes():
    rng = np.random.default_rng(5)
    shapes = [(20, 6), (17, 9), (24, 4)]
    bucket = (24, 16)
    mats = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    batch, (nr, nc) = stack_requests(mats, bucket)
    res = jacobi_svd_batched(jnp.asarray(batch), n_rows=nr, n_cols=nc,
                             sweeps=14)
    for i, (a, (m, d)) in enumerate(zip(mats, shapes)):
        ref = np.linalg.svd(a, compute_uv=False)
        np.testing.assert_allclose(np.asarray(res.S[i, :d]), ref,
                                   rtol=1e-4, atol=1e-4)
        u = np.asarray(res.U[i, :m, :d])
        s = np.asarray(res.S[i, :d])
        vt = np.asarray(res.Vt[i, :d, :d])
        np.testing.assert_allclose(u * s[None, :] @ vt, a, atol=2e-3)


def test_pca_fit_batched_matches_unbatched():
    rng = np.random.default_rng(6)
    cfg = PCAConfig(T=8, sweeps=15)
    shapes = [(40, 6), (50, 11)]
    bucket = (56, 16)
    mats = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    batch, (nr, nc) = stack_requests(mats, bucket)
    res = pca_fit_batched(jnp.asarray(batch), n_rows=nr, n_cols=nc,
                          config=cfg)
    for i, (x, (m, d)) in enumerate(zip(mats, shapes)):
        ref = fit(x, cfg)
        np.testing.assert_allclose(np.asarray(res.eigenvalues[i, :d]),
                                   np.asarray(ref.eigenvalues),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(res.mean[i, :d]),
                                   np.asarray(ref.mean), atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.cvcr[i, :d]),
                                   np.asarray(ref.cvcr), atol=1e-4)


def test_svd_matmul_fn_is_used_everywhere():
    """core satellite: the Gram product and U back-projection must route
    through the injected matmul."""
    calls = []

    def counting_mm(a, b):
        calls.append((a.shape, b.shape))
        return jnp.matmul(a, b)

    a = jnp.asarray(np.random.default_rng(0).standard_normal((10, 4)),
                    jnp.float32)
    jacobi_svd(a, matmul_fn=counting_mm, sweeps=4, rotation="rowcol")
    shapes = set(calls)
    assert ((4, 10), (10, 4)) in shapes     # Gram A^T A
    assert ((10, 4), (4, 4)) in shapes      # U = A V


# ---------------------------------------------------------------------------
# engine behavior
# ---------------------------------------------------------------------------

def _server(clock=None, **kw):
    kw.setdefault("config", PCAConfig(T=8, S=4, sweeps=12))
    kw.setdefault("policy", BucketPolicy(T=8))
    if clock is not None:
        kw["clock"] = clock
    return PCAServer(**kw)


def test_engine_flush_on_full():
    srv = _server(max_delay_s=1e9)   # deadline can never fire
    tickets = [srv.submit(_sym(6, seed=i)) for i in range(4)]
    assert all(t.done for t in tickets)          # S-full flush, no poll needed
    assert srv.pending() == 0
    for i, t in enumerate(tickets):
        ref = np.linalg.eigh(_sym(6, seed=i))[0][::-1]
        np.testing.assert_allclose(t.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)


def test_engine_flush_on_timeout():
    t = [0.0]
    srv = _server(clock=lambda: t[0], max_delay_s=0.5)
    ticket = srv.submit(_sym(6))
    assert not ticket.done
    assert srv.poll() == 0                       # deadline not reached
    t[0] = 0.49
    assert srv.poll() == 0
    t[0] = 0.51
    assert srv.poll() == 1 and ticket.done       # deadline flush
    rec = ticket.record
    assert rec.batch_size == 1 and rec.queue_s == pytest.approx(0.51)


def test_engine_executable_cache_hits_on_repeated_shapes():
    srv = _server(max_delay_s=1e9)
    [srv.submit(_sym(6, seed=i)) for i in range(4)]
    assert srv.stats.cache_misses == 1 and srv.stats.cache_hits == 0
    [srv.submit(_sym(6, seed=10 + i)) for i in range(4)]
    assert srv.stats.cache_hits == 1             # same (op, bucket, batch)
    # timeout-style partial flush pads the batch -> same executable, still hit
    srv.submit(_sym(7, seed=20))
    srv.drain()
    assert srv.stats.cache_hits == 2
    assert len(srv._cache) == 1


def test_engine_mixed_buckets_separate_queues():
    srv = _server(max_delay_s=1e9)
    small = srv.submit(_sym(6))                  # bucket (8, 8)
    big = srv.submit(_sym(12))                   # bucket (16, 16)
    assert not small.done and not big.done and srv.pending() == 2
    srv.drain()
    assert small.done and big.done
    assert small.record.bucket == (8, 8) and big.record.bucket == (16, 16)


def test_engine_stats_summary():
    srv = _server(max_delay_s=1e9)
    srv.solve_many([_sym(6, seed=i) for i in range(8)])
    s = srv.stats.summary()
    assert s["requests"] == 8 and s["flushes"] == 2
    assert s["mean_batch"] == 4.0
    assert 0.0 <= s["mean_padding_waste"] < 1.0
    assert s["latency_p99_ms"] >= s["latency_p50_ms"] > 0.0
    pvm = srv.stats.predicted_vs_measured()
    assert len(pvm) == 8 and all(r["predicted_s"] > 0 for r in pvm)


# ---------------------------------------------------------------------------
# dispatch / in-flight / retire pipeline
# ---------------------------------------------------------------------------

def _assert_served_equal(got, want, op):
    fields = [f.name for f in dataclasses.fields(got)]
    assert fields, op
    for field in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=f"{op}.{field}")


@pytest.mark.parametrize("op", ["eigh", "svd", "pca"])
def test_async_matches_sync_per_op(op):
    """Result parity: the pipeline runs the identical cached executable on
    identical slabs, so a deep pipeline must match the synchronous engine
    bit-for-bit on every served field."""
    rng = np.random.default_rng(11)
    if op == "eigh":
        mats = [_sym(n, seed=n) for n in (5, 7, 6, 8, 4, 6, 7, 5)]
    else:
        mats = [rng.standard_normal((24, d)).astype(np.float32)
                for d in (5, 7, 6, 4, 5, 7, 6, 4)]
    got = _server(max_delay_s=1e9, max_inflight=3).solve_many(mats, op=op)
    want = _server(max_delay_s=1e9).solve_many(mats, op=op)
    for g, w in zip(got, want):
        _assert_served_equal(g, w, op)


def test_async_inflight_cap_backpressures_dispatch():
    """Dispatching past max_inflight must retire the oldest flush first:
    older microbatches complete without any poll/drain, and the pipeline
    depth never exceeds the cap."""
    srv = _server(max_delay_s=1e9, max_inflight=2,
                  config=PCAConfig(T=8, S=2, sweeps=12), max_batch=2)
    t1 = [srv.submit(_sym(6, seed=i)) for i in range(2)]      # flush 1
    assert srv.inflight() == 1 and srv.pending() == 0
    assert not any(t.done for t in t1)
    t2 = [srv.submit(_sym(6, seed=10 + i)) for i in range(2)]  # flush 2
    # cap 2: dispatching flush 2 forced flush 1 home (no poll/drain called)
    assert all(t.done for t in t1)
    t3 = [srv.submit(_sym(6, seed=20 + i)) for i in range(2)]  # flush 3
    assert all(t.done for t in t2)
    assert srv.inflight() == 1
    assert [d for _, d in srv.stats.inflight_depths] == [1, 2, 2]
    srv.drain()
    assert all(t.done for t in t1 + t2 + t3) and srv.inflight() == 0
    for i, t in enumerate(t1):
        ref = np.linalg.eigh(_sym(6, seed=i))[0][::-1]
        np.testing.assert_allclose(t.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)


def test_async_out_of_order_retirement():
    """A younger flush may retire before an older one: each flush fulfils
    only its own tickets, so completion order never corrupts results."""
    srv = _server(max_delay_s=1e9, max_inflight=4)
    small = [srv.submit(_sym(6, seed=i)) for i in range(4)]    # flush 1
    big = [srv.submit(_sym(12, seed=i)) for i in range(4)]     # flush 2
    assert srv.inflight() == 2
    big[0].result()                  # retire flush 2 while flush 1 flies
    assert all(t.done for t in big)
    assert not any(t.done for t in small) and srv.inflight() == 1
    assert srv.drain() == 4          # retires exactly flush 1
    assert all(t.done for t in small)
    for i, t in enumerate(small):
        ref = np.linalg.eigh(_sym(6, seed=i))[0][::-1]
        np.testing.assert_allclose(t.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)
    for i, t in enumerate(big):
        ref = np.linalg.eigh(_sym(12, seed=i))[0][::-1]
        np.testing.assert_allclose(t.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)


def test_max_inflight_one_is_synchronous_under_injected_clock():
    """The pipeline at depth 1 degrades exactly to the old synchronous
    flush: full batches retire inside submit, deadline flushes retire
    inside poll, and nothing is ever left in flight."""
    t = [0.0]
    srv = _server(clock=lambda: t[0], max_delay_s=0.5)
    assert srv.max_inflight == 1
    tickets = [srv.submit(_sym(6, seed=i)) for i in range(4)]
    assert all(tk.done for tk in tickets)        # S-full flush, synchronous
    assert srv.inflight() == 0
    late = srv.submit(_sym(6, seed=9))
    assert not late.done
    t[0] = 0.51
    assert srv.poll() == 1 and late.done and srv.inflight() == 0
    # under the frozen injected clock the pipeline accounting is exact:
    # dispatch == launch == wait == retire, so overlap is identically zero
    assert all(f.overlap_s == 0.0 and f.wait_s == 0.0
               for f in srv.stats.flush_records)
    assert srv.stats.summary()["max_inflight_depth"] == 1


def test_poll_dispatches_expired_queues_in_sorted_order():
    """Retirement order under poll is reproducible: expired queues are
    visited in sorted (op, bucket) order regardless of submission order."""
    t = [0.0]
    srv = _server(clock=lambda: t[0], max_delay_s=0.5)
    srv.submit(_sym(12))                         # ("eigh", (16, 16)) first
    srv.submit(_sym(6))                          # ("eigh", (8, 8)) second
    srv.submit(np.random.default_rng(0).standard_normal((8, 6))
               .astype(np.float32), op="svd")    # ("svd", (8, 8)) third
    t[0] = 1.0
    assert srv.poll() == 3
    flushed = [(r.op, r.bucket) for r in srv.stats.records]
    assert flushed == [("eigh", (8, 8)), ("eigh", (16, 16)),
                       ("svd", (8, 8))]


def test_ticket_result_error_names_op_bucket_and_depth():
    srv = _server(max_delay_s=1e9)
    srv.submit(_sym(6, seed=0))
    ticket = srv.submit(_sym(6, seed=1))
    with pytest.raises(RuntimeError, match=r"op='eigh'.*\(8, 8\).*2 "
                                           r"request\(s\)"):
        ticket.result()
    assert not ticket.done and srv.pending() == 2


def test_ticket_wait_flushes_its_own_queue():
    """wait() on a still-queued ticket dispatches its bucket's partial
    batch (like a deadline expiry) and blocks through retirement -- other
    buckets stay queued."""
    srv = _server(max_delay_s=1e9)
    other = srv.submit(_sym(12, seed=0))         # different bucket
    ticket = srv.submit(_sym(6, seed=3))
    res = ticket.wait()
    assert ticket.done and ticket.record.batch_size == 1
    assert not other.done and srv.pending() == 1
    ref = np.linalg.eigh(_sym(6, seed=3))[0][::-1]
    np.testing.assert_allclose(res.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    assert ticket.wait() is res                  # idempotent once done
    assert ticket.wait(timeout=0.0) is res


def test_ticket_wait_timeout_leaves_flush_in_flight():
    srv = _server(max_delay_s=1e9, max_inflight=2,
                  config=PCAConfig(T=8, S=1, sweeps=80), max_batch=1)
    ticket = srv.submit(_sym(24, seed=0))        # slow enough to catch flying
    assert not ticket.done and srv.inflight() == 1
    try:
        ticket.wait(timeout=0.0)
        assert ticket.done                       # device won the race: fine
    except TimeoutError:
        assert not ticket.done and srv.inflight() == 1
    res = ticket.wait()                          # no timeout: blocks home
    assert ticket.done and srv.inflight() == 0
    assert res.eigenvalues.shape == (24,)
