"""Shared forced-mesh subprocess harness.

Multi-device tests need a specific host-device count regardless of how the
main pytest process was launched; XLA fixes the device count at backend
init, so each case runs in a child process that sets XLA_FLAGS before
importing jax and prints its result as a final JSON line.  Used by
tests/test_distributed.py and tests/test_sharded_serving.py.
"""
import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_mesh_subprocess(body: str, device_count: int = 8) -> dict:
    """Run ``body`` in a child with ``device_count`` forced host devices.

    The child gets json/numpy/jax/jnp pre-imported; it must print a JSON
    object as its last stdout line, which is returned parsed.
    """
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={device_count}")
        import json
        import numpy as np
        import jax, jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])
