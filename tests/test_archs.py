"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU; output shapes and finiteness asserted.  Full
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.parallel.sharding import REPLICATED


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frames, cfg.d_model)), jnp.float32)
    return batch


# jamba's reduced config still needs a full attn_every=8 interleave period
# (8 hybrid layers): by far the slowest compiles of the suite.  The full-
# period cases run in the slow tier; the fast tier covers the hybrid
# mamba+attention+MoE path with a 2-layer interleave (below).  The arctic
# (dense-residual MoE) and whisper (encdec) *train* steps ride in the slow
# tier too -- their forward/decode smokes keep those families covered fast.
_SLOW_ARCHS = {"jamba-v0.1-52b"}
_SLOW_TRAIN_ARCHS = _SLOW_ARCHS | {"arctic-480b", "whisper-small",
                                   "granite-34b"}
_SLOW_DECODE_ARCHS = _SLOW_ARCHS | {"whisper-small"}


def _mark_slow(archs, slow):
    return [pytest.param(a, marks=pytest.mark.slow) if a in slow else a
            for a in archs]


def _fast_hybrid_config():
    """2-layer jamba stand-in: one mamba + one attention layer, MoE on."""
    return reduced_config("jamba-v0.1-52b", n_layers=2, attn_every=2,
                          moe_every=2)


def _forward_smoke(cfg):
    params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(0), cfg))
    batch = _batch(cfg)
    logits, aux, _, _, npfx = tfm.forward(params, batch, cfg, REPLICATED,
                                          "train")
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s + npfx, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))


def _train_step_smoke(cfg):
    params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(0), cfg))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init(params, opt_cfg)

    @jax.jit
    def step(p, o, batch):
        (l, m), g = jax.value_and_grad(
            lambda pp: tfm.loss_fn(pp, batch, cfg, REPLICATED),
            has_aux=True)(p)
        newp, newo, _ = adamw.update(g, o, p, opt_cfg)
        return newp, newo, l

    batch = _batch(cfg)
    p1, o1, l1 = step(params, opt, batch)
    assert np.isfinite(float(l1))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p1))
    assert delta > 0


@pytest.mark.parametrize("arch", _mark_slow(ARCH_IDS, _SLOW_ARCHS))
def test_smoke_forward_shapes_no_nan(arch):
    _forward_smoke(reduced_config(arch))


@pytest.mark.parametrize("arch", _mark_slow(ARCH_IDS, _SLOW_TRAIN_ARCHS))
def test_smoke_train_step(arch):
    _train_step_smoke(reduced_config(arch))


def test_smoke_forward_hybrid_fast():
    _forward_smoke(_fast_hybrid_config())


def test_smoke_train_step_hybrid_fast():
    _train_step_smoke(_fast_hybrid_config())


@pytest.mark.parametrize("arch", _mark_slow(
    ["granite-8b", "jamba-v0.1-52b", "falcon-mamba-7b", "whisper-small"],
    _SLOW_DECODE_ARCHS))
def test_smoke_decode_matches_forward(arch):
    _decode_smoke(reduced_config(arch))


def test_smoke_decode_hybrid_fast():
    _decode_smoke(_fast_hybrid_config())


def _decode_smoke(cfg):
    params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(1), cfg))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)), jnp.int32)
    batch = {"tokens": tokens[:, :8]}
    full = {"tokens": tokens}
    for k in ("patches", "frames"):
        pass
    if cfg.family == "encdec":
        fr = jnp.asarray(rng.standard_normal((2, cfg.n_frames, cfg.d_model)),
                         jnp.float32)
        batch["frames"] = fr
        full["frames"] = fr
    _, state = tfm.prefill(params, batch, cfg, REPLICATED, cache_len=12)
    logits, _ = tfm.decode_step(params, state, tokens[:, 8], cfg, REPLICATED)
    ref = tfm.forward(params, full, cfg, REPLICATED, "train")[0][:, -1, :]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=5e-5, rtol=1e-3)


def test_all_archs_registered_with_exact_specs():
    """Pin the assigned architecture table."""
    spec = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "falcon-mamba-7b": (64, 4096, 32, 32, 0, 65024),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    }
    assert set(ARCH_IDS) == set(spec)
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    # MoE / SSM structure pins
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").dense_residual
    assert get_config("llama4-maverick-400b-a17b").top_k == 1
    assert get_config("jamba-v0.1-52b").attn_every == 8
    assert get_config("jamba-v0.1-52b").n_experts == 16
    assert get_config("falcon-mamba-7b").family == "ssm"
    assert get_config("qwen1.5-32b").qkv_bias
    assert get_config("olmo-1b").norm == "nonparametric"
    assert get_config("whisper-small").encoder_layers == 12


def test_input_specs_cover_all_cells():
    """Every non-skipped (arch x shape) cell produces well-formed abstract
    input specs; skips match DESIGN.md Arch-applicability."""
    n_cells = n_skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            n_cells += 1
            if applicable(cfg, shape):
                n_skipped += 1
                assert shape.name == "long_500k"
                assert cfg.family not in ("ssm", "hybrid")
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
    assert n_cells == 40
    assert n_skipped == 8  # all non-SSM/hybrid archs skip long_500k
