"""Serving-plan autotuner: deterministic traffic generators, traffic-profile
JSON round trip, cost-model behavior (padding-waste monotonicity, batching
and pipelining preferences, overlap-calibrated occupancy), the ``pow2_cap``
bucket-policy extension, ``apply_plan`` mid-stream hot-swap under the
injected clock, and analytic-vs-measured top-1 agreement on a simple
trace."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import PCAConfig
from repro.serving import (BucketPolicy, CostModel, PCAServer, ServingPlan,
                           ServingStats, TrafficProfile, TRACE_KINDS,
                           autotune, plan_grid, server_for_plan,
                           synthetic_trace, trace_dims)
from repro.serving.autotune import request_sequence, solve_work


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


CFG = PCAConfig(T=8, S=4, sweeps=10)


# ---------------------------------------------------------------------------
# synthetic traffic generators
# ---------------------------------------------------------------------------

def test_trace_dims_deterministic_and_bounded():
    for kind in TRACE_KINDS:
        a = trace_dims(kind, 64, lo=6, hi=48, seed=3)
        b = trace_dims(kind, 64, lo=6, hi=48, seed=3)
        assert a == b, kind                       # same seed, same stream
        assert all(6 <= d <= 48 for d in a), kind
    assert trace_dims("uniform", 64, seed=3) != trace_dims("uniform", 64,
                                                           seed=4)
    with pytest.raises(ValueError, match="unknown trace kind"):
        trace_dims("spiky", 8)


def test_trace_kinds_have_distinct_shapes():
    uniform = trace_dims("uniform", 256, lo=6, hi=48, seed=0)
    bimodal = trace_dims("bimodal", 256, lo=6, hi=48, seed=0)
    heavy = trace_dims("heavy", 256, lo=6, hi=48, seed=0)
    # bimodal: two modes at the ends, a hole in the middle
    mid = [d for d in bimodal if 18 <= d <= 36]
    assert len(mid) < len(bimodal) * 0.2
    assert any(d <= 12 for d in bimodal) and any(d >= 40 for d in bimodal)
    # heavy: mass near lo with a long tail
    assert float(np.median(heavy)) <= 12
    assert max(heavy) >= 30
    # uniform: spread across the whole range
    assert float(np.std(uniform)) > float(np.std(heavy))


def test_synthetic_trace_matrices():
    eigh = synthetic_trace("uniform", 8, op="eigh", lo=6, hi=12, seed=0)
    assert all(m.shape[0] == m.shape[1] for m in eigh)
    assert all(np.allclose(m, m.T) for m in eigh)
    svd = synthetic_trace("uniform", 8, op="svd", lo=6, hi=12, seed=0)
    assert all(m.shape[0] == 4 * m.shape[1] for m in svd)
    again = synthetic_trace("uniform", 8, op="eigh", lo=6, hi=12, seed=0)
    for a, b in zip(eigh, again):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# pow2_cap bucket policy
# ---------------------------------------------------------------------------

def test_bucket_policy_pow2_cap():
    pol = BucketPolicy(T=16, mode="pow2", pow2_cap=64)
    assert pol.bucket_dim(16) == 16
    assert pol.bucket_dim(33) == 64            # geometric below the cap
    assert pol.bucket_dim(65) == 80            # linear beyond it (5 tiles)
    assert pol.bucket_dim(70) == 80
    # capped growth is still monotone across the crossover
    dims = [pol.bucket_dim(n) for n in range(1, 200)]
    assert dims == sorted(dims)
    assert all(d >= n for n, d in enumerate(dims, start=1))


def test_bucket_policy_pow2_cap_validation():
    with pytest.raises(ValueError, match="only applies to the pow2"):
        BucketPolicy(T=16, mode="tile", pow2_cap=64)
    with pytest.raises(ValueError, match="multiple of T"):
        BucketPolicy(T=16, mode="pow2", pow2_cap=40)
    with pytest.raises(ValueError, match="multiple of T"):
        BucketPolicy(T=16, mode="pow2", pow2_cap=8)


def test_plan_grid_skips_invalid_caps():
    grid = plan_grid(modes=("tile", "pow2"), tiles=(8, 16),
                     pow2_caps=(None, 32, 40), batches=(4,),
                     inflights=(1,))
    assert all(p.pow2_cap is None for p in grid if p.mode == "tile")
    caps16 = {p.pow2_cap for p in grid if p.mode == "pow2" and p.T == 16}
    assert caps16 == {None, 32}                # 40 % 16 != 0 -> skipped
    caps8 = {p.pow2_cap for p in grid if p.mode == "pow2" and p.T == 8}
    assert caps8 == {None, 32, 40}
    for p in grid:
        p.policy()                             # every grid point is valid


# ---------------------------------------------------------------------------
# profile capture + JSON round trip
# ---------------------------------------------------------------------------

def test_profile_round_trip_through_json(tmp_path):
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=10.0)
    mats = [_sym(n, seed=n) for n in (5, 9, 12, 7)]
    srv.solve_many(mats)
    srv.solve_many(mats)                       # second pass: cache hits
    profile = TrafficProfile.from_stats(srv.stats,
                                        captured=srv.describe_plan())
    assert profile.requests == 8
    assert profile.flushes >= 2
    assert profile.work_dispatched > 0         # flush op/bucket enrichment
    assert profile.mean_dispatch_miss_s > profile.mean_dispatch_hit_s > 0
    assert profile.captured_plan["T"] == 8
    assert TrafficProfile.from_json(profile.to_json()) == profile
    path = tmp_path / "profile.json"
    profile.save(path)
    assert TrafficProfile.load(path) == profile
    json.loads(profile.to_json())              # valid JSON, not just repr


def test_profile_of_idle_server_is_well_defined():
    stats = ServingStats()
    profile = TrafficProfile.from_stats(stats)
    assert profile.requests == 0 and profile.shape_counts == ()
    assert profile.arrival_rate == 0.0 and profile.overlap_frac == 0.0
    assert TrafficProfile.from_json(profile.to_json()) == profile
    # and the underlying summary is explicit zeros, never NaN
    summary = stats.summary()
    for key, val in summary.items():
        assert np.isfinite(val), (key, val)
    assert summary["latency_p50_ms"] == 0.0
    assert summary["latency_p99_ms"] == 0.0
    assert summary["queue_p50_ms"] == 0.0
    assert summary["requests_per_s"] == 0.0


def test_request_sequence_is_deterministic_shuffle():
    profile = TrafficProfile.from_shapes(
        [("eigh", (8, 8), 3), ("svd", (16, 4), 2)])
    seq = request_sequence(profile, seed=1)
    assert seq == request_sequence(profile, seed=1)
    assert len(seq) == 5
    assert sorted(seq) == [("eigh", (8, 8))] * 3 + [("svd", (16, 4))] * 2


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_padding_waste_monotone():
    """More padding waste under the same plan -> strictly worse score."""
    plan = ServingPlan(mode="tile", T=16, max_batch=4)
    snug = TrafficProfile.from_shapes([("eigh", (16, 16), 32)])
    wasteful = TrafficProfile.from_shapes([("eigh", (17, 17), 32)])
    model = CostModel()
    c_snug = model.plan_cost(plan, snug)
    c_waste = model.plan_cost(plan, wasteful)
    assert c_waste["est_padding_waste"] > c_snug["est_padding_waste"]
    assert c_waste["total_s"] > c_snug["total_s"]


def test_cost_model_prefers_batching_on_homogeneous_traffic():
    profile = TrafficProfile.from_shapes([("eigh", (16, 16), 64)])
    model = CostModel()
    one = model.plan_cost(ServingPlan(T=16, max_batch=1), profile)
    eight = model.plan_cost(ServingPlan(T=16, max_batch=8), profile)
    assert eight["total_s"] < one["total_s"]


def test_cost_model_credits_pipelining():
    profile = TrafficProfile.from_shapes([("eigh", (16, 16), 64)])
    model = CostModel()
    sync = model.plan_cost(ServingPlan(T=16, max_batch=4,
                                       max_inflight=1), profile)
    deep = model.plan_cost(ServingPlan(T=16, max_batch=4,
                                       max_inflight=4), profile)
    assert sync["hidden_s"] == 0.0
    assert deep["hidden_s"] > 0.0
    assert deep["total_s"] < sync["total_s"]


def test_cost_model_occupancy_calibrates_from_measured_overlap():
    """A profile captured under a pipelined plan that only reached half its
    theoretical overlap scales the candidate's occupancy down too."""
    ideal = TrafficProfile.from_shapes(
        [("eigh", (16, 16), 16)],
        captured={"max_inflight": 4}, overlap_frac=0.75)
    poor = dataclasses.replace(ideal, overlap_frac=0.375)
    model = CostModel()
    plan = ServingPlan(T=16, max_batch=4, max_inflight=4)
    assert model.occupancy(plan, ideal) == pytest.approx(0.75)
    assert model.occupancy(plan, poor) == pytest.approx(0.375)
    assert model.occupancy(ServingPlan(T=16, max_inflight=1), ideal) == 0.0


def test_cost_model_charges_bucket_fragmentation():
    """A tiny tile shatters heterogeneous traffic into many executables;
    the compile term must bite."""
    shapes = [("eigh", (d, d), 4) for d in (6, 14, 22, 30, 38, 46)]
    profile = TrafficProfile.from_shapes(shapes)
    model = CostModel()
    fine = model.plan_cost(ServingPlan(mode="tile", T=8, max_batch=4),
                           profile)
    coarse = model.plan_cost(ServingPlan(mode="pow2", T=16, max_batch=4),
                             profile)
    assert fine["n_buckets"] > coarse["n_buckets"]
    assert fine["compile_s"] > coarse["compile_s"]


def test_cost_model_calibrates_from_profile():
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=10.0)
    mats = [_sym(9, seed=i) for i in range(8)]
    srv.solve_many(mats)
    srv.solve_many(mats)
    profile = TrafficProfile.from_stats(srv.stats,
                                        captured=srv.describe_plan())
    model = CostModel.calibrated(profile)
    default = CostModel()
    # compile cost comes from the measured hit/miss dispatch split
    assert model.compile_s_per_executable != pytest.approx(
        default.compile_s_per_executable)
    assert model.device_work_per_s == pytest.approx(
        profile.work_dispatched / profile.device_s)


def test_solve_work_scales():
    assert solve_work("eigh", (32, 32)) == 32.0 ** 3
    assert solve_work("svd", (64, 16)) == 64 * 16 ** 2 + 16 ** 3
    assert solve_work("pca", (64, 16)) > solve_work("eigh", (16, 16))


# ---------------------------------------------------------------------------
# apply_plan hot-swap
# ---------------------------------------------------------------------------

def test_apply_plan_midstream_preserves_inflight_and_queued_tickets():
    """The swap drains in-flight flushes, re-buckets queued tickets in
    place, and dispatches any queue the new (smaller) batch cap considers
    full -- all under the injected clock, so every step is deterministic."""
    t = [0.0]
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=0.5,
                    clock=lambda: t[0], max_inflight=2, max_batch=4)
    flying = [srv.submit(_sym(6, seed=i)) for i in range(4)]  # full flush
    assert all(tk.inflight and not tk.done for tk in flying)
    queued = [srv.submit(_sym(11, seed=10 + i)) for i in range(2)]
    assert all(tk.bucket == (16, 16) for tk in queued)
    switch = srv.apply_plan(ServingPlan(mode="pow2", T=4, pow2_cap=16,
                                        max_batch=2, max_inflight=1))
    # in-flight work retired first: those tickets are done, under the old
    # plan's buckets
    assert all(tk.done for tk in flying)
    # queued tickets were re-bucketed in place (pow2 T=4: 11 -> 16) and the
    # new max_batch=2 made their queue full, so they dispatched at once
    assert switch["requeued"] == 2
    assert all(tk.done and tk.bucket == (16, 16) for tk in queued)
    assert srv.pending() == 0 and srv.inflight() == 0
    for i, tk in enumerate(flying):
        ref = np.linalg.eigh(_sym(6, seed=i))[0][::-1]
        np.testing.assert_allclose(tk.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)
    for i, tk in enumerate(queued):
        ref = np.linalg.eigh(_sym(11, seed=10 + i))[0][::-1]
        np.testing.assert_allclose(tk.result().eigenvalues, ref,
                                   rtol=1e-3, atol=1e-3)
    # the switch is on the record: old plan, new plan, requeue count
    assert len(srv.stats.plan_switches) == 1
    rec = srv.stats.plan_switches[0]
    assert rec["from"]["T"] == 8 and rec["to"]["T"] == 4
    assert rec["to"]["max_batch"] == 2 and rec["requeued"] == 2
    assert srv.stats.summary()["plan_switches"] == 1


def test_apply_plan_requeue_keeps_deadlines_and_submit_order():
    t = [0.0]
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=0.5,
                    clock=lambda: t[0], max_batch=8)
    early = srv.submit(_sym(6, seed=0))
    t[0] = 0.2
    late = srv.submit(_sym(12, seed=1))
    srv.apply_plan(ServingPlan(mode="tile", T=16, max_batch=8,
                               max_inflight=1))
    # both requests now share one (16, 16) bucket queue, oldest first
    assert early.bucket == late.bucket == (16, 16)
    assert srv.pending() == 2
    t[0] = 0.45
    assert srv.poll() == 0                     # original deadlines survive
    t[0] = 0.51                                # early's deadline (0.5) fires
    assert srv.poll() == 2                     # one flush retires both
    assert early.done and late.done
    assert early.record.batch_size == 2


def test_apply_plan_validates_plan():
    srv = PCAServer(CFG, policy=BucketPolicy(T=8))
    with pytest.raises(ValueError, match="max_inflight"):
        srv.apply_plan(ServingPlan(max_inflight=0))
    with pytest.raises(ValueError, match="max_batch"):
        srv.apply_plan(ServingPlan(max_batch=0))


def test_apply_plan_failure_leaves_server_and_tickets_intact():
    """A plan that fails to materialize (bad pow2_cap, bogus mesh spec)
    must raise *before* the server mutates: queued tickets stay queued and
    the old plan stays in force."""
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=10.0,
                    max_batch=4)
    ticket = srv.submit(_sym(6))
    before = srv.describe_plan()
    with pytest.raises(ValueError, match="multiple of T"):
        srv.apply_plan(ServingPlan(mode="pow2", T=16, pow2_cap=40))
    with pytest.raises(ValueError):
        srv.apply_plan(ServingPlan(mesh="bogus"))
    assert srv.describe_plan() == before
    assert srv.pending() == 1 and not ticket.done
    srv.drain()
    assert ticket.done                     # the ticket was never orphaned
    ref = np.linalg.eigh(_sym(6))[0][::-1]
    np.testing.assert_allclose(ticket.result().eigenvalues, ref,
                               rtol=1e-3, atol=1e-3)


def test_apply_plan_realigns_config_with_cold_server():
    """A hot-swapped server must compile the executables a cold server
    built from the same plan would -- including the matmul block size
    (config.T) when the config routes through a kernel backend -- so
    hot-vs-cold results stay bit-identical even off the default datapath."""
    cfg = PCAConfig(T=16, S=4, sweeps=8, backend="ref", rotation="matmul")
    mats = [_sym(n, seed=n) for n in (5, 9, 12, 7)]
    plan = ServingPlan(mode="tile", T=8, max_batch=2, max_inflight=1)
    cold = server_for_plan(plan, cfg)
    hot = PCAServer(cfg, policy=BucketPolicy(T=16), max_delay_s=10.0)
    hot.submit(mats[0])                        # queued across the swap
    hot.apply_plan(plan)
    assert hot.config.T == 8 and hot.config.S == 2
    for g, w in zip(cold.solve_many(mats), hot.solve_many(mats)):
        for f in dataclasses.fields(g):
            np.testing.assert_array_equal(np.asarray(getattr(g, f.name)),
                                          np.asarray(getattr(w, f.name)))


def test_apply_plan_same_buckets_reuse_executables():
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=10.0)
    srv.solve_many([_sym(7, seed=i) for i in range(4)])
    misses = srv.stats.cache_misses
    # same bucketing, different pipeline depth: the (op, bucket, batch)
    # executable survives the swap
    srv.apply_plan(ServingPlan(mode="tile", T=8, max_batch=4,
                               max_inflight=2))
    srv.solve_many([_sym(7, seed=10 + i) for i in range(4)])
    assert srv.stats.cache_misses == misses


# ---------------------------------------------------------------------------
# the search driver
# ---------------------------------------------------------------------------

def test_autotune_analytic_and_measured_agree_on_simple_trace():
    """Batching a homogeneous burst beats serve-one-at-a-time both in the
    model and on the hardware: the analytic top-1 and the measured top-1
    must be the same plan."""
    mats = [_sym(9, seed=i) for i in range(16)]
    srv = PCAServer(CFG, policy=BucketPolicy(T=8), max_delay_s=10.0)
    for _ in range(2):
        srv.solve_many(mats)
    profile = TrafficProfile.from_stats(srv.stats,
                                        captured=srv.describe_plan())
    grid = [ServingPlan(mode="tile", T=8, max_batch=1),
            ServingPlan(mode="tile", T=8, max_batch=8)]
    analytic = autotune(profile, grid=grid, config=CFG)
    assert analytic.mode == "analytic"
    assert analytic.best.max_batch == 8
    measured = autotune(profile, grid=grid, config=CFG, measure_top_k=2,
                        passes=2)
    assert measured.mode == "measured"
    assert len(measured.measured) == 2
    assert measured.best == analytic.best
    json.dumps(measured.to_json())             # result is report-ready


def test_server_for_plan_matches_plan():
    plan = ServingPlan(mode="pow2", T=8, pow2_cap=32, max_batch=2,
                       max_inflight=3)
    srv = server_for_plan(plan, CFG)
    described = srv.describe_plan()
    assert described["mode"] == "pow2" and described["T"] == 8
    assert described["pow2_cap"] == 32
    assert described["max_batch"] == 2 and described["max_inflight"] == 3
    assert srv.config.sweeps == CFG.sweeps     # config carries over
