"""Beyond-paper KV-cache PCA compression: exactness in the retained
subspace, error bounds for low-rank caches, rank suggestion."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import kv_compression as kvc


def _lowrank_cache(b, s, kv, hd, r_true, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    basis = rng.standard_normal((kv, hd, r_true)).astype(np.float32)
    coef = rng.standard_normal((b, s, kv, r_true)).astype(np.float32)
    x = np.einsum("bskr,kdr->bskd", coef, basis)
    if noise:
        x = x + noise * rng.standard_normal(x.shape).astype(np.float32)
    return jnp.asarray(x)


def test_exact_for_truly_lowrank_cache():
    k = _lowrank_cache(2, 64, 4, 32, r_true=6, seed=1)
    v = _lowrank_cache(2, 64, 4, 32, r_true=6, seed=2)
    q = jnp.asarray(np.random.default_rng(3).standard_normal((2, 4, 2, 32)),
                    jnp.float32)
    err, ratio = kvc.attention_error(q, k, v,
                                     kvc.KVCompressionConfig(rank=8), 0.18)
    assert float(err) < 1e-3
    assert ratio == 8 / 32


def _rank_sweep_errs(ranks):
    k = _lowrank_cache(1, 96, 2, 32, r_true=12, seed=4, noise=0.05)
    v = _lowrank_cache(1, 96, 2, 32, r_true=12, seed=5, noise=0.05)
    q = jnp.asarray(np.random.default_rng(6).standard_normal((1, 2, 3, 32)),
                    jnp.float32)
    errs = []
    for r in ranks:
        e, _ = kvc.attention_error(q, k, v,
                                   kvc.KVCompressionConfig(rank=r), 0.18)
        errs.append(float(e))
    assert errs[-1] < 1e-3              # full rank = exact
    assert all(b <= a + 1e-6 for a, b in zip(errs, errs[1:]))


def test_error_decreases_with_rank_fast():
    _rank_sweep_errs((2, 32))


@pytest.mark.slow
def test_error_decreases_with_rank():
    _rank_sweep_errs((2, 8, 16, 32))


def test_suggest_rank_finds_true_rank():
    k = _lowrank_cache(2, 128, 3, 32, r_true=5, seed=7)
    r = kvc.suggest_rank(k, coverage=0.999)
    assert 4 <= r <= 7
