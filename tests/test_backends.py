"""Backend-dispatch registry: golden interpret-vs-ref parity for every
registered op, resolution-order semantics, and per-bucket backend routing in
``PCAServer`` (distinct backend-qualified cache entries, identical results)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import backends
from repro.core import PCAConfig
from repro.kernels import ops
from repro.serving import BucketPolicy, PCAServer, threshold_router

# what the registry's auto rule resolves to on THIS host (pallas on TPU,
# interpret elsewhere) -- keeps these tests green on both host kinds
_AUTO = "pallas" if jax.default_backend() == "tpu" else "interpret"


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------

def test_registry_lists_every_op_and_backend():
    assert set(backends.registered_ops()) == {
        "mm_engine_matmul", "dle_find_pivot", "cordic_rotate",
        "flash_attention", "mamba_scan", "covariance", "jacobi_sweep"}
    for op in backends.registered_ops():
        assert backends.backends_for(op) == ("pallas", "interpret", "ref")


def test_registry_rejects_unknown_names():
    with pytest.raises(KeyError):
        backends.resolve("no_such_op")
    with pytest.raises(ValueError):
        backends.resolve("mm_engine_matmul", "hls")
    with pytest.raises(ValueError):
        backends.set_default_backend("hls")


def test_default_backend_resolution_order(monkeypatch):
    # auto: per host
    monkeypatch.delenv(backends.ENV_VAR, raising=False)
    assert backends.default_backend() == _AUTO
    # env escape hatch
    monkeypatch.setenv(backends.ENV_VAR, "ref")
    assert backends.default_backend() == "ref"
    # process default beats env
    backends.set_default_backend("interpret")
    try:
        assert backends.default_backend() == "interpret"
        # scoped override beats process default
        with backends.use_backend("ref"):
            assert backends.default_backend() == "ref"
        assert backends.default_backend() == "interpret"
    finally:
        backends.set_default_backend(None)
    assert backends.default_backend() == "ref"


def test_use_backend_reroutes_ops(monkeypatch):
    """The scoped override must change what ops.* actually run."""
    calls = []
    real = backends.resolve("mm_engine_matmul", "ref")

    def spy(a, b, **kw):
        calls.append("ref")
        return real(a, b, **kw)

    monkeypatch.setitem(
        backends.registry._REGISTRY["mm_engine_matmul"], "ref", spy)
    # distinctive shape/block so the jit trace (where resolve() runs) is
    # fresh and the spy is actually reached
    a = jnp.ones((3, 5), jnp.float32)
    b = jnp.ones((5, 4), jnp.float32)
    with backends.use_backend("ref"):
        ops.mm_engine_matmul(a, b, block=8)
    assert calls  # the spy ran -> dispatch honoured the context


# ---------------------------------------------------------------------------
# golden parity: interpret vs ref for every registered op
# ---------------------------------------------------------------------------

def _mm_inputs():
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.standard_normal((37, 21)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((21, 19)), jnp.float32)
    return (a, b), dict(block=16)


def _dle_inputs():
    rng = np.random.default_rng(43)
    c = rng.standard_normal((26, 26)).astype(np.float32)
    c = c + c.T
    return (jnp.asarray(c),), dict(tile=16)


def _cordic_inputs():
    rng = np.random.default_rng(44)
    k = 33
    return (jnp.asarray(rng.uniform(-3, 3, k), jnp.float32),
            jnp.asarray(rng.uniform(-3, 3, k), jnp.float32),
            jnp.asarray(rng.uniform(-3, 3, k), jnp.float32)), dict(block=16)


def _fa_inputs():
    rng = np.random.default_rng(45)
    q = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    return (q, k, v), dict(causal=True, block_q=16, block_k=16)


def _ms_inputs():
    rng = np.random.default_rng(46)
    b, l, d, n = 2, 24, 8, 4
    return (jnp.asarray(rng.standard_normal((b, l, d)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.2, (b, l, d)), jnp.float32),
            jnp.asarray(-rng.uniform(0.5, 2, (d, n)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32),
            jnp.asarray(rng.standard_normal((d,)), jnp.float32)), \
        dict(chunk=8)


def _cov_inputs():
    rng = np.random.default_rng(47)
    x = jnp.asarray(rng.standard_normal((45, 18)), jnp.float32)
    return (x,), dict(block_m=16)


def _sweep_inputs():
    rng = np.random.default_rng(48)
    n = 12
    a = rng.standard_normal((n, n)).astype(np.float32)
    c = jnp.asarray((a + a.T) / 2)
    v = jnp.eye(n, dtype=jnp.float32)
    pairs = jnp.asarray([[0, 1], [2, 5], [4, 9], [6, 11]], jnp.int32)
    return (c, v, pairs), {}


# per-op (wrapper, inputs, tolerance): the CORDIC tolerance covers its
# Q2.29 fixed-point angle quantisation vs the float-exact reference
_PARITY_CASES = {
    "mm_engine_matmul": (ops.mm_engine_matmul, _mm_inputs, 1e-5),
    "dle_find_pivot": (ops.dle_find_pivot, _dle_inputs, 0.0),
    "cordic_rotate": (ops.cordic_rotation_params, _cordic_inputs, 3e-7),
    "flash_attention": (ops.flash_attention, _fa_inputs, 2e-5),
    "mamba_scan": (ops.mamba_scan, _ms_inputs, 1e-4),
    "covariance": (ops.covariance, _cov_inputs, 2e-5),
    "jacobi_sweep": (ops.jacobi_sweep, _sweep_inputs, 0.0),
}


def test_every_registered_op_has_a_parity_case():
    assert set(_PARITY_CASES) == set(backends.registered_ops())


@pytest.mark.parametrize("op", sorted(_PARITY_CASES))
def test_interpret_matches_ref(op):
    fn, make_inputs, tol = _PARITY_CASES[op]
    args, kw = make_inputs()
    got = fn(*args, backend="interpret", **kw)
    want = fn(*args, backend="ref", **kw)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# PCAConfig backend plumbing
# ---------------------------------------------------------------------------

def test_pca_config_backend_names_matmul_fn():
    assert PCAConfig().matmul_fn() is None
    assert not PCAConfig().use_pallas
    cfg = PCAConfig(T=16, backend="interpret")
    mm = cfg.matmul_fn()
    rng = np.random.default_rng(47)
    a = jnp.asarray(rng.standard_normal((9, 7)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((7, 5)), jnp.float32)
    np.testing.assert_allclose(np.asarray(mm(a, b)),
                               np.asarray(a) @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)
    assert PCAConfig(backend="pallas").use_pallas


# ---------------------------------------------------------------------------
# per-bucket backend routing in PCAServer
# ---------------------------------------------------------------------------

def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _routed_server():
    # small bucket (8, 8) -> plain XLA; large bucket (24, 24) -> the Pallas
    # MM-Engine under the interpreter (the CPU-runnable stand-in for the
    # compiled "pallas" backend)
    return PCAServer(PCAConfig(T=8, S=2, sweeps=14),
                     policy=BucketPolicy(T=8), max_delay_s=1e9,
                     backend_router=threshold_router(
                         16, large="interpret", small=None))


def test_threshold_router_boundaries():
    route = threshold_router(16, large="pallas", small="ref")
    assert route("eigh", (8, 8)) == "ref"
    assert route("eigh", (16, 16)) == "pallas"
    assert route("svd", (24, 8)) == "pallas"
    # default large="auto" resolves per host, so threshold_router(n) is
    # safe on any machine
    assert threshold_router(16)("eigh", (16, 16)) == _AUTO
    assert threshold_router(16)("eigh", (8, 8)) is None
    assert set(backends.available()) >= {"interpret", "ref"}
    assert ("pallas" in backends.available()) == (_AUTO == "pallas")


def test_threshold_router_resolves_auto_once_at_construction(monkeypatch):
    """The "auto" sentinel is resolved when the router is built, not on
    every route call -- and telemetry therefore only ever sees the
    concrete backend name."""
    calls = []
    orig = backends.default_backend

    def counting_default():
        calls.append(1)
        return orig()

    monkeypatch.setattr(backends, "default_backend", counting_default)
    route = threshold_router(16)            # large="auto"
    assert calls == [1]                     # resolved exactly once, eagerly
    for n in (8, 16, 24, 32):
        assert route("eigh", (n, n)) != "auto"
    assert calls == [1]                     # ...and never again per route

    srv = PCAServer(PCAConfig(T=8, S=2, sweeps=14), policy=BucketPolicy(T=8),
                    max_delay_s=1e9,
                    backend_router=threshold_router(16, large="auto",
                                                    small="ref"))
    srv.solve_many([_sym(20, seed=5), _sym(20, seed=6)], op="eigh")
    recorded = {r.backend for r in srv.stats.records}
    assert recorded == {_AUTO}              # the concrete name, no sentinel


def test_server_routes_buckets_to_different_backends():
    srv = _routed_server()
    mats = [_sym(6, seed=1), _sym(6, seed=2), _sym(20, seed=3),
            _sym(20, seed=4)]
    results = srv.solve_many(mats, op="eigh")
    for m, r in zip(mats, results):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    routed = {(r.bucket, r.backend) for r in srv.stats.records}
    assert routed == {((8, 8), None), ((24, 24), "interpret")}
    # distinct backend-qualified cache entries, one per bucket
    assert len(srv._cache) == 2
    assert {k[3].backend for k in srv._cache} == {None, "interpret"}


def test_routed_backends_agree_with_unrouted_server():
    """Backend choice must not change results: the routed server and an
    all-XLA server agree bitwise-tightly on the same traffic."""
    mats = [_sym(20, seed=7), _sym(20, seed=8)]
    routed = _routed_server().solve_many(mats, op="eigh")
    plain = PCAServer(PCAConfig(T=8, S=2, sweeps=14),
                      policy=BucketPolicy(T=8),
                      max_delay_s=1e9).solve_many(mats, op="eigh")
    for a, b in zip(routed, plain):
        np.testing.assert_allclose(a.eigenvalues, b.eigenvalues,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.abs(a.eigenvectors),
                                   np.abs(b.eigenvectors), atol=1e-4)


def test_same_bucket_two_backends_two_cache_entries():
    """Flipping the router between runs must MISS the cache (the key is
    backend-qualified), not silently reuse the other backend's executable."""
    srv = _routed_server()
    srv.solve_many([_sym(20, seed=1), _sym(20, seed=2)], op="eigh")
    assert srv.stats.cache_misses == 1
    srv.backend_router = threshold_router(16, large=None, small=None)
    srv.solve_many([_sym(20, seed=3), _sym(20, seed=4)], op="eigh")
    assert srv.stats.cache_misses == 2 and len(srv._cache) == 2
