"""Observability stack: span tracing (lifecycle, parentage, ring bounds,
Chrome-schema export/validation), the metric registry (histogram
percentiles vs numpy, Prometheus golden text, windowed snapshots under an
injected clock), SLO accounting (goodput math on a crafted burst, deadline
misses offline and online), and the serving integration contract: a traced
server is bitwise identical to an untraced one, every request span parents
to its flush span, and the instrumented hot path stays within 3% of bare
throughput (slow tier)."""
import dataclasses
import json
import time

import numpy as np
import pytest

from repro.core import PCAConfig
from repro.obs import (DEFAULT_BUCKETS, MetricRegistry, Observability,
                       SLOTracker, Tracer, histogram_quantile,
                       slo_from_records, validate_trace)
from repro.serving import BucketPolicy, PCAServer
from repro.serving.autotune import ServingPlan, TrafficProfile, autotune
from repro.serving.stats import RequestRecord, ServingStats


class ManualClock:
    """Injectable monotonic clock driven by the test."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_span_lifecycle_and_parentage():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    parent = tr.begin("flush", cat="flush", track="flushes", op="eigh")
    clock.advance(0.5)
    child = tr.begin("wait", track="flushes", parent=parent.id)
    clock.advance(0.25)
    child.end()
    parent.end()
    assert len(tr) == 2
    by_name = {s.name: s for s in tr.spans}
    assert by_name["wait"].parent == by_name["flush"].id
    assert by_name["flush"].ts == 0.0
    assert by_name["flush"].dur == pytest.approx(0.75)
    assert by_name["wait"].ts == pytest.approx(0.5)
    assert dict(by_name["flush"].args)["op"] == "eigh"
    # double-end is a no-op, not a duplicate span
    assert parent.end() is None
    assert len(tr) == 2


def test_complete_and_reserved_ids():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    fid = tr.new_id()
    # child recorded before its parent (the engine does exactly this:
    # compile spans land at dispatch, the flush span lands at retire)
    tr.complete("compile", ts=0.0, end=0.1, parent=fid, track="flushes")
    tr.complete("flush", ts=0.0, end=1.0, id=fid, track="flushes")
    doc = tr.export()
    assert validate_trace(doc) == []
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    compile_ev = next(e for e in xs if e["name"] == "compile")
    assert compile_ev["args"]["parent"] == fid


def test_ring_buffer_bounds_and_dropped_counter():
    tr = Tracer(capacity=8, clock=ManualClock())
    for i in range(20):
        tr.complete(f"s{i}", ts=float(i), end=float(i) + 0.5)
    assert len(tr) == 8
    assert tr.dropped == 12
    assert [s.name for s in tr.spans] == [f"s{i}" for i in range(12, 20)]
    doc = tr.export()
    assert doc["otherData"]["dropped"] == 12
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False, clock=ManualClock())
    h = tr.begin("x")
    assert h.end() is None
    assert tr.complete("y", ts=0.0, end=1.0) is None
    assert tr.instant("z") is None
    assert len(tr) == 0


def test_export_lane_allocation_for_overlapping_roots():
    """Two concurrent root spans of one track must land on different tids
    (side-by-side lanes), a later non-overlapping span reuses lane 0, and
    a child rides its parent's lane so the flame nests."""
    tr = Tracer(clock=ManualClock())
    a = tr.complete("a", ts=0.0, end=2.0, track="flushes")
    tr.complete("b", ts=1.0, end=3.0, track="flushes")       # overlaps a
    tr.complete("c", ts=4.0, end=5.0, track="flushes")       # after both
    tr.complete("a.child", ts=0.5, end=1.5, track="flushes", parent=a.id)
    doc = tr.export()
    assert validate_trace(doc) == []
    tid = {e["name"]: e["tid"] for e in doc["traceEvents"]
           if e["ph"] == "X"}
    assert tid["a"] != tid["b"]
    assert tid["c"] == tid["a"]
    assert tid["a.child"] == tid["a"]


def test_validate_trace_catches_violations():
    ok = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 5, "pid": 0, "tid": 1},
    ]}
    assert validate_trace(ok) == []
    assert validate_trace({"traceEvents": []})
    # missing required key
    assert any("missing required key" in e for e in validate_trace(
        {"traceEvents": [{"name": "a", "ph": "X", "ts": 0, "pid": 0}]}))
    # decreasing timestamps
    assert any("non-decreasing" in e for e in validate_trace(
        {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 5, "dur": 1, "pid": 0, "tid": 1},
            {"name": "b", "ph": "X", "ts": 1, "dur": 1, "pid": 0, "tid": 1},
        ]}))
    # X without dur
    assert any("dur" in e for e in validate_trace(
        {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "pid": 0, "tid": 1}]}))
    # unmatched B
    assert any("unmatched B" in e for e in validate_trace(
        {"traceEvents": [
            {"name": "a", "ph": "B", "ts": 0, "pid": 0, "tid": 1}]}))
    # parent id that is not in the trace
    assert any("not in trace" in e for e in validate_trace(
        {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": 1, "pid": 0, "tid": 1,
             "id": 7, "args": {"parent": 99}}]}))
    # child ends after its parent
    assert any("after its parent" in e for e in validate_trace(
        {"traceEvents": [
            {"name": "p", "ph": "X", "ts": 0, "dur": 5, "pid": 0, "tid": 1,
             "id": 1},
            {"name": "c", "ph": "X", "ts": 0, "dur": 50, "pid": 0, "tid": 2,
             "id": 2, "args": {"parent": 1}}]}))


def test_trace_save_roundtrip(tmp_path):
    tr = Tracer(clock=ManualClock())
    tr.complete("a", ts=0.0, end=1.0)
    path = tr.save(tmp_path / "trace.json")
    doc = json.loads(path.read_text())
    assert validate_trace(doc) == []
    assert doc["displayTimeUnit"] == "ms"
    # Chrome/Perfetto metadata present
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= names


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_histogram_percentiles_vs_numpy():
    """Bucket-interpolated quantiles must agree with numpy to within one
    bucket width on a smooth sample."""
    clock = ManualClock()
    reg = MetricRegistry(clock=clock)
    fam = reg.histogram("lat_seconds", "x", ("op",))
    child = fam.labels(op="eigh")
    rng = np.random.default_rng(0)
    vals = rng.gamma(2.0, 0.005, size=4000)    # latency-ish, ~5-20ms
    for v in vals:
        child.observe(float(v), now=clock.advance(1e-4))
    uppers = list(child.uppers)
    for p in (50, 90, 99):
        got = child.percentile(p)
        want = float(np.percentile(vals, p))
        i = next(i for i, hi in enumerate(uppers) if want <= hi)
        lo = uppers[i - 1] if i else 0.0
        assert lo - 1e-12 <= got <= uppers[i] + 1e-12, (p, got, want)


def test_histogram_quantile_edges():
    assert np.isnan(histogram_quantile(0.5, (1.0, 2.0), [0, 0, 0]))
    # all mass in the overflow bucket clamps to the last finite upper
    assert histogram_quantile(0.5, (1.0, 2.0), [0, 0, 10]) == 2.0
    # interpolation inside one bucket
    got = histogram_quantile(0.5, (1.0, 2.0), [0, 10, 0])
    assert got == pytest.approx(1.5)


def test_prometheus_golden_output():
    clock = ManualClock()
    reg = MetricRegistry(clock=clock)
    reg.counter("req_total", "Requests.", ("op",)).labels(op="eigh").inc(
        3, now=1.0)
    reg.gauge("depth", "Depth.").labels().set(2, now=1.0)
    h = reg.histogram("lat", "Latency.", ("op",), buckets=(0.1, 1.0))
    c = h.labels(op="eigh")
    c.observe(0.05, now=1.0)
    c.observe(0.5, now=2.0)
    c.observe(5.0, now=3.0)
    assert reg.to_prometheus() == """\
# HELP depth Depth.
# TYPE depth gauge
depth 2
# HELP lat Latency.
# TYPE lat histogram
lat_bucket{op="eigh",le="0.1"} 1
lat_bucket{op="eigh",le="1"} 2
lat_bucket{op="eigh",le="+Inf"} 3
lat_sum{op="eigh"} 5.55
lat_count{op="eigh"} 3
# HELP req_total Requests.
# TYPE req_total counter
req_total{op="eigh"} 3
"""


def test_windowed_snapshot_under_injected_clock():
    clock = ManualClock()
    reg = MetricRegistry(clock=clock)
    ctr = reg.counter("req_total", labels=("op",)).labels(op="eigh")
    h = reg.histogram("lat", labels=()).labels()
    # old traffic: 10 requests of 1ms at t in [0, 10)
    for i in range(10):
        clock.t = float(i)
        ctr.inc()
        h.observe(1e-3)
    # recent traffic: 5 requests of 100ms at t in [100, 105)
    for i in range(5):
        clock.t = 100.0 + i
        ctr.inc()
        h.observe(0.1)
    clock.t = 105.0
    snap = reg.snapshot(window_s=10.0)
    c = snap["series"]["req_total"]["children"]["eigh"]
    assert c["total"] == 15 and c["delta"] == 5
    assert c["rate_per_s"] == pytest.approx(0.5)
    hs = snap["series"]["lat"]["children"][""]
    assert hs["count"] == 5 and hs["lifetime_count"] == 15
    # the windowed p50 sits in the 100ms bucket, not the 1ms one
    assert hs["p50"] > 5e-2
    life = reg.snapshot()
    assert life["series"]["lat"]["children"][""]["count"] == 15
    # windowed percentile readout straight off the child agrees
    assert h.percentile(50, window_s=10.0) > 5e-2
    assert h.percentile(50) < 5e-2        # lifetime p50 is the 1ms mode


def test_registry_family_idempotence_and_mismatch():
    reg = MetricRegistry(clock=ManualClock())
    a = reg.counter("x_total", "x", ("op",))
    assert reg.counter("x_total", "x", ("op",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total", "x", ("op",))
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", "x", ("op", "bucket"))
    h = reg.histogram("h", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="buckets"):
        reg.histogram("h", buckets=(1.0, 5.0))
    with pytest.raises(ValueError, match="expected labels"):
        a.labels("eigh", "extra")


def test_to_json_is_nan_free():
    reg = MetricRegistry(clock=ManualClock())
    reg.histogram("empty", labels=()).labels()   # no observations -> NaN p50
    doc = reg.to_json()
    assert doc["series"]["empty"]["children"][""]["p50"] is None
    json.dumps(doc)                              # JSON-clean by contract


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------

def test_slo_goodput_on_crafted_burst():
    """10 requests over a 10s span, alternating 10ms / 200ms latency,
    SLO=50ms: 5 compliant -> goodput 0.5 rps, throughput 1 rps."""
    clock = ManualClock()
    reg = MetricRegistry(clock=clock)
    slo = SLOTracker(slo_s=0.05, registry=reg, clock=clock)
    for i in range(10):
        lat = 0.01 if i % 2 == 0 else 0.2
        t_done = float(i + 1)
        slo.observe(op="eigh", latency_s=lat, t_done=t_done,
                    t_submit=t_done - 1.0 if i == 0 else None,
                    deadline=t_done + (1.0 if i < 8 else -1.0))
    s = slo.summary()
    assert s["requests"] == 10 and s["compliant"] == 5
    assert s["slo_miss_count"] == 5 and s["slo_miss_frac"] == 0.5
    assert s["deadline_miss_count"] == 2
    assert s["goodput_rps"] == pytest.approx(0.5)
    assert s["throughput_rps"] == pytest.approx(1.0)
    # mirrored registry counters agree with the summary
    prom = reg.to_prometheus()
    assert 'slo_requests_total{op="eigh"} 10' in prom
    assert 'slo_miss_total{op="eigh"} 5' in prom
    assert 'deadline_miss_total{op="eigh"} 2' in prom
    # trailing window: only the last 3 fulfils (t_done >= 8)
    clock.t = 11.0
    w = slo.summary(window_s=3.0)
    assert w["requests"] == 3
    assert w["goodput_rps"] == pytest.approx(w["compliant"] / 3.0)


def test_slo_none_means_throughput_equals_goodput():
    slo = SLOTracker(slo_s=None, clock=ManualClock())
    for i in range(4):
        slo.observe(op="svd", latency_s=10.0, t_done=float(i + 1))
    s = slo.summary()
    assert s["slo_miss_count"] == 0
    assert s["goodput_rps"] == s["throughput_rps"]
    with pytest.raises(ValueError):
        SLOTracker(slo_s=-1.0)


def test_slo_from_records_offline():
    recs = [
        RequestRecord(rid=i, op="eigh", shape=(8, 8), bucket=(8, 8),
                      batch_size=4, cache_hit=True, t_submit=float(i),
                      t_done=float(i) + lat, queue_s=0.0, padding_waste=0.0,
                      deadline=float(i) + 0.05)
        for i, lat in enumerate((0.01, 0.02, 0.10, 0.01))
    ]
    s = slo_from_records(recs, slo_s=0.05)
    assert s["requests"] == 4 and s["slo_miss_count"] == 1
    assert s["deadline_miss_count"] == 1          # the 100ms one
    # records without a deadline field never count as deadline misses
    legacy = [dataclasses.replace(r, deadline=float("inf")) for r in recs]
    assert slo_from_records(legacy, slo_s=None)["deadline_miss_count"] == 0
    assert slo_from_records([], slo_s=0.05)["goodput_rps"] == 0.0


def test_serving_stats_summary_counts_deadline_misses():
    clock = ManualClock()
    stats = ServingStats(clock=clock)
    for i, (t_done, deadline) in enumerate(
            ((1.0, 2.0), (2.0, 1.5), (3.0, 2.0))):
        stats.record_request(RequestRecord(
            rid=i, op="eigh", shape=(8, 8), bucket=(8, 8), batch_size=1,
            cache_hit=True, t_submit=0.0, t_done=t_done, queue_s=0.0,
            padding_waste=0.0, deadline=deadline))
    s = stats.summary()
    assert s["deadline_miss_count"] == 2
    assert s["deadline_miss_frac"] == pytest.approx(2 / 3)


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def _mixed_burst(seed=0):
    rng = np.random.default_rng(seed)
    mats = []
    for n in (5, 9, 12, 7, 11, 6, 10, 8):
        a = rng.standard_normal((n, n)).astype(np.float32)
        mats.append((a + a.T) / 2)
    return mats


def test_traced_server_bitwise_identical_to_untraced():
    cfg = PCAConfig(T=8, S=4, sweeps=6)
    mats = _mixed_burst()
    bare = PCAServer(cfg, policy=BucketPolicy(T=8), max_delay_s=10.0,
                     max_inflight=2)
    obs = Observability.enabled(slo_ms=1000.0)
    traced = PCAServer(cfg, policy=BucketPolicy(T=8), max_delay_s=10.0,
                       max_inflight=2, obs=obs, clock=obs.clock)
    for g, w in zip(traced.solve_many(mats, op="eigh"),
                    bare.solve_many(mats, op="eigh")):
        for field in (f.name for f in dataclasses.fields(g)):
            np.testing.assert_array_equal(np.asarray(getattr(g, field)),
                                          np.asarray(getattr(w, field)))
    assert len(obs.tracer) > 0
    assert obs.summary()["slo"]["requests"] == len(mats)


def test_request_spans_parent_to_flush_spans():
    obs = Observability.enabled(slo_ms=1000.0)
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=6), policy=BucketPolicy(T=8),
                    max_delay_s=10.0, obs=obs, clock=obs.clock)
    mats = _mixed_burst()
    srv.solve_many(mats, op="eigh")
    doc = obs.trace_doc()
    assert validate_trace(doc) == []
    xs = {e["id"]: e for e in doc["traceEvents"]
          if e.get("ph") == "X" and isinstance(e.get("id"), int)}
    requests = [e for e in xs.values() if e["name"] == "request:eigh"]
    flushes = [e for e in xs.values() if e["name"] == "flush:eigh"]
    assert len(requests) == len(mats)
    assert len(flushes) == srv.stats.flushes
    for e in requests:
        parent = xs[e["args"]["parent"]]
        assert parent["name"] == "flush:eigh"
    # flush children cover the whole stage pipeline, incl. the compile
    # span every cache-miss flush records
    child_names = {e["name"] for e in xs.values()
                   if e["args"].get("parent") in {f["id"] for f in flushes}}
    assert {"dispatch", "inflight", "wait", "retire",
            "compile"} <= child_names


def test_serving_metrics_and_backend_collector():
    obs = Observability.enabled()
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=6), policy=BucketPolicy(T=8),
                    max_delay_s=10.0, obs=obs, clock=obs.clock)
    srv.solve_many(_mixed_burst(), op="eigh")
    prom = obs.prometheus_text()
    assert 'serve_requests_total{op="eigh"} 8' in prom
    # per-(op, bucket, backend, executor) latency histogram series
    assert 'serve_request_latency_seconds_bucket{op="eigh",bucket="8x8"' \
        in prom or 'serve_request_latency_seconds_bucket{op="eigh"' in prom
    assert "serve_flushes_total" in prom and "cache=" in prom
    assert "serve_launches_total" in prom
    # the kernel registry's resolution counts surface at export time:
    # force a resolution so the collector has something to mirror (the
    # plain-XLA datapath this config serves on never calls resolve())
    from repro.backends import registered_ops, resolve
    op = registered_ops()[0]
    resolve(op, "ref")
    prom = obs.prometheus_text()
    assert f'kernel_backend_resolutions_total{{op="{op}",backend="ref"}}' \
        in prom


def test_plan_swap_and_autotune_observed():
    obs = Observability.enabled()
    cfg = PCAConfig(T=8, S=4, sweeps=6)
    srv = PCAServer(cfg, policy=BucketPolicy(T=8), max_delay_s=10.0,
                    obs=obs, clock=obs.clock)
    srv.solve_many(_mixed_burst(), op="eigh")
    profile = TrafficProfile.from_stats(srv.stats)
    result = autotune(profile, grid=[ServingPlan(T=8, max_batch=4)],
                      config=cfg, obs=obs)
    srv.apply_plan(result.best)
    names = [s.name for s in obs.tracer.spans]
    assert "autotune" in names and "plan_swap" in names
    prom = obs.prometheus_text()
    assert "serve_plan_swaps_total 1" in prom
    assert 'autotune_searches_total{mode="analytic"} 1' in prom


@pytest.mark.slow
def test_instrumented_overhead_within_3_percent():
    """The acceptance gate: serving the large-bucket throughput regime
    with full observability attached must stay within 3% of the bare
    server.  Interleaved best-of-reps (scheduler noise only ever slows a
    pass down) on identical cached executables."""
    from repro.launch.serve_pca import mixed_traffic

    cfg = PCAConfig(T=16, S=8, sweeps=12)
    mats = mixed_traffic(32, "eigh", (46,))

    def build(obs):
        kw = {"obs": obs}
        if obs is not None:
            kw["clock"] = obs.clock
        return PCAServer(cfg, policy=BucketPolicy(T=16), max_batch=8,
                         max_delay_s=10.0, max_inflight=2, **kw)

    bare = build(None)
    traced = build(Observability.enabled(slo_ms=50.0))

    def one_pass(srv):
        t0 = time.perf_counter()
        srv.solve_many(mats, op="eigh")
        return time.perf_counter() - t0

    for srv in (bare, traced):
        one_pass(srv)                       # warmup: compile the bucket
    best = {id(bare): float("inf"), id(traced): float("inf")}
    for _ in range(5):
        for srv in (bare, traced):          # interleaved: shared noise
            best[id(srv)] = min(best[id(srv)], one_pass(srv))
    overhead = best[id(traced)] / best[id(bare)] - 1.0
    assert overhead <= 0.03, (
        f"instrumentation overhead {overhead * 100:.2f}% > 3% "
        f"(bare {best[id(bare)] * 1e3:.2f}ms, "
        f"traced {best[id(traced)] * 1e3:.2f}ms)")
