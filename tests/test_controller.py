"""The autonomous serving controller: tick cadence and argument
validation, stats-window profiling with quiet-op carry-forward, the
hysteresis / dwell anti-thrash guards, bandit-vs-exhaustive top-1
agreement, hot-swap + admission feedback on a real regime shift under
the injected clock (bit-deterministic), and the ``controller_*``
telemetry families."""
import dataclasses

import numpy as np
import pytest

from repro.core import PCAConfig
from repro.serving import (BucketPolicy, ControllerSpec, CostModel,
                           ExecutionSpec, PCAServer, SchedulingSpec,
                           ServerSpec, ServingController, ServingPlan,
                           TenantSpec, TrafficFrontend, TrafficProfile,
                           VirtualClock, bandit_search, build_server,
                           generate, merge, plan_grid, synthetic_trace)

# kwarg-built fixture servers trip the spec shim by design; the shim
# itself is covered by tests/test_spec.py
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

CFG = PCAConfig(T=16, S=4, sweeps=6)
# slow modeled device with the compile term zeroed: padding waste
# dominates, so a T=16 server on dim-8 traffic always has a profitable
# swap available -- deterministic fodder for the guard tests
PINNED = CostModel(device_work_per_s=1e6, compile_s_per_executable=0.0)


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _server(t, **kw):
    kw.setdefault("policy", BucketPolicy(T=16))
    kw.setdefault("max_delay_s", 0.01)
    return PCAServer(CFG, clock=lambda: t[0], **kw)


# ---------------------------------------------------------------------------
# construction + cadence
# ---------------------------------------------------------------------------

def test_controller_validates_args():
    srv = _server([0.0])
    with pytest.raises(ValueError, match="window_s"):
        ServingController(srv, window_s=0.0)
    with pytest.raises(ValueError, match="window_s"):
        ServingController(srv, reprofile_every_s=0.0)
    with pytest.raises(ValueError, match="hysteresis"):
        ServingController(srv, hysteresis=1.0)


def test_maybe_tick_respects_cadence():
    t = [0.0]
    ctrl = ServingController(_server(t), window_s=1.0,
                             reprofile_every_s=1.0)
    ctrl.maybe_tick(0.0)
    assert ctrl.ticks == 1
    ctrl.maybe_tick(0.5)                       # not due yet: cheap no-op
    assert ctrl.ticks == 1
    ctrl.maybe_tick(1.0)
    assert ctrl.ticks == 2
    ctrl.maybe_tick()                          # falls back to server clock
    assert ctrl.ticks == 2                     # t[0] still 0.0: not due


def test_empty_window_skips_search():
    t = [0.0]
    ctrl = ServingController(_server(t), window_s=1.0,
                             reprofile_every_s=0.25,
                             min_window_requests=4)
    assert ctrl.maybe_tick(0.1) is None        # idle server: nothing to tune
    assert ctrl.ticks == 1 and ctrl.last_result is None
    assert not ctrl.swaps


def test_from_spec_maps_fields():
    cspec = ControllerSpec(enabled=True, window_s=3.0,
                           reprofile_every_s=0.5, hysteresis=0.07,
                           min_dwell_s=1.5, budget_frac=0.5, measure=False)
    ctrl = ServingController.from_spec(_server([0.0]), cspec)
    assert ctrl.window_s == 3.0
    assert ctrl.reprofile_every_s == 0.5
    assert ctrl.hysteresis == 0.07
    assert ctrl.min_dwell_s == 1.5
    assert ctrl.budget_frac == 0.5 and not ctrl.measure


def test_current_plan_mirrors_server_facts():
    srv = _server([0.0], max_batch=8, max_inflight=2)
    cur = ServingController(srv).current_plan()
    assert cur == ServingPlan(mode="tile", T=16, max_batch=8,
                              max_inflight=2, mesh="none")
    srv.apply_plan(ServingPlan(mode="pow2", T=8, pow2_cap=32, max_batch=2))
    cur = ServingController(srv).current_plan()
    assert cur.mode == "pow2" and cur.T == 8 and cur.pow2_cap == 32


# ---------------------------------------------------------------------------
# window profiling
# ---------------------------------------------------------------------------

def test_window_profile_uses_stats_and_decays_quiet_ops():
    t = [0.0]
    srv = _server(t, max_batch=4, max_delay_s=10.0)
    ctrl = ServingController(srv, window_s=1.0, decay=0.5)
    srv.solve_many([_sym(9, seed=i) for i in range(4)])   # retire at t=0
    p1 = ctrl.window_profile(0.5)
    assert p1.requests == 4
    assert ("eigh", (9, 9), 4) in p1.shape_counts
    assert p1.duration_s == 1.0 and p1.arrival_rate == pytest.approx(4.0)
    # the traffic leaves the window: the op carries forward, halved each
    # re-profile, so a pause never tunes against an empty profile
    p2 = ctrl.window_profile(2.0)
    assert ("eigh", (9, 9), 2) in p2.shape_counts
    p3 = ctrl.window_profile(4.0)
    assert ("eigh", (9, 9), 1) in p3.shape_counts


def test_window_profile_windows_fresh_traffic():
    t = [0.0]
    srv = _server(t, max_delay_s=10.0)
    ctrl = ServingController(srv, window_s=1.0)
    srv.solve_many([_sym(9, seed=i) for i in range(4)])   # t_done = 0.0
    t[0] = 5.0
    srv.solve_many([_sym(28, seed=i) for i in range(2)])  # t_done = 5.0
    prof = ctrl.window_profile(5.5)
    # the op is fresh in this window, so only its in-window shapes count
    # -- carry-forward is for *quiet* ops, it must not resurrect stale
    # shapes of an op that is still talking
    assert ("eigh", (28, 28), 2) in prof.shape_counts
    assert not any(shape == (9, 9) for _, shape, _ in prof.shape_counts)
    assert prof.requests == 2


# ---------------------------------------------------------------------------
# swap path: hysteresis, dwell, admission feedback
# ---------------------------------------------------------------------------

def _fed_controller(t, **kw):
    """A T=16 server on dim-8 traffic with a pinned slow model: the
    bandit's best plan always beats the current one by a wide margin."""
    srv = _server(t, max_batch=4, max_delay_s=10.0)
    fe = TrafficFrontend(srv, (TenantSpec("t0"),), slo_ms=100.0,
                         admission="shed", model=CostModel())
    kw.setdefault("window_s", 1.0)
    kw.setdefault("reprofile_every_s", 0.25)
    kw.setdefault("hysteresis", 0.05)
    kw.setdefault("min_dwell_s", 0.5)
    ctrl = ServingController(srv, frontend=fe, model=PINNED, **kw)
    srv.solve_many([_sym(8, seed=i) for i in range(8)])
    return srv, fe, ctrl


def test_tick_swaps_and_feeds_admission_model():
    t = [0.0]
    srv, fe, ctrl = _fed_controller(t)
    before = fe.admission.model
    swap = ctrl.maybe_tick(0.1)
    assert swap is not None
    assert swap["t"] == 0.1 and swap["predicted_gain"] > 0.05
    assert swap["plan"] == ctrl.last_result.best.describe()
    # the server now runs the bandit's best plan...
    assert ctrl.current_plan() == ctrl.last_result.best
    assert len(srv.stats.plan_switches) == 1
    assert ctrl.plan_log == [(0.1, ctrl.last_result.best)]
    # ...and the frontend's admission control scores against the model
    # the controller decided with, not the stale construction-time one
    assert fe.model is PINNED and fe.admission.model is PINNED
    assert fe.admission.model is not before
    assert fe.admission.policy is srv.policy
    assert fe.admission.batch == srv.max_batch


def test_hysteresis_blocks_marginal_swaps():
    t = [0.0]
    _, _, ctrl = _fed_controller(t, hysteresis=0.95)
    assert ctrl.maybe_tick(0.1) is None        # nothing gains 95%
    assert ctrl.ticks == 1 and not ctrl.swaps
    assert ctrl.last_result is not None        # it did search, then held


def test_dwell_blocks_immediate_reswap():
    t = [0.0]
    srv, _, ctrl = _fed_controller(t, min_dwell_s=0.5)
    assert ctrl.maybe_tick(0.1) is not None
    # revert behind the controller's back: the profitable swap is
    # available again, but dwell must hold it back until t >= 0.6
    srv.apply_plan(ServingPlan())
    assert ctrl.maybe_tick(0.4) is None
    assert len(ctrl.swaps) == 1
    assert ctrl.maybe_tick(0.7) is not None
    assert len(ctrl.swaps) == 2


def test_same_plan_is_a_noop_tick():
    t = [0.0]
    _, _, ctrl = _fed_controller(t)
    assert ctrl.maybe_tick(0.1) is not None
    assert ctrl.maybe_tick(1.0) is None        # already on the best plan
    assert len(ctrl.swaps) == 1
    assert ctrl.summary()["swaps"] == 1
    assert ctrl.summary()["swap_log"][0]["plan"] == \
        ctrl.last_result.best.describe()


# ---------------------------------------------------------------------------
# bandit vs exhaustive grid
# ---------------------------------------------------------------------------

def test_bandit_top1_matches_exhaustive_grid_on_bimodal_trace():
    mats = synthetic_trace("bimodal", 24, op="eigh", lo=8, hi=44, seed=0)
    srv = PCAServer(CFG, policy=BucketPolicy(T=16), max_delay_s=10.0)
    for _ in range(2):
        srv.solve_many(mats)
    profile = TrafficProfile.from_stats(srv.stats,
                                        captured=srv.describe_plan())
    grid = plan_grid()
    model = CostModel.calibrated(profile)
    result = bandit_search(profile, grid=grid, model=model,
                           budget_frac=0.25, measure=False)
    exhaustive = min(grid, key=lambda p:
                     model.plan_cost(p, profile)["total_s"])
    assert result.best == exhaustive
    assert result.mode == "bandit-analytic"
    assert result.measured_evals == 0          # analytic rung is free
    assert result.grid_size == len(grid)


# ---------------------------------------------------------------------------
# closed loop under the virtual clock
# ---------------------------------------------------------------------------

def _regime_stream(n_small=60, n_big=60):
    tenant = (TenantSpec("t0"),)
    small = generate("poisson", rate=200.0, n=n_small, tenants=tenant,
                     seed=5, trace="uniform", lo=8, hi=12)
    shift_t = max(a.t for a in small) + 1e-3
    big = [dataclasses.replace(a, t=a.t + shift_t) for a in
           generate("poisson", rate=40.0, n=n_big, tenants=tenant,
                    seed=9, trace="uniform", lo=28, hi=44)]
    return merge(small, big), shift_t


def _controlled_run(stream, hysteresis=0.05, min_dwell_s=0.25,
                    window_s=0.5, reprofile_every_s=0.25):
    spec = ServerSpec(
        scheduling=SchedulingSpec(T=16, max_batch=4, max_delay_s=0.02),
        execution=ExecutionSpec(sweeps=6),
        controller=ControllerSpec(enabled=True, window_s=window_s,
                                  reprofile_every_s=reprofile_every_s,
                                  hysteresis=hysteresis,
                                  min_dwell_s=min_dwell_s))
    srv = build_server(spec, clock=VirtualClock())
    srv.controller.model = PINNED
    fe = TrafficFrontend(srv, (TenantSpec("t0"),), slo_ms=500.0,
                         admission="none", model=CostModel(), seed=1)
    srv.controller.frontend = fe
    rep = fe.run(stream, pace=False)
    return srv, fe, rep


def test_controlled_run_is_deterministic_and_swaps_after_shift():
    stream, shift_t = _regime_stream()
    srv_a, fe_a, rep_a = _controlled_run(stream)
    srv_b, fe_b, rep_b = _controlled_run(stream)
    # bit-deterministic: results AND the whole control timeline replay
    assert rep_a.digest == rep_b.digest
    assert ([s["t"] for s in srv_a.controller.swaps]
            == [s["t"] for s in srv_b.controller.swaps])
    assert ([s["plan"] for s in srv_a.controller.swaps]
            == [s["plan"] for s in srv_b.controller.swaps])
    ctrl = srv_a.controller
    assert ctrl.ticks > 0 and len(ctrl.swaps) >= 1
    # the regime shift is answered within two re-profile windows + dwell
    post = [s["t"] for s in ctrl.swaps if s["t"] >= shift_t]
    assert post, "no swap after the regime shift"
    assert post[0] - shift_t <= 2 * ctrl.window_s + ctrl.min_dwell_s
    # the admission model in force is the controller's, not the
    # construction-time one
    assert fe_a.admission.model is PINNED


def test_guards_prevent_thrash_on_oscillating_trace():
    """Alternating 1s bursts of small and large matrices: an unguarded
    controller chases every flip; hysteresis + dwell hold the line."""
    tenant = (TenantSpec("t0"),)
    chunks = []
    t0 = 0.0
    for i in range(4):
        lo, hi = ((8, 12) if i % 2 == 0 else (28, 44))
        burst = generate("poisson", rate=60.0, n=30, tenants=tenant,
                         seed=10 + i, trace="uniform", lo=lo, hi=hi)
        chunks.append([dataclasses.replace(a, t=a.t + t0) for a in burst])
        t0 = max(a.t for a in chunks[-1]) + 1e-3
    stream = merge(*chunks)
    srv_eager, _, _ = _controlled_run(stream, hysteresis=0.0,
                                      min_dwell_s=0.0)
    srv_guarded, _, _ = _controlled_run(stream, hysteresis=0.10,
                                        min_dwell_s=2.0)
    eager = len(srv_eager.controller.swaps)
    guarded = len(srv_guarded.controller.swaps)
    assert eager > guarded
    # dwell alone bounds the worst case: one swap per dwell period
    span = max(a.t for a in stream)
    assert guarded <= span / 2.0 + 1


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_controller_emits_telemetry():
    from repro.obs import Observability
    t = [0.0]
    obs = Observability.enabled(slo_ms=100.0, clock=lambda: t[0])
    srv = PCAServer(CFG, policy=BucketPolicy(T=16), max_delay_s=10.0,
                    clock=lambda: t[0], obs=obs)
    ctrl = ServingController(srv, window_s=1.0, reprofile_every_s=0.25,
                             hysteresis=0.05, min_dwell_s=0.5,
                             model=PINNED)
    ctrl.maybe_tick(0.05)                      # empty window -> skip
    srv.solve_many([_sym(8, seed=i) for i in range(8)])
    assert ctrl.maybe_tick(0.4) is not None    # swap
    ctrl.maybe_tick(0.7)                       # same-plan skip
    text = obs.metrics.to_prometheus()
    assert "controller_ticks_total 3" in text
    assert "controller_swaps_total 1" in text
    assert 'controller_skips_total{reason="empty-window"} 1' in text
    assert 'controller_skips_total{reason="same-plan"} 1' in text
    assert "controller_predicted_gain" in text
    names = [ev["name"] for ev in obs.tracer.export()["traceEvents"]]
    assert "controller_tick" in names


def test_controller_without_obs_is_silent_but_functional():
    t = [0.0]
    srv, _, ctrl = _fed_controller(t)
    assert srv.obs is None
    assert ctrl.maybe_tick(0.1) is not None    # no AttributeError paths
    assert ctrl.summary()["swaps"] == 1
