"""Test-session guards.

The dry-run isolation contract: ONLY repro.launch.dryrun (and the other
launch-time scripts) force a 512-device host platform; smoke tests and
benches must see the single real device.  Multi-device tests run in
subprocesses (tests/test_distributed.py) that set XLA_FLAGS themselves.
"""
import os


def pytest_sessionstart(session):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "xla_force_host_platform_device_count" not in flags, (
        "tests must run with the default (single) device; multi-device "
        "tests spawn their own subprocesses")
