"""Test-session guards + marker registration.

The dry-run isolation contract: ONLY repro.launch.dryrun (and the other
launch-time scripts) force a 512-device host platform; smoke tests and
benches must see the single real device.  Multi-device tests run in
subprocesses (tests/test_distributed.py) that set XLA_FLAGS themselves.

Tiering: ``slow`` marks long-running full-size cases (see pytest.ini);
the default run is the fast tier (`-m "not slow"` via addopts), which must
finish in under 5 minutes on CPU.  Every slow case's subsystem keeps
fast-tier coverage -- through a reduced variant (jamba hybrid, checkpoint
resume, property sweeps, DLE tilewise, KV rank sweep) or a sibling smoke
(arctic/whisper forward+decode, the other sharded-parity tests).
"""
import os


def pytest_configure(config):
    # belt-and-braces: keep the marker registered even if pytest.ini is not
    # picked up (e.g. running a test file from another rootdir)
    config.addinivalue_line(
        "markers", "slow: long-running full-size case (fast variant runs "
        "by default; opt in with -m slow)")


def pytest_sessionstart(session):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "xla_force_host_platform_device_count" not in flags, (
        "tests must run with the default (single) device; multi-device "
        "tests spawn their own subprocesses")
