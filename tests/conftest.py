"""Test-session guards + marker registration.

The dry-run isolation contract: ONLY repro.launch.dryrun (and the other
launch-time scripts) force a *massive* (512-device) host platform; a
dryrun-scale flag leaking into the test environment would silently turn
every jit into a 512-way compile.  A deliberate small multi-device run is
fine and is exactly what the mesh-8 CI matrix job does
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``): sharded-serving
and distributed tests then exercise a real mesh in-process.  Tests that
need a specific device count regardless of the session environment spawn
subprocesses that set XLA_FLAGS themselves
(tests/test_distributed.py, tests/test_sharded_serving.py).

Tiering: ``slow`` marks long-running full-size cases (see pytest.ini);
the default run is the fast tier (`-m "not slow"` via addopts), which must
finish in under 5 minutes on CPU.  Every slow case's subsystem keeps
fast-tier coverage -- through a reduced variant (jamba hybrid, checkpoint
resume, property sweeps, DLE tilewise, KV rank sweep) or a sibling smoke
(arctic/whisper forward+decode, the other sharded-parity tests).
"""
import os


def pytest_configure(config):
    # belt-and-braces: keep the marker registered even if pytest.ini is not
    # picked up (e.g. running a test file from another rootdir)
    config.addinivalue_line(
        "markers", "slow: long-running full-size case (fast variant runs "
        "by default; opt in with -m slow)")


def pytest_sessionstart(session):
    flags = os.environ.get("XLA_FLAGS", "")
    marker = "xla_force_host_platform_device_count"
    if marker in flags:
        count = int(flags.split(marker + "=", 1)[1].split()[0].split(",")[0])
        assert count <= 64, (
            f"XLA_FLAGS forces {count} host devices -- that is a "
            "launch-dryrun-scale platform leaking into the test "
            "environment; tests support deliberate small meshes only "
            "(e.g. the mesh-8 CI job)")
