"""Lightweight stand-in for ``hypothesis`` on bare environments.

The tier-1 suite must collect and run without optional dev dependencies.
When ``hypothesis`` is importable the test modules use the real thing; when
it is not, this module provides deterministic miniature replacements for the
small subset the suite uses (``given`` / ``settings`` / ``strategies``):
each property test runs a handful of seeded pseudo-random examples instead
of a full shrinking search.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # bare env: deterministic samples
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import types

import numpy as np

# Fallback sample budget: enough to exercise shape/seed variety without a
# shrinking engine, small enough to keep bare-env CI fast.
FALLBACK_MAX_EXAMPLES = 5


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def _floats(min_value, max_value, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


st = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    floats=_floats,
    booleans=_booleans,
)


def settings(max_examples: int = 10, **_kwargs):
    """Accepts (and mostly ignores) real-hypothesis settings knobs."""

    def deco(fn):
        fn._fallback_max_examples = min(max_examples, FALLBACK_MAX_EXAMPLES)
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        # NOT functools.wraps: the wrapper must present a zero-arg signature
        # so pytest does not mistake the drawn parameters for fixtures.
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples",
                        FALLBACK_MAX_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__dict__.update(fn.__dict__)
        return wrapper

    return deco
