"""Two-tier executable cache: SolverKey de-fragmentation, the bounded
in-memory LRU, the persistent AOT tier's failure modes (corruption,
environment drift, concurrent warmers), warmup, and the rank-deficiency
fix in the SVD back-projection that rides along."""
import os
import pathlib
import pickle
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PCAConfig
from repro.serving import (BucketPolicy, LRUCache, PCAServer, ServingPlan,
                           SolverKey, TrafficProfile, aot_supported,
                           jacobi_svd_batched)
import repro.serving.cache as cache_mod
import repro.serving.sharded as sharded_mod
from repro.obs import Observability

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
aot = pytest.mark.skipif(not aot_supported(),
                         reason="jax lacks serialize_executable")


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _server(tmpdir=None, sweeps=4, **kw):
    kw.setdefault("policy", BucketPolicy(T=8))
    kw.setdefault("max_delay_s", 10.0)
    return PCAServer(PCAConfig(T=8, S=2, sweeps=sweeps),
                     cache_dir=(str(tmpdir) if tmpdir is not None else None),
                     **kw)


def _assert_results_equal(a, b):
    for ra, rb in zip(a, b):
        for field in ra.__dataclass_fields__:
            np.testing.assert_array_equal(getattr(ra, field),
                                          getattr(rb, field))


# ---------------------------------------------------------------------------
# keying + memory tier
# ---------------------------------------------------------------------------

def test_lru_cache_evicts_coldest_first():
    evicted = []
    lru = LRUCache(max_entries=2, on_evict=lambda k, v: evicted.append(k))
    lru["a"], lru["b"] = 1, 2
    assert lru["a"] == 1            # refresh "a": "b" is now coldest
    lru["c"] = 3
    assert set(lru) == {"a", "c"}
    assert lru.evictions == 1 and evicted == ["b"]
    assert lru.get("b") is None
    # unbounded mode never evicts
    unbounded = LRUCache(max_entries=None)
    for i in range(600):
        unbounded[i] = i
    assert len(unbounded) == 600 and unbounded.evictions == 0


def test_solver_key_ignores_scheduling_facts():
    """The fragmentation bug: T/S are scheduling facts, not numerics --
    configs differing only there must share one executable key."""
    a = SolverKey.from_config(PCAConfig(T=8, S=2))
    b = SolverKey.from_config(PCAConfig(T=32, S=64))
    assert a == b and hash(a) == hash(b)
    assert a != SolverKey.from_config(PCAConfig(T=8, S=2, sweeps=3))
    # ...except the matmul block size once a kernel backend consumes it
    ka = SolverKey.from_config(PCAConfig(T=8, backend="interpret"))
    kb = SolverKey.from_config(PCAConfig(T=16, backend="interpret"))
    assert ka != kb
    assert ka.backend == "interpret"      # engine tests key on k[3].backend


def test_solver_key_carries_precision_and_fused():
    """Mixed-precision policy and fused routing change the compiled
    executable, so they must fragment the key -- unlike T/S."""
    base = SolverKey.from_config(PCAConfig(T=8, S=2))
    assert base.precision == "fp32" and base.fused is False
    assert base != SolverKey.from_config(
        PCAConfig(T=8, S=2, precision="bf16_fp32acc"))
    assert base != SolverKey.from_config(PCAConfig(T=8, S=2, fused=True))
    # content hash fragments with them too (the disk-tier file name)
    h = lambda k: cache_mod.content_hash("pca", (8, 8), 2, k, None)
    assert h(base) != h(SolverKey.from_config(
        PCAConfig(T=8, S=2, precision="bf16_fp32acc")))


def test_cache_format_bump_invalidates_disk_entries(monkeypatch):
    """CACHE_FORMAT is key material: entries hashed under format N are
    never looked up by a format N+1 server (clean miss, no load error)."""
    key = SolverKey.from_config(PCAConfig(T=8, S=2))
    new = cache_mod.content_hash("eigh", (8, 8), 2, key, None)
    monkeypatch.setattr(cache_mod, "CACHE_FORMAT",
                        cache_mod.CACHE_FORMAT - 1)
    old = cache_mod.content_hash("eigh", (8, 8), 2, key, None)
    assert new != old


def test_local_executor_builds_each_solver_once(monkeypatch):
    """Regression for the rebuild-per-key bug: two batch sizes of one
    bucket used to re-build and re-trace an identical solver closure."""
    builds = []
    real = sharded_mod.build_solver_fn

    def counting(op, config):
        builds.append((op, SolverKey.from_config(config)))
        return real(op, config)

    monkeypatch.setattr(sharded_mod, "build_solver_fn", counting)
    srv = _server(pad_batches=False, sweeps=3)
    mats = [_sym(6, seed=i) for i in range(4)]
    srv.submit(mats[0]).wait()            # flush of batch 1
    for m in mats[1:]:                    # flush of batch 2 + batch 1
        srv.submit(m)
    srv.drain()
    assert {k[2] for k in srv._cache} >= {1, 2}   # distinct engine keys...
    fns = {id(srv._cache[k]) for k in srv._cache}
    assert len(fns) == 1                  # ...but one shared jit wrapper
    assert len(builds) == 1, builds       # built (and traced) exactly once


def test_engine_cache_bounded_with_gauge():
    obs = Observability.enabled()
    srv = _server(sweeps=2, obs=obs, clock=obs.clock,
                  max_cached_executables=2)
    for n in (5, 9, 17):                  # three buckets, one executable each
        srv.solve_many([_sym(n)], op="eigh")
    assert len(srv._cache) == 2
    assert srv._cache.evictions >= 1
    assert srv.cache_summary()["entries"] == 2
    text = obs.prometheus_text()
    assert "serve_executables_cached 2" in text
    # the evicted (coldest) bucket recompiles on return; the hot one hits
    srv.solve_many([_sym(17)], op="eigh")
    assert len(srv._cache) == 2


# ---------------------------------------------------------------------------
# warmup
# ---------------------------------------------------------------------------

def test_warmup_prebuilds_profile_executables():
    obs = Observability.enabled()
    srv = _server(sweeps=2, obs=obs, clock=obs.clock)
    profile = TrafficProfile.from_shapes(
        [("eigh", (6, 6), 3), ("eigh", (5, 5), 1), ("svd", (12, 6), 2)])
    # (6,6) and (5,5) share the (8,8) bucket -> two distinct executables
    assert len(srv.warmup_keys(profile)) == 2
    doc = srv.warmup(profile)
    assert doc["executables"] == 2 and doc["compile"] == 2
    again = srv.warmup(profile)
    assert again["memory"] == 2 and again["compile"] == 0
    # warm traffic is all cache hits from the first flush
    srv.solve_many([_sym(6), _sym(5)], op="eigh")
    assert srv.stats.summary()["cache_hit_rate"] == 1.0
    names = {e.get("name") for e in obs.trace_doc()["traceEvents"]}
    assert "warmup" in names
    assert "serve_warmup_executables_total" in obs.prometheus_text()


def test_warmup_keys_ordered_by_descending_traffic_weight():
    """SLO-aware warmup: the executables the profile says will be hit
    most compile first, so an interrupted warmup has already armed the
    highest-traffic paths.  Order is pinned: weight desc, then first
    appearance."""
    srv = _server(sweeps=2)
    profile = TrafficProfile.from_shapes([
        ("eigh", (6, 6), 2),       # bucket (8,8): 2 + 5 = 7 total
        ("svd", (12, 6), 1),       # lone low-traffic shape
        ("eigh", (5, 5), 5),       # folds onto the (8,8) eigh bucket
        ("pca", (12, 6), 4),
    ])
    keys = srv.warmup_keys(profile)
    assert [(k[0], k[1]) for k in keys] == [
        ("eigh", (8, 8)),          # weight 7
        ("pca", (16, 8)),          # weight 4
        ("svd", (16, 8)),          # weight 1
    ]
    # bare (op, shape) rows (no counts) keep working: weight 1 each,
    # insertion order preserved
    bare = srv.warmup_keys([("svd", (12, 6)), ("eigh", (6, 6))])
    assert [k[0] for k in bare] == ["svd", "eigh"]


def test_apply_plan_prewarms_incoming_executables():
    srv = _server(sweeps=2, max_batch=2)
    srv.submit(_sym(6))                   # queued, below max_batch: no flush
    plan = ServingPlan(mode="tile", T=16, max_batch=2, max_inflight=1,
                       mesh="none")
    switch = srv.apply_plan(plan)
    assert switch["prewarmed"]["compile"] >= 1
    srv.drain()
    assert srv.stats.flush_records        # the queued request was served...
    assert all(f.cache_hit for f in srv.stats.flush_records)  # ...warm


# ---------------------------------------------------------------------------
# persistent tier
# ---------------------------------------------------------------------------

@aot
def test_disk_tier_round_trip_is_bitwise_identical(tmp_path):
    mats = [_sym(6), _sym(7, seed=1)]
    seeder = _server(tmp_path)
    expect = seeder.solve_many(mats, op="eigh")
    assert seeder.cache_summary()["disk"]["stores"] >= 1
    assert list(tmp_path.glob("*.jexec"))

    fresh = _server(tmp_path)
    got = fresh.solve_many(mats, op="eigh")
    disk = fresh.cache_summary()["disk"]
    assert disk["hits"] >= 1 and disk["errors"] == 0
    _assert_results_equal(expect, got)
    # and identical to a plain-JIT replica: the serialize round trip and
    # the AOT path must never touch the math
    _assert_results_equal(expect, _server().solve_many(mats, op="eigh"))


@aot
def test_corrupt_cache_entry_falls_back_and_repairs(tmp_path):
    mats = [_sym(6)]
    expect = _server(tmp_path).solve_many(mats, op="eigh")
    files = list(tmp_path.glob("*.jexec"))
    assert files
    for f in files:
        f.write_bytes(b"not a pickled executable")

    srv = _server(tmp_path)
    got = srv.solve_many(mats, op="eigh")
    _assert_results_equal(expect, got)
    disk = srv.cache_summary()["disk"]
    assert disk["errors"] >= 1            # quarantined the torn entry...
    assert disk["stores"] >= 1            # ...and repaired it in place

    repaired = _server(tmp_path)
    _assert_results_equal(expect, repaired.solve_many(mats, op="eigh"))
    disk = repaired.cache_summary()["disk"]
    assert disk["hits"] >= 1 and disk["errors"] == 0


@aot
def test_environment_drift_invalidates_cleanly(tmp_path, monkeypatch):
    """A different (jax version, device backend) fingerprint hashes to a
    different file name: the stale entry is simply never looked up."""
    mats = [_sym(6)]
    _server(tmp_path).solve_many(mats, op="eigh")
    before = set(tmp_path.glob("*.jexec"))

    monkeypatch.setattr(cache_mod, "environment_fingerprint",
                        lambda: ("jax-9.9.9", "quantum"))
    srv = _server(tmp_path)
    srv.solve_many(mats, op="eigh")
    disk = srv.cache_summary()["disk"]
    assert disk["hits"] == 0 and disk["misses"] >= 1
    assert disk["errors"] == 0            # clean miss, not a load failure
    assert set(tmp_path.glob("*.jexec")) > before   # stored under new hash


@aot
def test_header_version_mismatch_is_quarantined(tmp_path):
    """Defense in depth: even if the hash collided across environments,
    the in-file header is checked and a drifted entry is rejected."""
    mats = [_sym(6)]
    expect = _server(tmp_path).solve_many(mats, op="eigh")
    path = next(iter(tmp_path.glob("*.jexec")))
    record = pickle.loads(path.read_bytes())
    record["jax"] = "0.0.1"
    path.write_bytes(pickle.dumps(record))

    srv = _server(tmp_path)
    got = srv.solve_many(mats, op="eigh")
    _assert_results_equal(expect, got)
    disk = srv.cache_summary()["disk"]
    assert disk["errors"] >= 1 and disk["stores"] >= 1


_WARMER = """\
import sys
from repro.core import PCAConfig
from repro.serving import BucketPolicy, PCAServer, TrafficProfile
srv = PCAServer(PCAConfig(T=8, S=2, sweeps=2), policy=BucketPolicy(T=8),
                max_delay_s=10.0, cache_dir=sys.argv[1])
doc = srv.warmup(TrafficProfile.from_shapes(
    [("eigh", (6, 6), 1), ("svd", (12, 6), 1)]))
assert doc["executables"] == 2, doc
print("warmed")
"""


@aot
def test_concurrent_warmers_share_one_cache_dir(tmp_path):
    """Two replicas warming the same --cache-dir concurrently must not
    torch each other's entries (atomic write-then-rename)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    procs = [subprocess.Popen([sys.executable, "-c", _WARMER,
                               str(tmp_path)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
             for _ in range(2)]
    outs = [p.communicate(timeout=600) for p in procs]
    assert all(p.returncode == 0 for p in procs), outs
    assert all("warmed" in out for out, _ in outs)
    # every surviving entry is loadable: a third replica warms with zero
    # compiles and zero quarantines
    srv = PCAServer(PCAConfig(T=8, S=2, sweeps=2), policy=BucketPolicy(T=8),
                    max_delay_s=10.0, cache_dir=str(tmp_path))
    doc = srv.warmup(TrafficProfile.from_shapes(
        [("eigh", (6, 6), 1), ("svd", (12, 6), 1)]))
    assert doc["compile"] == 0 and doc["disk"] == doc["executables"] == 2
    assert srv.cache_summary()["disk"]["errors"] == 0


@aot
def test_disk_cache_size_cap_evicts_down_to_cap(tmp_path):
    fn = jax.jit(lambda x: x + 1.0).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    probe = cache_mod.DiskCache(tmp_path / "probe")
    assert probe.put("a" * 64, fn)
    entry_bytes = probe.total_bytes()

    disk = cache_mod.DiskCache(tmp_path / "capped",
                               max_bytes=int(entry_bytes * 1.5))
    assert disk.put("a" * 64, fn)
    assert disk.put("b" * 64, fn)         # over cap: one entry evicted
    assert len(disk.entries()) == 1
    assert disk.total_bytes() <= disk.max_bytes


# ---------------------------------------------------------------------------
# rank-deficiency fix in the SVD back-projection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [None, "interpret"])
def test_rank_deficient_svd_zeroes_dead_columns(backend):
    """U = A V / s used to amplify Gram-path rounding noise into garbage
    columns wherever s ~ 0; those columns must now be exactly zero while
    the live ones still reconstruct A."""
    rng = np.random.default_rng(3)
    n, rank = 16, 2
    A = (rng.standard_normal((n, rank))
         @ rng.standard_normal((rank, n))).astype(np.float32)
    mm = PCAConfig(T=16, backend=backend).matmul_fn()
    res = jacobi_svd_batched(A[None], matmul_fn=mm, sweeps=14)
    U, S, Vt = (np.asarray(res.U[0]), np.asarray(res.S[0]),
                np.asarray(res.Vt[0]))
    ref = np.linalg.svd(A, compute_uv=False)
    np.testing.assert_allclose(S[:rank], ref[:rank], rtol=1e-3)
    assert np.all(U[:, rank:] == 0.0)     # dead columns: exactly zero
    assert np.all(np.isfinite(U))
    scale = float(ref[0])
    np.testing.assert_allclose(U @ np.diag(S) @ Vt, A,
                               atol=2e-3 * scale)
    # live columns are orthonormal (the noise never leaked into them)
    np.testing.assert_allclose(U[:, :rank].T @ U[:, :rank], np.eye(rank),
                               atol=1e-3)


def test_zero_matrix_svd_is_all_zero():
    res = jacobi_svd_batched(np.zeros((1, 8, 8), np.float32), sweeps=4)
    assert np.all(np.asarray(res.U) == 0.0)
    assert np.all(np.asarray(res.S) == 0.0)


def test_full_rank_svd_unchanged_by_rcond_mask():
    """The mask only ever turns noise into zeros: a well-conditioned
    input's factors are bit-identical with the mask disabled."""
    rng = np.random.default_rng(11)
    u, _, vt = np.linalg.svd(rng.standard_normal((8, 8)))
    A = (u @ np.diag(np.linspace(2.0, 1.0, 8)) @ vt).astype(
        np.float32)[None]                 # condition number 2: all live
    masked = jacobi_svd_batched(A, sweeps=10)
    unmasked = jacobi_svd_batched(A, sweeps=10, rcond=0.0)
    np.testing.assert_array_equal(np.asarray(masked.U),
                                  np.asarray(unmasked.U))
    np.testing.assert_array_equal(np.asarray(masked.S),
                                  np.asarray(unmasked.S))
