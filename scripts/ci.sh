#!/usr/bin/env bash
# CI entry point: a short serving smoke (so the multi-tenant server path --
# submit -> bucket -> batch -> executable cache -> unpack -- is exercised on
# every PR) followed by the tier-1 test suite.  The smoke runs first because
# the seed suite still carries known environment-dependent failures (Pallas
# kernel tests on non-TPU backends) that stop `pytest -x` early.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== serving smoke (serve_pca --selftest) =="
python -m repro.launch.serve_pca --selftest

echo "== tier-1 tests =="
python -m pytest -x -q
