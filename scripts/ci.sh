#!/usr/bin/env bash
# CI entry point.  Order:
#   1. resolved-API banner  -- which Pallas compiler-params spelling and
#      which kernel backends this host resolves to (version drift shows up
#      here first, not as 28 cryptic kernel failures)
#   2. serving smoke        -- submit -> bucket -> batch -> cache -> unpack
#   3. backend-sweep smoke  -- one sweep point: a router splits two buckets
#      across two kernel backends in one server, verified against numpy
#   4. tier-1 tests         -- fast tier by default (pytest.ini deselects
#      `slow`); MUST be zero failures, enforced by the pytest exit code
#      under `set -e`.  `scripts/ci.sh --slow` appends the slow tier.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== resolved accelerator API =="
python - <<'EOF'
from repro.kernels import compat
from repro import backends
print(compat.describe())
print(backends.describe())
EOF

echo "== serving smoke (serve_pca --selftest) =="
python -m repro.launch.serve_pca --selftest

echo "== backend-sweep smoke (serve_throughput --selftest) =="
python -m benchmarks.serve_throughput --selftest

echo "== tier-1 tests (fast tier; zero failures required) =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tier =="
    python -m pytest -q -m slow
fi
