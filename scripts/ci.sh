#!/usr/bin/env bash
# CI entry point.  Order:
#   1. resolved-API banner  -- which Pallas compiler-params spelling and
#      which kernel backends this host resolves to (version drift shows up
#      here first, not as 28 cryptic kernel failures), plus
#      jax.device_count() and the mesh shape the sharded smoke will
#      resolve to (device-visibility drift shows up in the log header
#      instead of as parity failures), plus the serving plan the
#      autotuner picks for a canned reference trace (cost-model drift
#      shows up as a changed banner plan before it shows up as a
#      BENCH_autotune_gain gate failure)
#   2. serving smoke        -- submit -> bucket -> batch -> cache -> unpack,
#      including a sharded-flush parity leg over every visible device and
#      an async-pipeline leg (sync-vs-async bit-for-bit parity on a mixed
#      burst, in-flight depth telemetry > 1) and a cold-start leg (a
#      replica seeds a --cache-dir, a fresh replica warms every
#      executable from disk with zero compiles, bit-for-bit parity) and
#      a spec leg (ServerSpec JSON round trip, spec-vs-kwarg
#      construction parity, the kwarg-soup deprecation shim) and a
#      controller leg (a regime-shift stream under a virtual clock:
#      deterministic swaps, dwell guard respected, recalibrated cost
#      model pushed into the frontend's admission controller);
#      runs in both matrix jobs
#   3. backend-sweep smoke  -- one sweep point: a router splits two buckets
#      across two kernel backends in one server, verified against numpy
#   4. observability smoke  -- a traced serve_pca run must export a
#      schema-valid Chrome trace (request->flush parentage checked by
#      repro.obs.validate_trace) and Prometheus metrics carrying the
#      per-(op, bucket, backend) latency histograms and SLO counters
#   5. frontend smoke       -- the open-loop traffic frontend's
#      deterministic virtual-clock checks: a seeded Poisson run is
#      bit-identical across invocations, shed accounting balances,
#      admission beats unbounded queueing past saturation, and WFQ
#      bounds the starved tenant's p99 where FIFO does not; runs in
#      both matrix jobs
#   6. perf-regression gate -- re-emit BENCH_serve_throughput.json and diff
#      it against the committed copy (scripts/check_bench.py; fails on
#      >25% throughput regression).  Runs regardless of --slow.
#   7. tier-1 tests         -- fast tier by default (pytest.ini deselects
#      `slow`); MUST be zero failures, enforced by the pytest exit code
#      under `set -e`.  `scripts/ci.sh --slow` appends the slow tier.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== resolved accelerator API =="
python - <<'EOF'
import jax
from repro.kernels import compat
from repro import backends
from repro.serving import mesh_executor
print(compat.describe())
print(backends.describe())
print(f"devices: jax.device_count()={jax.device_count()} "
      f"({jax.default_backend()})")
print(f"sharded smoke resolves to: {mesh_executor('auto').describe()}")
from repro.serving import TrafficProfile, autotune
profile = TrafficProfile.from_shapes(
    [("eigh", (12, 12), 24), ("eigh", (40, 40), 8)])
print(f"autotuned plan (reference bimodal trace): "
      f"{autotune(profile).best.describe()}")
EOF

echo "== serving smoke (serve_pca --selftest) =="
python -m repro.launch.serve_pca --selftest

echo "== backend-sweep smoke (serve_throughput --selftest) =="
python -m benchmarks.serve_throughput --selftest

echo "== observability smoke (traced serve_pca + trace schema gate) =="
OBS_DIR="${OBS_DIR:-$(mktemp -d)}"
python -m repro.launch.serve_pca --requests 16 --slo-ms 50 \
    --trace-out "$OBS_DIR/trace.json" \
    --metrics-out "$OBS_DIR/metrics.prom" > "$OBS_DIR/serve_pca.json"
python - "$OBS_DIR" <<'EOF'
import json, pathlib, sys
from repro.obs import validate_trace
obs_dir = pathlib.Path(sys.argv[1])
doc = json.loads((obs_dir / "trace.json").read_text())
errors = validate_trace(doc)
assert not errors, errors[:5]
xs = {e["id"]: e for e in doc["traceEvents"]
      if e.get("ph") == "X" and isinstance(e.get("id"), int)}
requests = [e for e in xs.values() if e["name"].startswith("request:")]
assert requests, "no request spans in trace"
for e in requests:
    assert xs[e["args"]["parent"]]["name"].startswith("flush:")
prom = (obs_dir / "metrics.prom").read_text()
for want in ("serve_request_latency_seconds_bucket", "serve_flushes_total",
             "slo_requests_total"):
    assert want in prom, f"{want} missing from metrics export"
slo = json.loads((obs_dir / "serve_pca.json").read_text())["obs"]["slo"]
assert slo["requests"] == 16, slo
print(f"observability smoke ok: {len(xs)} spans, "
      f"{len(requests)} request spans, "
      f"goodput {slo['goodput_rps']:.1f} rps @ {slo['slo_ms']:.0f}ms SLO")
EOF

echo "== frontend smoke (goodput --selftest) =="
python -m benchmarks.goodput --selftest

echo "== perf-regression gate (serve_throughput + check_bench) =="
# single-device regime only: grid rows from a multi-device process carry a
# different device_count identity and can never match the committed file,
# and the sharded rows are regime-pinned in a subprocess, so the
# single-device job already gates everything this job could.
if [[ "$(python -c 'import jax; print(jax.device_count())')" == "1" ]]; then
    python -m benchmarks.serve_throughput
    python scripts/check_bench.py
else
    echo "skipped: multi-device regime (gated by the single-device job)"
fi

echo "== tier-1 tests (fast tier; zero failures required) =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tier =="
    python -m pytest -q -m slow
fi
