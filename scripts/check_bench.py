#!/usr/bin/env python
"""Perf-regression gate: fresh BENCH_*.json vs the committed copies.

The BENCH_*.json files at the repo root are the perf trajectory -- each PR
commits the numbers its benchmarks measured.  Until now nothing *enforced*
the trajectory; this script does: after CI re-runs a benchmark (emitting a
fresh JSON over the committed one), it diffs every row of the fresh file
against the committed copy (``git show HEAD:<file>``) and fails on a
throughput regression beyond the tolerance.

Rows are matched by their identity fields (strings, bools and ints --
T/S/policy/backend/n_devices/...), and compared on their throughput metric:
``requests_per_s`` (higher is better) when present, else the first
``*_us``/``us_per_*`` field (lower is better).  A fresh row that *grew* a
new identity field the committed copy predates (e.g. a sweep gains an
``inflight`` axis) still gates against its committed predecessor: when no
exact match exists, a base row whose identity is a strict subset of the
fresh row's -- same value on every field the committed row knows about --
is compared instead, provided the subset match is unambiguous (a single
base candidate).  Exact matches claim their baselines first, then widened
rows claim what remains first-come in emission order, so a benchmark that
fans one old row out into several new ones gates one of them and reports
the rest as added.  Rows present on only one side are
reported but never fail the gate -- a benchmark may legitimately emit
fewer rows in a reduced environment (e.g. the single-device CI job skips
the multi-device sweep) or grow new rows in the PR under test.

``BENCH_autotune_gain.json`` additionally carries an *intra-file* gate: its
tuned-plan rows (``plan`` analytic/measured) must stay at or above the
default-plan row's throughput within the tolerance -- an autotuner that
"wins" the search but loses the measurement is a cost-model bug, and the
gate catches it even when the file was not re-emitted this run (the
committed rows themselves must honor the invariant).
``BENCH_cold_start.json`` carries one too: the warm rows (warm disk cache /
``--warmup``) must remove >= 80% of the cold row's time-to-first-response,
minus tolerance slack -- a warm replica that still pays compile-scale
first-request latency is a persistent-cache regression.
``BENCH_goodput.json`` carries two ratio gates (machine-independent by
construction): past saturation (load_pct > 100) the admission="shed" rows
must hold >= 1.3x the admission="none" rows' goodput, and the WFQ fairness
row's worst-tenant goodput must hold >= 2x the FIFO row's -- both with the
tolerance as multiplicative slack.  Matched goodput rows additionally gate
on ``shed_frac``: the shed fraction may not grow more than 5 percentage
points (plus slack) over the committed row -- goodput held up by shedding
ever more traffic is a capacity regression the rps diff alone can hide.

A file whose content is byte-identical to HEAD was not re-emitted this run
and is skipped for the row-vs-HEAD diff.  The tolerance (default 25% from
the CI issue) can be loosened for noisy hosts with ``--tol 0.4`` or
``CHECK_BENCH_TOL=0.4``.

Exit codes: 0 ok / nothing comparable, 1 regression, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# (metric, higher_is_better) probed in order; first hit wins
METRIC_PREFERENCE = (
    ("requests_per_s", True),
    ("goodput_rps", True),
    ("achieved_flops", True),
    ("us_per_request", False),
    ("ttfr_ms", False),
    ("mm_engine_us", False),
    ("dle_scan_us", False),
    ("us_per_call", False),
    ("regret_frac", False),
    ("measured_frac", False),
)


def row_key(row: dict):
    """Identity of a row: every non-float field, sorted for determinism."""
    return tuple(sorted(
        (k, v) for k, v in row.items()
        if isinstance(v, (str, bool)) or (isinstance(v, int)
                                          and not isinstance(v, bool))))


def row_metric(row: dict, also_in: dict = None):
    """Throughput metric of ``row``; with ``also_in``, the first metric
    both rows carry (a row may grow a preferred metric the committed copy
    predates -- comparison needs a common one)."""
    for name, higher in METRIC_PREFERENCE:
        if isinstance(row.get(name), (int, float)) and (
                also_in is None
                or isinstance(also_in.get(name), (int, float))):
            return name, float(row[name]), higher
    return None


def iter_rows(doc: dict):
    """Every (section, row) of a BENCH doc: any top-level list of dicts.

    The ``provenance`` metadata block (git SHA, emission time, jax
    version -- see ``benchmarks/common.emit_json``) is explicitly not a
    row source: it describes the run, not a measurement, and must never
    enter the regression diff."""
    for section, val in sorted(doc.items()):
        if section == "provenance":
            continue
        if isinstance(val, list) and all(isinstance(r, dict) for r in val):
            for row in val:
                yield section, row


def committed_copy(name: str) -> str | None:
    r = subprocess.run(["git", "show", f"HEAD:{name}"], cwd=REPO_ROOT,
                       capture_output=True, text=True)
    return r.stdout if r.returncode == 0 else None


def pop_subset_match(base_rows: dict, section: str, fresh_key: tuple):
    """Claim the base row whose identity the fresh row's strictly extends.

    ``base_rows`` maps (section, key) -> row.  A base row is a candidate
    when it has an identity at all and every (field, value) of it also
    appears in the fresh row's identity -- i.e. the fresh row only *added*
    identity fields (an identity-less base row would be a "subset" of
    everything, so it never matches).  Exactly one candidate is required;
    ambiguity stays unmatched (better an added row than a wrong
    comparison).  The claimed row is popped so two fresh rows can never
    gate against the same baseline.
    """
    fresh_pairs = set(fresh_key)
    candidates = [k for k in base_rows
                  if k[0] == section and k[1] and set(k[1]) < fresh_pairs]
    if len(candidates) != 1:
        return None
    return base_rows.pop(candidates[0])


def autotune_gate(name: str, doc: dict, tol: float) -> tuple[list, bool]:
    """Intra-file invariant for BENCH_autotune_gain.json: every tuned-plan
    row must hold >= the default-plan row's throughput within ``tol``
    (the autotuner must never ship a plan that loses to the hand-picked
    default it searched against)."""
    rows = [r for _, r in iter_rows(doc) if isinstance(r.get("plan"), str)]
    defaults = [r for r in rows if r["plan"] == "default"
                and isinstance(r.get("requests_per_s"), (int, float))]
    if not defaults:
        return [f"{name}: no default-plan row; autotune gate skipped"], True
    base = max(float(r["requests_per_s"]) for r in defaults)
    lines, ok = [], True
    for r in rows:
        if r["plan"] == "default" or not isinstance(
                r.get("requests_per_s"), (int, float)):
            continue
        rps = float(r["requests_per_s"])
        ratio = rps / base if base > 0 else float("inf")
        verdict = "ok"
        if rps < base * (1.0 - tol):
            verdict, ok = "BELOW-DEFAULT", False
        lines.append(f"  {verdict:<13} tuned[{r['plan']}] "
                     f"{rps:.1f} vs default {base:.1f} rps "
                     f"({ratio:.2f}x)")
    header = (f"{name}: autotune gate (tuned >= default within "
              f"{tol * 100:.0f}%)")
    return [header] + lines, ok


def cold_start_gate(name: str, doc: dict, tol: float) -> tuple[list, bool]:
    """Intra-file invariant for BENCH_cold_start.json: every warm row
    (warm_disk / warmup) must remove >= 80% of the cold row's
    time-to-first-response, with the tolerance as slack on the remaining
    fraction (tol 0.25 -> warm TTFR must stay under 45% of cold).  A warm
    replica still paying compile-scale first-request latency means the
    persistent executable cache stopped doing its one job."""
    rows = [r for _, r in iter_rows(doc)
            if isinstance(r.get("mode"), str)
            and isinstance(r.get("ttfr_ms"), (int, float))]
    cold = [float(r["ttfr_ms"]) for r in rows if r["mode"] == "cold"]
    if not cold or min(cold) <= 0:
        return [f"{name}: no cold row; cold-start gate skipped"], True
    base = min(cold)
    ceiling = base * (0.2 + tol)
    lines, ok = [], True
    for r in rows:
        if r["mode"] == "cold":
            continue
        ttfr = float(r["ttfr_ms"])
        verdict = "ok"
        if ttfr > ceiling:
            verdict, ok = "STILL-COLD", False
        lines.append(f"  {verdict:<13} warm[{r['mode']}] ttfr "
                     f"{ttfr:.1f}ms vs cold {base:.1f}ms "
                     f"(reduction {1.0 - ttfr / base:.2f})")
    header = (f"{name}: cold-start gate (warm removes >= 80% of cold "
              f"TTFR, {tol * 100:.0f}% slack)")
    return [header] + lines, ok


def goodput_gate(name: str, doc: dict, tol: float) -> tuple[list, bool]:
    """Intra-file invariants for BENCH_goodput.json, both dimensionless
    ratios so they mean the same thing on any host:

      admission   past saturation (load_pct > 100) the admission="shed"
                  row must hold >= 1.3x the admission="none" row's
                  goodput at the same load -- admission control that no
                  longer beats unbounded queueing is dead weight.
      fairness    the WFQ row's worst-tenant goodput must hold >= 2x the
                  FIFO row's -- the whole point of per-tenant weighted
                  backlogs is that the mouse survives the whale.

    The tolerance is multiplicative slack on both thresholds."""
    rows = [r for _, r in iter_rows(doc)
            if isinstance(r.get("goodput_rps"), (int, float))]
    lines, ok = [], True

    by_load = {}
    for r in rows:
        if r.get("suite") == "load" and isinstance(r.get("load_pct"), int):
            by_load.setdefault(r["load_pct"], {})[r.get("admission")] = r
    checked = 0
    for load in sorted(by_load):
        pair = by_load[load]
        if load <= 100 or "shed" not in pair or "none" not in pair:
            continue
        checked += 1
        shed = float(pair["shed"]["goodput_rps"])
        none = float(pair["none"]["goodput_rps"])
        floor = 1.3 * (1.0 - tol)
        ratio = shed / none if none > 0 else float("inf")
        verdict = "ok"
        if ratio < floor:
            verdict, ok = "NO-ADMISSION-WIN", False
        lines.append(f"  {verdict:<16} load[{load}%] shed {shed:.1f} vs "
                     f"none {none:.1f} rps ({ratio:.2f}x, floor "
                     f"{floor:.2f}x)")

    fair = {r.get("scheduler"): r for r in rows
            if r.get("suite") == "fairness"}
    if "wfq" in fair and "fifo" in fair and all(
            isinstance(fair[s].get("worst_tenant_goodput_rps"),
                       (int, float)) for s in ("wfq", "fifo")):
        checked += 1
        wfq = float(fair["wfq"]["worst_tenant_goodput_rps"])
        fifo = float(fair["fifo"]["worst_tenant_goodput_rps"])
        floor = 2.0 * (1.0 - tol)
        ratio = wfq / fifo if fifo > 0 else float("inf")
        verdict = "ok"
        if ratio < floor:
            verdict, ok = "UNFAIR", False
        lines.append(f"  {verdict:<16} fairness wfq worst-tenant "
                     f"{wfq:.1f} vs fifo {fifo:.1f} rps ({ratio:.2f}x, "
                     f"floor {floor:.2f}x)")

    if not checked:
        return [f"{name}: no gateable rows; goodput gate skipped"], True
    header = (f"{name}: goodput gate (shed >= 1.3x none past saturation; "
              f"wfq worst-tenant >= 2x fifo; {tol * 100:.0f}% slack)")
    return [header] + lines, ok


def roofline_gate(name: str, doc: dict, tol: float) -> tuple[list, bool]:
    """Intra-file invariants for BENCH_roofline.json, the fused-kernel
    perf contract (ISSUE 9 acceptance):

      fusion   on the large fp32 covariance bucket, every fused row must
               beat the unfused block-streamed baseline by >= 1.15x
               device time -- a fused kernel that stops out-running the
               launch-per-block scan has lost its reason to exist.
      bf16     where the platform natively supports bf16 operand
               streaming (``bf16_supported`` -- TPU), the bf16 fused row
               must reach >= 1.3x the fp32 fused row's achieved FLOPs on
               the same (backend, bucket).  Rows measured on hosts that
               emulate bf16 (CPU) carry ``bf16_supported: false`` and are
               skipped with a note, never silently.

    The tolerance is multiplicative slack on both floors."""
    rows = [r for _, r in iter_rows(doc)
            if r.get("op") == "covariance"
            and isinstance(r.get("us_per_call"), (int, float))]
    lines, ok, checked = [], True, 0

    large = [r for r in rows
             if r.get("bucket") == "large" and r.get("precision") == "fp32"]
    unfused = {r.get("backend"): float(r["us_per_call"]) for r in large
               if r.get("variant") == "unfused"}
    if unfused:
        floor = 1.15 * (1.0 - tol)
        for r in large:
            if r.get("variant") != "fused":
                continue
            # same-backend baseline (what that server config runs without
            # fusion); kernel-less backends fall back to the plain-XLA scan
            backend = r.get("backend")
            base_us = unfused.get(backend, unfused.get("xla"))
            if base_us is None:
                continue
            checked += 1
            speedup = base_us / float(r["us_per_call"])
            verdict = "ok"
            if speedup < floor:
                verdict, ok = "FUSION-LOST", False
            lines.append(
                f"  {verdict:<13} fused[{backend}] "
                f"{float(r['us_per_call']):.0f}us vs unfused "
                f"{base_us:.0f}us ({speedup:.2f}x, floor {floor:.2f}x)")
    else:
        lines.append("  no unfused large-bucket row; fusion gate skipped")

    fused = {}
    for r in rows:
        if r.get("variant") == "fused":
            fused[(r.get("backend"), r.get("bucket"),
                   r.get("precision"))] = r
    bf16_checked = 0
    for (backend, bucket, precision), r in sorted(fused.items()):
        if precision != "bf16_fp32acc":
            continue
        base_row = fused.get((backend, bucket, "fp32"))
        if base_row is None:
            continue
        if not r.get("bf16_supported"):
            lines.append(f"  skipped       bf16[{backend}/{bucket}] "
                         f"(platform emulates bf16; no native win to hold)")
            continue
        checked += 1
        bf16_checked += 1
        floor = 1.3 * (1.0 - tol)
        ratio = (float(r["achieved_flops"])
                 / float(base_row["achieved_flops"]))
        verdict = "ok"
        if ratio < floor:
            verdict, ok = "NO-BF16-WIN", False
        lines.append(f"  {verdict:<13} bf16[{backend}/{bucket}] "
                     f"{ratio:.2f}x fp32 achieved FLOPs "
                     f"(floor {floor:.2f}x)")

    if not checked and not lines:
        return [f"{name}: no gateable rows; roofline gate skipped"], True
    header = (f"{name}: roofline gate (fused >= 1.15x unfused on large "
              f"fp32; bf16 >= 1.3x fp32 where native; "
              f"{tol * 100:.0f}% slack)")
    return [header] + lines, ok


def controller_gate(name: str, doc: dict, tol: float) -> tuple[list, bool]:
    """Intra-file invariants for BENCH_controller_regret.json (the
    autonomous-controller acceptance, machine-independent: the regret
    timeline runs under a virtual clock against a pinned cost model):

      regret   every suite="regret" row must hold regret_frac <= 0.10 --
               the controller captures >= 90% of the clairvoyant
               re-tuner's advantage over the static default plan.  The
               tolerance is multiplicative slack on the ceiling.
      thrash   the same rows must show swaps <= 3: adaptation, not
               oscillation.  No slack -- swap counts are deterministic.
      prune    every suite="prune" row must hold measured_evals <=
               budget_frac * grid_size (the successive-halving bandit's
               whole point vs the exhaustive measured grid).  No slack --
               eval counts are deterministic."""
    lines, ok, checked = [], True, 0
    for _, r in iter_rows(doc):
        suite = r.get("suite")
        if suite == "regret" and isinstance(r.get("regret_frac"),
                                            (int, float)):
            checked += 1
            regret = float(r["regret_frac"])
            ceiling = 0.10 * (1.0 + tol)
            verdict = "ok"
            if regret > ceiling:
                verdict, ok = "HIGH-REGRET", False
            lines.append(f"  {verdict:<13} regret[{r.get('scenario')}] "
                         f"{regret:.4f} (ceiling {ceiling:.4f})")
            swaps = r.get("swaps")
            if isinstance(swaps, int):
                verdict = "ok"
                if swaps > 3:
                    verdict, ok = "THRASHING", False
                lines.append(f"  {verdict:<13} swaps[{r.get('scenario')}] "
                             f"{swaps} (max 3)")
        elif suite == "prune" and isinstance(r.get("measured_evals"), int):
            checked += 1
            grid = int(r.get("grid_size", 0))
            budget = float(r.get("budget_frac", 0.25))
            cap = int(budget * grid)
            verdict = "ok"
            if grid and r["measured_evals"] > cap:
                verdict, ok = "NO-PRUNING", False
            lines.append(f"  {verdict:<13} prune[{r.get('scenario')}] "
                         f"{r['measured_evals']} measured evals vs "
                         f"grid {grid} (cap {cap})")
    if not checked:
        return [f"{name}: no gateable rows; controller gate skipped"], True
    header = (f"{name}: controller gate (regret <= 0.10 with "
              f"{tol * 100:.0f}% slack; swaps <= 3; measured evals <= "
              f"budget_frac * grid)")
    return [header] + lines, ok


def compare_file(name: str, tol: float) -> tuple[list, bool]:
    """Returns (report lines, ok)."""
    fresh_path = REPO_ROOT / name
    if not fresh_path.exists():
        return [f"{name}: absent from working tree; skipped"], True
    fresh_text = fresh_path.read_text()
    extra_lines: list = []
    extra_ok = True
    if name == "BENCH_autotune_gain.json":
        # intra-file gates run on the working-tree copy whether or not it
        # was re-emitted: committed rows must honor the invariant too
        extra_lines, extra_ok = autotune_gate(name, json.loads(fresh_text),
                                              tol)
    elif name == "BENCH_cold_start.json":
        extra_lines, extra_ok = cold_start_gate(name,
                                                json.loads(fresh_text), tol)
    elif name == "BENCH_goodput.json":
        extra_lines, extra_ok = goodput_gate(name, json.loads(fresh_text),
                                             tol)
    elif name == "BENCH_roofline.json":
        extra_lines, extra_ok = roofline_gate(name, json.loads(fresh_text),
                                              tol)
    elif name == "BENCH_controller_regret.json":
        extra_lines, extra_ok = controller_gate(name,
                                                json.loads(fresh_text), tol)
    base_text = committed_copy(name)
    if base_text is None:
        return ([f"{name}: not in HEAD (new benchmark); diff skipped"]
                + extra_lines), extra_ok
    if fresh_text == base_text:
        return ([f"{name}: identical to HEAD (not re-emitted); diff "
                 f"skipped"] + extra_lines), extra_ok
    lines, ok = compare_docs(name, json.loads(base_text),
                             json.loads(fresh_text), tol)
    return lines + extra_lines, ok and extra_ok


def compare_docs(name: str, base_doc: dict, fresh_doc: dict,
                 tol: float) -> tuple[list, bool]:
    """Diff two BENCH documents row-by-row; returns (report lines, ok)."""
    base_rows = {}
    for section, row in iter_rows(base_doc):
        base_rows[(section, row_key(row))] = row

    # two passes: every exact identity match claims its baseline first, so
    # a widened row can never steal the base row an exact fresh row needs
    fresh = [(section, row, row_key(row))
             for section, row in iter_rows(fresh_doc)]
    matches = {}
    for i, (section, row, key) in enumerate(fresh):
        base = base_rows.pop((section, key), None)
        if base is not None:
            matches[i] = (base, False)
    for i, (section, row, key) in enumerate(fresh):
        if i not in matches:
            base = pop_subset_match(base_rows, section, key)
            if base is not None:
                matches[i] = (base, True)

    lines, ok, compared = [], True, 0
    for i, (section, row, key) in enumerate(fresh):
        ident = ", ".join(f"{k}={v}" for k, v in key) or "<no id>"
        base, widened = matches.get(i, (None, False))
        if widened:
            ident += " (identity widened)"
        if base is None:
            lines.append(f"  NEW     {section}[{ident}]")
            continue
        metric = row_metric(row, also_in=base)
        if metric is None:
            lines.append(f"  NOMETRIC {section}[{ident}]")
            continue
        mname, fresh_v, higher = metric
        base_v = float(base[mname])
        if base_v <= 0:
            continue
        compared += 1
        # delta > 0 is always an improvement, < 0 a regression
        delta = ((fresh_v - base_v) / base_v if higher
                 else (base_v - fresh_v) / base_v)
        verdict = "ok"
        if delta < -tol:
            verdict = "REGRESSION"
            ok = False
        lines.append(
            f"  {verdict:<10} {section}[{ident}] {mname}: "
            f"{base_v:.1f} -> {fresh_v:.1f} ({delta * 100:+.1f}%)")
        # shed_frac band: goodput held up by shedding ever more traffic is
        # a capacity regression the rps diff alone can hide
        if isinstance(row.get("shed_frac"), (int, float)) and isinstance(
                base.get("shed_frac"), (int, float)):
            grew = float(row["shed_frac"]) - float(base["shed_frac"])
            band = 0.05 + 0.2 * tol
            if grew > band:
                ok = False
                lines.append(
                    f"  SHED-GREW  {section}[{ident}] shed_frac: "
                    f"{float(base['shed_frac']):.3f} -> "
                    f"{float(row['shed_frac']):.3f} "
                    f"(+{grew * 100:.1f}pp > {band * 100:.1f}pp band)")
    for (section, key), _ in sorted(base_rows.items()):
        ident = ", ".join(f"{k}={v}" for k, v in key) or "<no id>"
        lines.append(f"  MISSING {section}[{ident}] (not emitted this run)")
    header = (f"{name}: {compared} rows compared, tolerance "
              f"{tol * 100:.0f}%")
    return [header] + lines, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json names (default: every tracked one)")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("CHECK_BENCH_TOL", "0.25")),
                    help="allowed fractional throughput regression "
                         "(default 0.25, env CHECK_BENCH_TOL)")
    args = ap.parse_args(argv)
    if args.tol < 0:
        ap.error("--tol must be >= 0")

    names = args.files
    if not names:
        r = subprocess.run(["git", "ls-files", "BENCH_*.json"],
                           cwd=REPO_ROOT, capture_output=True, text=True)
        if r.returncode != 0:
            print("check_bench: git unavailable and no files given",
                  file=sys.stderr)
            return 2
        names = r.stdout.split()
    if not names:
        print("check_bench: no BENCH_*.json files to compare")
        return 0

    all_ok = True
    for name in names:
        lines, ok = compare_file(name, args.tol)
        print("\n".join(lines))
        all_ok = all_ok and ok
    print("check_bench:", "OK" if all_ok else "FAILED (throughput "
          "regression beyond tolerance; see REGRESSION rows above)")
    return 0 if all_ok else 1


if __name__ == "__main__":
    sys.exit(main())
