"""Step-time watchdog: stall detection + straggler accounting.

At 1000+ node scale the failure modes that matter are (a) a hung collective
(one node died -> every node blocks forever) and (b) chronic stragglers.
The watchdog arms a timer around every step; if a step exceeds
``stall_factor`` x the EWMA step time (plus a floor), the registered
callback fires -- the trainer uses it to flush an emergency checkpoint and
exit with a distinct code the cluster scheduler maps to "restart from last
checkpoint".  Straggler steps (> ``straggler_factor`` x EWMA) are logged
with their step index for post-hoc correlation with host metrics.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

STALL_EXIT_CODE = 42  # scheduler contract: restart from latest checkpoint


@dataclasses.dataclass
class StragglerRecord:
    step: int
    seconds: float
    ewma: float


class Watchdog:
    def __init__(self, stall_factor: float = 10.0, floor_s: float = 30.0,
                 straggler_factor: float = 2.0,
                 on_stall: Optional[Callable[[], None]] = None):
        self.stall_factor = stall_factor
        self.floor_s = floor_s
        self.straggler_factor = straggler_factor
        self.on_stall = on_stall
        self.ewma: Optional[float] = None
        self.stragglers: List[StragglerRecord] = []
        self._timer: Optional[threading.Timer] = None
        self._t0 = 0.0
        self._step = 0
        self.stalled = False

    # -- per-step protocol ---------------------------------------------------

    def start_step(self, step: int):
        self._step = step
        self._t0 = time.monotonic()
        budget = max(self.floor_s,
                     (self.ewma or self.floor_s) * self.stall_factor)
        self._timer = threading.Timer(budget, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def end_step(self):
        if self._timer:
            self._timer.cancel()
            self._timer = None
        dt = time.monotonic() - self._t0
        if self.ewma is not None and dt > self.straggler_factor * self.ewma:
            self.stragglers.append(StragglerRecord(self._step, dt, self.ewma))
        self.ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        return dt

    def _fire(self):
        self.stalled = True
        if self.on_stall:
            self.on_stall()

    def summary(self) -> dict:
        return {
            "ewma_step_s": self.ewma,
            "n_stragglers": len(self.stragglers),
            "stragglers": [dataclasses.asdict(s)
                           for s in self.stragglers[-16:]],
        }
