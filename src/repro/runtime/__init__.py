from .elastic import pick_mesh, resume_or_init
from .watchdog import STALL_EXIT_CODE, Watchdog
