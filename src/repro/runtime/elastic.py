"""Elastic restart: resume training on whatever mesh is currently healthy.

Checkpoints store *logical* (global) arrays, so resuming only needs a new
sharding tree for the new mesh -- ``checkpointer.restore`` device_puts each
leaf onto it.  ``pick_mesh`` chooses the largest (data x model) grid the
surviving device set supports with model-dim divisibility constraints, and
``resume_or_init`` wires it together.  Data-pipeline cursors live in
checkpoint metadata, so no examples are skipped or repeated on restart.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.checkpoint import checkpointer


def pick_mesh(model_parallel: int, devices=None):
    """Largest (data, model) mesh over the available devices."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    tp = model_parallel
    while tp > 1 and (n % tp or model_parallel % tp):
        tp -= 1
    dp = n // tp
    return jax.make_mesh((dp, tp), ("data", "model"),
                         devices=devices[: dp * tp])


def resume_or_init(ckpt_dir, state_like, shardings, init_fn,
                   step: Optional[int] = None):
    """Restore the latest checkpoint onto the current mesh, or initialise.

    Returns (state, metadata, resumed: bool).
    """
    latest = checkpointer.latest_step(ckpt_dir)
    if latest is None:
        return init_fn(), {}, False
    state, meta = checkpointer.restore(ckpt_dir, state_like, step=step,
                                       shardings=shardings)
    return state, meta, True
