"""Elastic restart: resume training on whatever mesh is currently healthy.

Checkpoints store *logical* (global) arrays, so resuming only needs a new
sharding tree for the new mesh -- ``checkpointer.restore`` device_puts each
leaf onto it.  ``pick_mesh`` chooses the largest (data x model) grid the
surviving device set supports with model-dim divisibility constraints, and
``resume_or_init`` wires it together.  Data-pipeline cursors live in
checkpoint metadata, so no examples are skipped or repeated on restart.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.checkpoint import checkpointer


def pick_mesh(model_parallel: int, devices=None, global_batch=None):
    """Largest (data, model) mesh over the available devices.

    ``global_batch`` caps the data axis: batch-dim sharding needs
    ``global_batch % dp == 0``, so dp shrinks to the largest divisor of the
    batch that the devices support (a reduced 4-sample smoke on an 8-device
    host gets a (4, tp) mesh and leaves the surplus devices idle, instead
    of failing the divisibility check at dispatch).
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    tp = model_parallel
    while tp > 1 and (n % tp or model_parallel % tp):
        tp -= 1
    dp = n // tp
    if global_batch is not None:
        dp = min(dp, global_batch)
        while dp > 1 and global_batch % dp:
            dp -= 1
    return jax.make_mesh((dp, tp), ("data", "model"),
                         devices=devices[: dp * tp])


def resume_or_init(ckpt_dir, state_like, shardings, init_fn,
                   step: Optional[int] = None):
    """Restore the latest checkpoint onto the current mesh, or initialise.

    Returns (state, metadata, resumed: bool).
    """
    latest = checkpointer.latest_step(ckpt_dir)
    if latest is None:
        return init_fn(), {}, False
    state, meta = checkpointer.restore(ckpt_dir, state_like, step=step,
                                       shardings=shardings)
    return state, meta, True
