"""MANOJAVAM core: unified matmul + Jacobi-SVD engine for PCA."""
from .covariance import (blocked_covariance, covariance,
                         distributed_covariance, standardize)
from .cordic import (ANGLE_MODES, cordic_atan2, cordic_sincos,
                     rotation_params, rotation_params_cordic,
                     rotation_params_rutishauser)
from .dle import Pivot, find_pivot, find_pivot_tilewise
from .jacobi import (DEFAULT_SWEEPS, EighResult, jacobi_eigh, jacobi_svd,
                     offdiag_frobenius, relative_offdiag, round_robin_rounds)
from .pca import (PAPER_CONFIG_ARTIX7, PAPER_CONFIG_VUS, PCAConfig, PCAResult,
                  evcr_cvcr, fit, fit_distributed, fit_transform, select_k,
                  transform)
from .schedule import PAPER_SCHEDULE, SweepSchedule, convergence_curve
from . import memory_model

__all__ = [
    "ANGLE_MODES", "DEFAULT_SWEEPS", "EighResult", "PAPER_CONFIG_ARTIX7",
    "PAPER_CONFIG_VUS", "PAPER_SCHEDULE", "PCAConfig", "PCAResult", "Pivot",
    "SweepSchedule", "blocked_covariance", "convergence_curve", "cordic_atan2",
    "cordic_sincos", "covariance", "distributed_covariance", "evcr_cvcr",
    "find_pivot", "find_pivot_tilewise", "fit", "fit_distributed",
    "fit_transform", "jacobi_eigh", "jacobi_svd", "memory_model",
    "offdiag_frobenius", "relative_offdiag", "rotation_params",
    "rotation_params_cordic", "rotation_params_rutishauser",
    "round_robin_rounds", "select_k", "standardize", "transform",
]
