"""Data Lookup Engine (DLE): max-|off-diagonal| pivot search.

The hardware DLE streams accumulator output tiles and finds the maximum
off-diagonal element c_pq plus the matching diagonal elements c_pp / c_qq in a
single pass, masking main-diagonal entries only inside diagonal tiles
("tile-aware filtering", Sec. VI-C).  ``find_pivot`` is the flat functional
form used by the solver; ``find_pivot_tilewise`` reproduces the streaming
tile-by-tile scan and is the oracle for ``kernels/dle.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class Pivot(NamedTuple):
    p: jnp.ndarray          # row index (scalar int32)
    q: jnp.ndarray          # col index (scalar int32)
    apq: jnp.ndarray        # C[p, q]
    app: jnp.ndarray        # C[p, p]
    aqq: jnp.ndarray        # C[q, q]


def find_pivot(C) -> Pivot:
    """Global max |off-diagonal| element of a symmetric matrix."""
    n = C.shape[0]
    offdiag = jnp.abs(C) * (1.0 - jnp.eye(n, dtype=C.dtype))
    idx = jnp.argmax(offdiag)
    p = (idx // n).astype(jnp.int32)
    q = (idx % n).astype(jnp.int32)
    d = jnp.diagonal(C)
    return Pivot(p, q, C[p, q], d[p], d[q])


def find_pivot_tilewise(C, tile: int) -> Pivot:
    """Streaming-scan semantics: per-tile max with tile-aware diagonal
    masking, then a final reduce over tiles.  Bit-identical result to
    ``find_pivot`` (up to argmax tie order) but structured the way the DLE
    consumes accumulator tiles.
    """
    n = C.shape[0]
    if n % tile:
        pad = tile - n % tile
        C = jnp.pad(C, ((0, pad), (0, pad)))
        np_ = n + pad
    else:
        np_ = n
    g = np_ // tile
    # (g, g, tile, tile) tile view
    tiles = C.reshape(g, tile, g, tile).transpose(0, 2, 1, 3)
    ii = jnp.arange(tile)
    local_eye = (ii[:, None] == ii[None, :])
    # diagonal entries only exist in tiles with row-block == col-block:
    block_diag = (jnp.arange(g)[:, None] == jnp.arange(g)[None, :])
    mask = block_diag[:, :, None, None] & local_eye[None, None, :, :]
    valid = C.shape  # noqa: F841  (documentation anchor)
    mag = jnp.where(mask, 0.0, jnp.abs(tiles))
    # also mask padded region
    row_ids = (jnp.arange(g) * tile)[:, None, None, None] + ii[None, None, :, None]
    col_ids = (jnp.arange(g) * tile)[None, :, None, None] + ii[None, None, None, :]
    mag = jnp.where((row_ids < n) & (col_ids < n), mag, 0.0)
    # per-tile reduce (what each accumulator-port comparator does) ...
    tile_max = mag.max(axis=(2, 3))
    tile_arg = mag.reshape(g, g, tile * tile).argmax(axis=2)
    # ... then the global reduce over the tile stream
    flat = tile_max.reshape(-1)
    best_tile = jnp.argmax(flat)
    bi = best_tile // g
    bj = best_tile % g
    loc = tile_arg[bi, bj]
    p = (bi * tile + loc // tile).astype(jnp.int32)
    q = (bj * tile + loc % tile).astype(jnp.int32)
    d = jnp.diagonal(C)
    return Pivot(p, q, C[p, q], d[p], d[q])
