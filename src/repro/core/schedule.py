"""Deterministic sweep scheduling + Frobenius-norm convergence study.

The paper replaces on-chip convergence monitoring (a full-matrix
sqrt-of-sum-of-squares pipeline that would cost Fmax and routing) with an
offline Frobenius-norm study establishing a fixed 50-sweep schedule
(Sec. V, Sec. VII-D).  This module is that offline study, plus the schedule
object the accelerating code consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from .jacobi import DEFAULT_SWEEPS, jacobi_eigh, relative_offdiag
from .covariance import covariance, standardize


@dataclasses.dataclass(frozen=True)
class SweepSchedule:
    """Fixed-iteration schedule (hardware mode) or tolerance mode (software).

    ``sweeps`` is the deterministic upper bound; ``tol=None`` reproduces the
    hardware's fixed-latency behaviour.
    """
    sweeps: int = DEFAULT_SWEEPS
    tol: Optional[float] = None

    def kwargs(self) -> Dict:
        return {"sweeps": self.sweeps, "tol": self.tol}


PAPER_SCHEDULE = SweepSchedule(sweeps=DEFAULT_SWEEPS, tol=None)


def convergence_curve(
    X: np.ndarray,
    sweeps: int = 25,
    pivot: str = "parallel",
    angle: str = "rutishauser",
) -> np.ndarray:
    """Relative off-diagonal energy after each sweep (paper Fig. 8).

    Returns an array of length sweeps+1 (index 0 = before any sweep).
    """
    Xs, _, _ = standardize(jnp.asarray(X, jnp.float32))
    C = covariance(Xs)
    res = jacobi_eigh(C, sweeps=sweeps, pivot=pivot, angle=angle,
                      track_history=True)
    return np.asarray(res.history)


def sweeps_to_tolerance(curve: np.ndarray, tol: float = 1e-6) -> int:
    """First sweep index at which the relative off-norm drops below tol
    (returns len(curve) if never)."""
    below = np.nonzero(curve <= tol)[0]
    return int(below[0]) if below.size else len(curve)


def make_ill_conditioned(n: int, d: int, cluster_gap: float = 1e-6,
                         seed: int = 0) -> np.ndarray:
    """Synthetic dataset with tightly clustered eigenvalues -- the
    ill-conditioned regime the 50-sweep safety factor is sized for."""
    rng = np.random.default_rng(seed)
    # eigenvalues clustered in pairs separated by cluster_gap
    base = np.repeat(np.linspace(1.0, 2.0, d // 2 + 1)[: (d + 1) // 2], 2)[:d]
    eigs = base + cluster_gap * rng.standard_normal(d)
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    cov_sqrt = Q * np.sqrt(np.abs(eigs))
    return (rng.standard_normal((n, d)) @ cov_sqrt.T).astype(np.float32)
