"""Mixed-precision policy for the PCA hot path.

MANOJAVAM runs fixed-point datapaths sized to the workload; the TPU analog
is reduced-precision *operand streaming* with guarded accumulation (the
standard throughput lever in the related FPGA-PCA literature -- Martel et
al.'s hyperspectral PCA, Burrello et al.'s embedded PCA).  Three policies:

  ``fp32``          fp32 operands, fp32 accumulation.  The default and the
                    bitwise baseline every fused kernel is tested against.
  ``bf16_fp32acc``  bf16 operand streaming into fp32 accumulators for the
                    covariance/Gram products (half the HBM bytes on the
                    bandwidth-bound leg).  Jacobi rotations, angles and the
                    U = A V back-projection stay fp32: rotation numerics
                    are what convergence rests on, and they are
                    compute-light -- all the bandwidth is in the Gram pass.
  ``fp64``          the reference lane.  Requires an ``JAX_ENABLE_X64=1``
                    process; error budgets are measured against it via the
                    subprocess idiom (``run_fp64_oracle``), so the serving
                    process never has to flip the global x64 switch.

``ERROR_BUDGETS`` documents the relative-Frobenius-error ceiling of each
(policy, op) against the fp64 oracle.  Measured typical errors on the
benchmark suites sit 4-10x below these ceilings (bf16 covariance ~1e-3 to
4e-3; fp32 ~1e-7); ``tests/test_precision.py`` enforces them and
``benchmarks/fig8_frobenius.py`` reports the measured values per release.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from typing import Dict

import numpy as np
import jax.numpy as jnp

PRECISIONS = ("fp32", "bf16_fp32acc", "fp64")

# relative Frobenius error vs the fp64 oracle, per (precision, op).
# "covariance" is ||C - C64|| / ||C64||; "eigh" is the eigenvalue-vector
# error; "svd" the singular-value-vector error (eigenvectors/singular
# vectors are compared through the subspaces they span, not budgeted here).
ERROR_BUDGETS: Dict[str, Dict[str, float]] = {
    "fp32": {"covariance": 1e-5, "eigh": 1e-4, "svd": 1e-4},
    "bf16_fp32acc": {"covariance": 2e-2, "eigh": 2e-2, "svd": 2e-2},
    "fp64": {"covariance": 0.0, "eigh": 0.0, "svd": 0.0},
}


def validate(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}")
    return precision


def operand_dtype(precision: str):
    """The dtype operands *stream* at (HBM-side) under a policy."""
    validate(precision)
    if precision == "bf16_fp32acc":
        return jnp.bfloat16
    if precision == "fp64":
        return jnp.float64
    return jnp.float32


def acc_dtype(precision: str):
    """The accumulator dtype -- never narrower than fp32."""
    validate(precision)
    return jnp.float64 if precision == "fp64" else jnp.float32


def supports_x64() -> bool:
    """Whether this process can hold a real float64 (x64 enabled)."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # probe, not a request: the
        return jnp.asarray(0.0, jnp.float64).dtype == jnp.float64  # truncation IS the answer


_ORACLE_SCRIPT = r"""
import json, sys
import numpy as np
import jax.numpy as jnp
from repro.core.covariance import covariance, standardize
from repro.core.jacobi import jacobi_eigh, jacobi_svd

inp, out = sys.argv[1], sys.argv[2]
data = np.load(inp)
X = jnp.asarray(data["X"], jnp.float64)
op = str(data["op"])
res = {"x64": bool(jnp.asarray(0.0, jnp.float64).dtype == jnp.float64)}
if op == "covariance":
    C = covariance(X)
    np.savez(out, C=np.asarray(C))
elif op == "eigh":
    C = covariance(X)
    r = jacobi_eigh(C, sweeps=int(data["sweeps"]))
    np.savez(out, eigenvalues=np.asarray(r.eigenvalues),
             eigenvectors=np.asarray(r.eigenvectors))
elif op == "svd":
    U, s, Vt = jacobi_svd(X, sweeps=int(data["sweeps"]))
    np.savez(out, U=np.asarray(U), S=np.asarray(s), Vt=np.asarray(Vt))
else:
    raise SystemExit(f"unknown op {op}")
print(json.dumps(res))
"""


def run_fp64_oracle(X: np.ndarray, op: str, sweeps: int = 50,
                    timeout: float = 600.0) -> Dict[str, np.ndarray]:
    """Compute the fp64 reference for ``op`` in a ``JAX_ENABLE_X64=1``
    subprocess (SNIPPETS snippet-1 idiom: the x64 switch is global and
    read at jax import, so the serving process cannot flip it for one
    call -- a child process can).

    Returns the result arrays as float64 numpy.  Raises on any subprocess
    failure: a missing oracle must fail the caller loudly, not silently
    compare against garbage.
    """
    if op not in ("covariance", "eigh", "svd"):
        raise ValueError(f"unknown oracle op {op!r}")
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as td:
        inp = os.path.join(td, "in.npz")
        out = os.path.join(td, "out.npz")
        np.savez(inp, X=np.asarray(X, np.float64), op=op, sweeps=sweeps)
        proc = subprocess.run(
            [sys.executable, "-c", _ORACLE_SCRIPT, inp, out],
            env=env, capture_output=True, text=True, timeout=timeout)
        if proc.returncode != 0:
            raise RuntimeError(
                f"fp64 oracle subprocess failed:\n{proc.stderr[-2000:]}")
        header = json.loads(proc.stdout.strip().splitlines()[-1])
        if not header.get("x64"):
            raise RuntimeError("fp64 oracle subprocess did not get x64 "
                               "dtypes (JAX_ENABLE_X64 ignored?)")
        with np.load(out) as z:
            return {k: np.asarray(z[k]) for k in z.files}


def rel_frobenius(a: np.ndarray, b: np.ndarray) -> float:
    """||a - b||_F / ||b||_F (b is the reference)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    denom = max(float(np.linalg.norm(b)), 1e-30)
    return float(np.linalg.norm(a - b)) / denom
