"""Rotation-parameter computation for Jacobi sweeps.

The paper computes theta = 1/2 * atan(2*c_pq / (c_pp - c_qq)) with a pipelined
CORDIC arctangent unit followed by a 1-bit right shift, then sin/cos with two
parallel CORDIC rotators (Sec. VI-C).  On TPU there is no CORDIC block; the VPU
executes the shift-add iterations SIMD-style across every concurrent pivot.
This module provides

  * ``rotation_params``            -- float atan2 formulation (fast mode)
  * ``rotation_params_rutishauser``-- Golub&Van-Loan stable t-formula
  * ``rotation_params_cordic``     -- fixed-point (Q2.29) CORDIC, bit-faithful
                                      to the hardware datapath
  * ``cordic_atan2`` / ``cordic_sincos`` -- the underlying engines

Sign convention (note: the paper's eq.(6)+(7) pair has a sign slip -- applying
R from eq.(7) with theta from eq.(6) does NOT annihilate c_pq; see DESIGN.md):
we keep the paper's R (R[p,p]=R[q,q]=cos, R[p,q]=sin, R[q,p]=-sin) and use

    theta = -1/2 * atan2(2*c_pq, c_pp - c_qq)

which zeroes the pivot exactly under C' = R^T C R.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

# Number of CORDIC micro-rotations (paper: pipelined stages).  30 iterations
# in Q2.29 reaches ~2^-29 angle granularity, comfortably below fp32 eps for
# the downstream rotation.
CORDIC_ITERS = 30
_FRAC_BITS = 29
_ONE = np.int64(1) << _FRAC_BITS
# CORDIC gain K = prod(sqrt(1 + 2^-2i)); we multiply by 1/K up front.
_GAIN = float(np.prod([np.sqrt(1.0 + 2.0 ** (-2 * i)) for i in range(CORDIC_ITERS)]))
_ATAN_TABLE = np.array(
    [np.arctan(2.0 ** -i) for i in range(CORDIC_ITERS)], dtype=np.float64
)
_ATAN_FIXED = np.round(_ATAN_TABLE * _ONE).astype(np.int32)


def rotation_params(apq, app, aqq):
    """theta, cos, sin such that R^T C R zeroes c_pq (paper R convention)."""
    theta = -0.5 * jnp.arctan2(2.0 * apq, app - aqq)
    return theta, jnp.cos(theta), jnp.sin(theta)


def rotation_params_rutishauser(apq, app, aqq):
    """Numerically-stable small-angle rotation (|theta| <= pi/4).

    Solves t^2 + 2*tau*t - 1 = 0 with tau = (app - aqq) / (2*apq) for the
    root of smaller magnitude.  Matches the paper's R convention: with
    s = t*c the update C' = R^T C R zeroes c_pq.
    """
    safe = jnp.abs(apq) > 0.0
    tau = (app - aqq) / jnp.where(safe, 2.0 * apq, 1.0)
    sgn = jnp.where(tau >= 0.0, 1.0, -1.0)
    t = sgn / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
    # sign fix: for our convention theta = -1/2 atan2(2 apq, app-aqq);
    # the G&VL root corresponds to s_gvl = -s_ours, so negate.
    t = jnp.where(safe, -t, 0.0)
    c = 1.0 / jnp.sqrt(1.0 + t * t)
    s = t * c
    theta = jnp.arctan(t)
    return theta, c, s


# ---------------------------------------------------------------------------
# Fixed-point CORDIC (vectorised; mirrors the RTL datapath)
# ---------------------------------------------------------------------------

# Q2.29 in int32: |values| stay below 2^31 through both CORDIC modes
# (vectoring norm growth <= K*sqrt(2)*2^29 ~ 1.25e9), matching the 32-bit
# RTL datapath.


def _to_fixed(x):
    return jnp.round(x * float(_ONE)).astype(jnp.int32)


def _from_fixed(x):
    return x.astype(jnp.float32) / float(_ONE)


def cordic_atan2(y, x, iters: int = CORDIC_ITERS, unroll: bool = False):
    """Vectorised vectoring-mode CORDIC: atan2(y, x) for x of any sign.

    Inputs are floats; they are normalised into Q2.29 exactly as the RTL
    front-end scales operands into its fixed-point format (a shared scale
    leaves the angle unchanged).

    ``unroll`` replaces the ``fori_loop`` over the angle table with an
    unrolled loop whose per-stage constants are python ints -- required
    inside a Pallas kernel body, which cannot capture a constant device
    array.  The micro-rotations are pure int32 arithmetic, so both
    spellings are bit-identical.
    """
    y = jnp.asarray(y, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    mag = jnp.maximum(jnp.maximum(jnp.abs(y), jnp.abs(x)), 1e-30)
    # shared power-of-two normalisation (a barrel shift in hardware)
    scale = jnp.exp2(-jnp.ceil(jnp.log2(mag)))
    yn = y * scale
    xn = x * scale
    # quadrant fold: vectoring CORDIC converges for x > 0
    neg_x = xn < 0
    xq = jnp.where(neg_x, -xn, xn)
    yq = jnp.where(neg_x, -yn, yn)
    xi = _to_fixed(xq)
    yi = _to_fixed(yq)
    zi = jnp.zeros_like(xi)

    def body(i, carry, step):
        xi, yi, zi = carry
        d = jnp.where(yi >= 0, 1, -1).astype(jnp.int32)
        x_new = xi + d * (yi >> i)
        y_new = yi - d * (xi >> i)
        z_new = zi + d * step
        return x_new, y_new, z_new

    if unroll:
        carry = (xi, yi, zi)
        for i in range(iters):
            carry = body(i, carry, jnp.int32(int(_ATAN_FIXED[i])))
        xi, yi, zi = carry
    else:
        # the table must only materialise on this branch: a constant device
        # array would be captured by a Pallas kernel trace even when unused
        atan_tab = jnp.asarray(_ATAN_FIXED)
        xi, yi, zi = lax.fori_loop(
            0, iters, lambda i, c: body(i, c, atan_tab[i]), (xi, yi, zi))
    ang = _from_fixed(zi)
    # unfold quadrant: atan2(y,x) = atan2(-y,-x) +/- pi
    pi = jnp.float32(np.pi)
    ang = jnp.where(neg_x, jnp.where(y >= 0, ang + pi, ang - pi), ang)
    return ang


def cordic_sincos(theta, iters: int = CORDIC_ITERS, unroll: bool = False):
    """Vectorised rotation-mode CORDIC: (sin, cos) of theta in (-pi, pi].

    ``unroll`` as in ``cordic_atan2`` (Pallas-kernel-safe spelling)."""
    theta = jnp.asarray(theta, jnp.float32)
    half_pi = jnp.float32(np.pi / 2)
    # fold into (-pi/2, pi/2]; CORDIC rotation converges for |z| < ~1.74 rad
    fold_hi = theta > half_pi
    fold_lo = theta < -half_pi
    th = jnp.where(fold_hi, theta - jnp.float32(np.pi),
                   jnp.where(fold_lo, theta + jnp.float32(np.pi), theta))
    flip = fold_hi | fold_lo

    zi = _to_fixed(th)
    xi = jnp.broadcast_to(_to_fixed(jnp.float32(1.0 / _GAIN)), zi.shape).astype(jnp.int32)
    yi = jnp.zeros_like(xi)

    def body(i, carry, step):
        xi, yi, zi = carry
        d = jnp.where(zi >= 0, 1, -1).astype(jnp.int32)
        x_new = xi - d * (yi >> i)
        y_new = yi + d * (xi >> i)
        z_new = zi - d * step
        return x_new, y_new, z_new

    if unroll:
        carry = (xi, yi, zi)
        for i in range(iters):
            carry = body(i, carry, jnp.int32(int(_ATAN_FIXED[i])))
        xi, yi, zi = carry
    else:
        # see cordic_atan2: keep the constant table off the unroll branch
        atan_tab = jnp.asarray(_ATAN_FIXED)
        xi, yi, zi = lax.fori_loop(
            0, iters, lambda i, c: body(i, c, atan_tab[i]), (xi, yi, zi))
    sin = _from_fixed(yi)
    cos = _from_fixed(xi)
    sign = jnp.where(flip, -1.0, 1.0).astype(jnp.float32)
    return sin * sign, cos * sign


def rotation_params_cordic(apq, app, aqq, iters: int = CORDIC_ITERS,
                           unroll: bool = False):
    """Paper-faithful datapath: CORDIC atan -> 1-bit right shift -> CORDIC
    sin/cos (two rotators in parallel in the RTL; one fused call here)."""
    full = cordic_atan2(2.0 * apq, app - aqq, iters, unroll=unroll)
    theta = -0.5 * full  # the RTL 1-bit arithmetic right shift (sign-fixed)
    s, c = cordic_sincos(theta, iters, unroll=unroll)
    return theta, c, s


ANGLE_MODES = {
    "atan2": rotation_params,
    "rutishauser": rotation_params_rutishauser,
    "cordic": rotation_params_cordic,
}
