"""End-to-end PCA pipeline on the MANOJAVAM engine (paper Alg. 1).

standardize -> C = X^T X (block-streamed MM-Engine) -> Jacobi eigh
(DLE pivoting + CORDIC rotations, fixed sweep schedule) -> EVCR/CVCR top-k
selection -> projection O = X V_k (MM-Engine again).

``PCAConfig(T, S)`` mirrors the hardware's two tunable parameters: T is the
tile size (Pallas block edge / streaming block), S the parallelism index
(grid parallelism on-chip; data-axis shards across a mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .covariance import blocked_covariance, covariance, distributed_covariance, standardize
from .jacobi import DEFAULT_SWEEPS, EighResult, jacobi_eigh
from .schedule import SweepSchedule


@dataclasses.dataclass(frozen=True)
class PCAConfig:
    T: int = 128                  # tile size (paper T; MXU-aligned default)
    S: int = 8                    # parallelism index (paper S)
    sweeps: int = DEFAULT_SWEEPS  # fixed deterministic schedule
    tol: Optional[float] = None   # software early-exit (None = hardware mode)
    pivot: str = "parallel"       # "paper" | "cyclic" | "parallel"
    rotation: str = "rowcol"      # "matmul" = unified MM-Engine datapath
    angle: str = "rutishauser"    # "cordic" = paper-faithful datapath
    standardize: bool = True
    # kernel backend for the matmul datapath: None = plain XLA jnp.matmul;
    # "pallas" / "interpret" / "ref" route every matmul through the
    # mm_engine op in the backend registry (repro.backends).  The old
    # boolean ``use_pallas=True`` is spelled ``backend="pallas"`` now.
    backend: Optional[str] = None
    # mixed-precision policy for the covariance/Gram leg ("fp32" |
    # "bf16_fp32acc" | "fp64"; see repro.core.precision).  Rotations,
    # angles and back-projections always stay fp32.
    precision: str = "fp32"
    # route the hot path through the fused one-launch kernels (covariance
    # + jacobi_sweep registry ops); bitwise-identical to the unfused path
    # at fp32
    fused: bool = False

    @property
    def use_pallas(self) -> bool:
        return self.backend == "pallas"

    def matmul_fn(self) -> Optional[Callable]:
        if self.backend is None:
            return None
        from repro.kernels import ops as kops
        backend = self.backend
        return lambda a, b: kops.mm_engine_matmul(a, b, block=self.T,
                                                  backend=backend)


PAPER_CONFIG_ARTIX7 = PCAConfig(T=4, S=8)
PAPER_CONFIG_VUS = PCAConfig(T=16, S=32)


class PCAResult(NamedTuple):
    components: jnp.ndarray    # (d, d) eigenvectors, columns, descending
    eigenvalues: jnp.ndarray   # (d,) descending
    mean: jnp.ndarray
    scale: jnp.ndarray
    evcr: jnp.ndarray          # explained variance contribution ratio (eq. 3)
    cvcr: jnp.ndarray          # cumulative variance contribution ratio (eq. 4)
    off_norm: jnp.ndarray      # final relative off-diagonal norm


def evcr_cvcr(eigenvalues):
    lam = jnp.maximum(eigenvalues, 0.0)
    total = jnp.maximum(jnp.sum(lam), 1e-30)
    evcr = lam / total
    cvcr = jnp.cumsum(evcr)
    return evcr, cvcr


def select_k(cvcr, variance_target: float = 0.95) -> jnp.ndarray:
    """Smallest k whose CVCR reaches the target (scree-plot companion)."""
    return jnp.minimum(jnp.sum(cvcr < variance_target) + 1, cvcr.shape[0])


def fit(X, config: PCAConfig = PCAConfig()) -> PCAResult:
    X = jnp.asarray(X)
    if config.standardize:
        Xs, mean, scale = standardize(X)
    else:
        Xs = X
        mean = jnp.zeros((X.shape[1],), X.dtype)
        scale = jnp.ones((X.shape[1],), X.dtype)
    mm = config.matmul_fn()
    C = blocked_covariance(Xs, block_m=config.T, matmul_fn=mm,
                           fused=config.fused, precision=config.precision,
                           backend=config.backend)
    res: EighResult = jacobi_eigh(
        C,
        sweeps=config.sweeps,
        tol=config.tol,
        pivot=config.pivot,
        rotation=config.rotation,
        angle=config.angle,
        matmul_fn=mm,
        fused=config.fused,
        fused_backend=config.backend,
    )
    evcr, cvcr = evcr_cvcr(res.eigenvalues)
    return PCAResult(res.eigenvectors, res.eigenvalues, mean, scale, evcr,
                     cvcr, res.off_norm)


def transform(X, result: PCAResult, k: int, config: PCAConfig = PCAConfig()):
    """Project onto the top-k subspace: O = X_std V_k (paper eq. 5)."""
    Xs = (jnp.asarray(X) - result.mean) / result.scale
    mm = config.matmul_fn() or jnp.matmul
    return mm(Xs, result.components[:, :k])


def fit_transform(X, k: int, config: PCAConfig = PCAConfig()):
    res = fit(X, config)
    return transform(X, res, k, config), res


def fit_distributed(X, mesh, config: PCAConfig = PCAConfig(),
                    data_axis: str = "data") -> PCAResult:
    """Data-parallel PCA: covariance block-streamed across the mesh
    (each shard = one 'row-block group' of the paper's schedule), Jacobi on
    the replicated d x d covariance."""
    X = jnp.asarray(X)
    if config.standardize:
        Xs, mean, scale = standardize(X)
    else:
        Xs, mean, scale = X, jnp.zeros((X.shape[1],)), jnp.ones((X.shape[1],))
    C = distributed_covariance(Xs, mesh, data_axis=data_axis,
                               block_m=config.T)
    res = jacobi_eigh(C, sweeps=config.sweeps, tol=config.tol,
                      pivot=config.pivot, rotation=config.rotation,
                      angle=config.angle)
    evcr, cvcr = evcr_cvcr(res.eigenvalues)
    return PCAResult(res.eigenvectors, res.eigenvalues, mean, scale, evcr,
                     cvcr, res.off_norm)
