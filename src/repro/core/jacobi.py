"""Jacobi eigendecomposition engine (the paper's Jacobian Unit + MM-Engine).

Three pivot strategies:

  * ``"paper"``    -- classical max-pivot Jacobi: per rotation the DLE scans
                      for the largest |off-diagonal| element (Sec. V/VI-C).
                      Latency-optimal on the FPGA, strictly serial on TPU;
                      kept as the faithful validation baseline.
  * ``"cyclic"``   -- row-cyclic sweeps (the paper's Cyclic Jacobi Method,
                      Sec. III): all n(n-1)/2 pivots in fixed order.
  * ``"parallel"`` -- round-robin tournament ordering (Brent-Luk [34], cited
                      by the paper as its algorithmic foundation): n/2
                      disjoint pivots per step, n-1 steps per sweep.  This is
                      the TPU-native schedule.

Two rotation-application modes:

  * ``"matmul"`` -- build the (block-)rotation matrix J and update
                    C <- J^T C J, V <- V J through the matmul engine: the
                    paper's unified-datapath mode (rotations re-use the
                    MM-Engine, Sec. VI-A).
  * ``"rowcol"`` -- update only the touched row/column pairs (O(n^2) per
                    parallel step instead of O(n^3)); beyond-paper fast path.

Convergence: fixed deterministic sweep count (default 50, the paper's safety
schedule) with optional software early-exit tolerance.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .cordic import ANGLE_MODES
from . import dle as dle_mod

DEFAULT_SWEEPS = 50  # paper Sec. VII-D: fixed 50-sweep factor-of-safety


class EighResult(NamedTuple):
    eigenvalues: jnp.ndarray    # (n,) descending
    eigenvectors: jnp.ndarray   # (n, n), column i pairs with eigenvalue i
    off_norm: jnp.ndarray       # final relative off-diagonal Frobenius norm
    history: Optional[jnp.ndarray]  # (sweeps+1,) relative off-norm per sweep


def offdiag_frobenius(C):
    """E_off(A) = sqrt(sum_{i != j} a_ij^2)  (paper eq. 11)."""
    n = C.shape[0]
    off = C * (1.0 - jnp.eye(n, dtype=C.dtype))
    return jnp.sqrt(jnp.sum(off * off))


def relative_offdiag(C):
    return offdiag_frobenius(C) / jnp.maximum(
        jnp.sqrt(jnp.sum(C * C)), jnp.asarray(1e-30, C.dtype)
    )


@functools.lru_cache(maxsize=64)
def round_robin_rounds(n: int) -> np.ndarray:
    """(n-1, n//2, 2) disjoint pivot pairs per round (circle method).

    ``n`` must be even; every unordered pair appears exactly once per sweep.
    """
    assert n % 2 == 0, "round-robin ordering needs even n (pad first)"
    players = list(range(n))
    rounds = []
    for _ in range(n - 1):
        pairs = []
        for i in range(n // 2):
            a, b = players[i], players[n - 1 - i]
            pairs.append((min(a, b), max(a, b)))
        rounds.append(pairs)
        players = [players[0]] + [players[-1]] + players[1:-1]
    return np.asarray(rounds, dtype=np.int32)


@functools.lru_cache(maxsize=64)
def cyclic_pairs(n: int) -> np.ndarray:
    """(n(n-1)/2, 1, 2) row-cyclic pivot order."""
    pairs = [(p, q) for p in range(n - 1) for q in range(p + 1, n)]
    return np.asarray(pairs, dtype=np.int32).reshape(-1, 1, 2)


def _build_rotation(n: int, p, q, c, s, dtype):
    """Dense block-rotation J (identity + embedded 2x2s, paper eq. 7).

    Degenerate pivots with p == q (the DLE's answer on an already-diagonal
    matrix) carry c = 1, s = 0 from ``_null_pivot_guard``; route the
    off-diagonal writes through ``where`` so they land on the diagonal as c
    instead of zeroing it.
    """
    J = jnp.eye(n, dtype=dtype)
    J = J.at[p, p].set(c.astype(dtype))
    J = J.at[q, q].set(c.astype(dtype))
    J = J.at[p, q].set(jnp.where(p == q, c, s).astype(dtype))
    J = J.at[q, p].set(jnp.where(p == q, c, -s).astype(dtype))
    return J


def _null_pivot_guard(p, q, apq, c, s):
    """Force the exact identity rotation on null pivots.

    Two cases: (a) apq == 0 -- nothing to annihilate.  The float angle
    formulas already return s = 0 here, but atan2/CORDIC do not (atan2(0, x)
    is pi for x < 0; the fixed-point CORDIC leaves ~2^-29 angle noise), so
    without the guard a zero-padded coordinate could mix with live ones.
    This is what makes bucket padding *exact*: a matrix embedded in a larger
    zero-padded bucket keeps its padded rows/cols at exactly zero through
    every sweep, for every pivot strategy and angle mode.  (b) p == q -- the
    max-pivot DLE degenerates to argmax index 0 on an all-zero off-diagonal;
    rotating "coordinate p against itself" must be a no-op.
    """
    null = (apq == 0.0) | (p == q)
    c = jnp.where(null, jnp.ones_like(c), c)
    s = jnp.where(null, jnp.zeros_like(s), s)
    return c, s


def _apply_rotations_rowcol(C, V, p, q, c, s):
    """Apply commuting rotations for disjoint pivot sets (vectorised).

    Convention (paper R, eq. 7): R[p,p]=R[q,q]=c, R[p,q]=s, R[q,p]=-s;
    C' = R^T C R, V' = V R.
    """
    c_ = c[:, None]
    s_ = s[:, None]
    rows_p = C[p, :]
    rows_q = C[q, :]
    C = C.at[p, :].set(c_ * rows_p - s_ * rows_q)
    C = C.at[q, :].set(s_ * rows_p + c_ * rows_q)
    cols_p = C[:, p]
    cols_q = C[:, q]
    C = C.at[:, p].set(c * cols_p - s * cols_q)
    C = C.at[:, q].set(s * cols_p + c * cols_q)
    vp = V[:, p]
    vq = V[:, q]
    V = V.at[:, p].set(c * vp - s * vq)
    V = V.at[:, q].set(s * vp + c * vq)
    return C, V


def _apply_rotations_matmul(C, V, p, q, c, s, matmul_fn):
    n = C.shape[0]
    J = _build_rotation(n, p, q, c, s, C.dtype)
    C = matmul_fn(matmul_fn(J.T, C), J)
    V = matmul_fn(V, J)
    return C, V


def _sweep_scan(C, V, rounds, angle_fn, rotation, matmul_fn,
                fused: bool = False, angle: str = "rutishauser",
                fused_backend: Optional[str] = None):
    """One full sweep: scan over pivot rounds.

    ``fused`` routes each round through the ``jacobi_sweep`` registry op --
    gather + angle + null-pivot guard + row/col rotation in one kernel
    launch (paper's fused Jacobian Unit) instead of a chain of XLA ops with
    C and V round-tripping HBM between them.  The fused round is
    bitwise-identical to the unfused body for every angle mode; it applies
    to ``rotation="rowcol"`` only (the "matmul" datapath deliberately
    routes rotations through the MM-Engine, so it stays unfused).
    """
    if fused and rotation == "rowcol":
        from repro.kernels import ops as kops

        def body(carry, pairs):
            C, V = carry
            C, V = kops.jacobi_sweep(C, V, pairs, angle=angle,
                                     backend=fused_backend)
            return (C, V), None
    else:
        def body(carry, pairs):
            C, V = carry
            p = pairs[:, 0]
            q = pairs[:, 1]
            apq = C[p, q]
            app = C[p, p]
            aqq = C[q, q]
            _, c, s = angle_fn(apq, app, aqq)
            c, s = _null_pivot_guard(p, q, apq, c, s)
            c = c.astype(C.dtype)
            s = s.astype(C.dtype)
            if rotation == "rowcol":
                C, V = _apply_rotations_rowcol(C, V, p, q, c, s)
            else:
                C, V = _apply_rotations_matmul(C, V, p, q, c, s, matmul_fn)
            return (C, V), None

    (C, V), _ = lax.scan(body, (C, V), rounds)
    return C, V


def _max_pivot_sweep(C, V, n_rot: int, angle_fn, rotation, matmul_fn,
                     pivot_fn=dle_mod.find_pivot):
    """n_rot classical max-pivot rotations (DLE lookup per rotation)."""

    def body(_, carry):
        C, V = carry
        piv = pivot_fn(C)
        _, c, s = angle_fn(piv.apq, piv.app, piv.aqq)
        c, s = _null_pivot_guard(piv.p, piv.q, piv.apq, c, s)
        c = c.astype(C.dtype)
        s = s.astype(C.dtype)
        p = piv.p[None]
        q = piv.q[None]
        if rotation == "rowcol":
            C, V = _apply_rotations_rowcol(C, V, p, q, c[None], s[None])
        else:
            C, V = _apply_rotations_matmul(C, V, p, q, c[None], s[None], matmul_fn)
        return C, V

    return lax.fori_loop(0, n_rot, body, (C, V))


def jacobi_eigh(
    C,
    sweeps: int = DEFAULT_SWEEPS,
    pivot: str = "parallel",
    rotation: str = "rowcol",
    angle: str = "rutishauser",
    matmul_fn: Optional[Callable] = None,
    tol: Optional[float] = None,
    track_history: bool = False,
    sort: bool = True,
    fused: bool = False,
    fused_backend: Optional[str] = None,
) -> EighResult:
    """Symmetric eigendecomposition via Jacobi rotations.

    Args:
      C: (n, n) symmetric matrix (float32/float64).
      sweeps: deterministic sweep budget (paper default: 50).
      pivot: "parallel" | "cyclic" | "paper" (max-pivot).
      rotation: "rowcol" | "matmul" (unified MM-Engine datapath).
      angle: "rutishauser" | "atan2" | "cordic".
      matmul_fn: matmul used by rotation="matmul" (defaults to jnp.matmul;
        inject ``kernels.ops.mm_engine_matmul`` for the Pallas path).
      tol: optional early-exit relative off-diagonal tolerance. When set,
        a while_loop replaces the fixed schedule (software mode).
      track_history: record the relative off-norm after every sweep.
      fused: run each pivot round through the fused ``jacobi_sweep``
        registry op (one launch per round; bitwise-identical to the
        unfused path).  Applies to the "parallel"/"cyclic" strategies with
        rotation="rowcol"; "paper" (max-pivot DLE) and the "matmul"
        rotation datapath fall back to the unfused chain.
      fused_backend: registry backend for the fused op (None = resolution
        order: pallas on TPU, interpret elsewhere).
    Returns:
      EighResult with eigenvalues (descending) and column eigenvectors.
    """
    if pivot not in ("parallel", "cyclic", "paper"):
        raise ValueError(f"unknown pivot strategy {pivot!r}")
    if rotation not in ("rowcol", "matmul"):
        raise ValueError(f"unknown rotation mode {rotation!r}")
    angle_fn = ANGLE_MODES[angle]
    matmul_fn = matmul_fn or jnp.matmul

    C = jnp.asarray(C)
    n_in = C.shape[0]
    if n_in == 1:  # trivial 1x1 problem
        return EighResult(jnp.diagonal(C), jnp.ones((1, 1), C.dtype),
                          jnp.zeros((), C.dtype), None)
    # round-robin needs even n: zero-pad one row/col (exact: the padded
    # coordinate never mixes -- its pivots have apq = 0 -> theta = 0).
    padded = pivot == "parallel" and n_in % 2 == 1
    if padded:
        C = jnp.pad(C, ((0, 1), (0, 1)))
    n = C.shape[0]
    V = jnp.eye(n, dtype=C.dtype)

    if pivot == "parallel":
        rounds = jnp.asarray(round_robin_rounds(n))
        rot_per_sweep = None
    elif pivot == "cyclic":
        rounds = jnp.asarray(cyclic_pairs(n))
        rot_per_sweep = None
    else:
        rounds = None
        rot_per_sweep = (n_in * (n_in - 1)) // 2  # one "sweep" worth

    def one_sweep(C, V):
        if pivot == "paper":
            return _max_pivot_sweep(C, V, rot_per_sweep, angle_fn, rotation,
                                    matmul_fn)
        return _sweep_scan(C, V, rounds, angle_fn, rotation, matmul_fn,
                           fused=fused, angle=angle,
                           fused_backend=fused_backend)

    if tol is not None:
        def cond(state):
            i, C, V = state
            return (i < sweeps) & (relative_offdiag(C) > tol)

        def body(state):
            i, C, V = state
            C, V = one_sweep(C, V)
            return i + 1, C, V

        _, C, V = lax.while_loop(cond, body, (jnp.int32(0), C, V))
        history = None
    elif track_history:
        hist0 = relative_offdiag(C)

        def body(carry, _):
            C, V = carry
            C, V = one_sweep(C, V)
            return (C, V), relative_offdiag(C)

        (C, V), hist = lax.scan(body, (C, V), None, length=sweeps)
        history = jnp.concatenate([hist0[None], hist])
    else:
        def body(carry, _):
            C, V = carry
            return one_sweep(C, V), None

        (C, V), _ = lax.scan(body, (C, V), None, length=sweeps)
        history = None

    off = relative_offdiag(C)
    eigvals = jnp.diagonal(C)
    if padded:
        eigvals = eigvals[:n_in]
        V = V[:n_in, :n_in]
    if sort:
        order = jnp.argsort(-eigvals)
        eigvals = eigvals[order]
        V = V[:, order]
    return EighResult(eigvals, V, off, history)


def jacobi_svd(A, matmul_fn: Optional[Callable] = None,
               fused: bool = False, fused_backend: Optional[str] = None,
               precision: str = "fp32", **kwargs):
    """SVD of A via eigendecomposition of the Gram matrix A^T A (the PCA
    path: singular values = sqrt(eigenvalues), V = right singular vectors).
    Returns (U, S, Vt) with the thin convention.

    The Gram product and the U = A V back-projection go through the same
    injected ``matmul_fn`` as the rotations: all three matmuls of the SVD
    share the unified MM-Engine datapath (paper Sec. VI-A).  ``fused``
    routes the Gram through the one-pass ``covariance`` registry op and the
    sweeps through the fused ``jacobi_sweep`` op; ``precision`` selects the
    Gram operand-streaming dtype (``repro.core.precision`` -- rotations and
    the back-projection always stay fp32).
    """
    mm = matmul_fn or jnp.matmul
    if fused:
        from repro.kernels import ops as kops
        gram = kops.covariance(A, precision=precision,
                               backend=fused_backend)
    else:
        gram = mm(A.T, A)
    res = jacobi_eigh(gram, matmul_fn=matmul_fn, fused=fused,
                      fused_backend=fused_backend, **kwargs)
    s = jnp.sqrt(jnp.maximum(res.eigenvalues, 0.0))
    V = res.eigenvectors
    safe = jnp.maximum(s, 1e-30)
    U = mm(A, V) / safe[None, :]
    return U, s, V.T
