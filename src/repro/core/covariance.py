"""Covariance computation C = X^T X with block streaming (paper Sec. VI-A).

The contraction dimension of X^T X is the *sample* axis M, so streaming
T-sized sample blocks keeps the on-chip working set constant regardless of
dataset size -- the paper's scale-invariance claim.  Three paths:

  * ``covariance``            -- plain jnp (oracle / CPU path)
  * ``blocked_covariance``    -- explicit block-streaming accumulation
                                 (structure of the MM-Engine schedule)
  * ``distributed_covariance``-- the same block streaming lifted across a
                                 mesh: each data shard accumulates its local
                                 X_i^T X_i and a psum over the data axis
                                 completes the accumulation.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map_compat


def standardize(X, eps: float = 1e-8) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Zero-mean / unit-variance per feature (paper eq. 1).

    MANOJAVAM assumes pre-standardized input; this is the host-side step.
    """
    mean = jnp.mean(X, axis=0)
    std = jnp.std(X, axis=0)
    std = jnp.where(std < eps, 1.0, std)
    return (X - mean) / std, mean, std


def covariance(X, normalize: bool = False) -> jnp.ndarray:
    """C = X^T X (paper eq. 2); ``normalize`` divides by (M - 1)."""
    C = X.T @ X
    if normalize:
        C = C / jnp.maximum(X.shape[0] - 1, 1)
    return C


def blocked_covariance(
    X,
    block_m: int = 128,
    matmul_fn: Optional[Callable] = None,
    normalize: bool = False,
    fused: bool = False,
    precision: str = "fp32",
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Stream sample blocks of T rows, accumulating partial products --
    the MM-Engine dataflow (matrix accumulators keep the output tile
    stationary while operand tiles stream through).

    ``fused=True`` routes the whole accumulation through the one-launch
    ``covariance`` registry op (paper Sec. VI-A fusion: one HBM pass, the
    Gram accumulator stationary on-chip) instead of one matmul launch per
    block; with fp32 ``precision`` the result is bitwise-identical to the
    unfused path at the same ``block_m``.  ``precision`` selects the
    operand-streaming dtype (``repro.core.precision``); ``backend`` names
    the registry backend for the fused op.
    """
    if fused:
        from repro.kernels import ops as kops
        return kops.covariance(X, block_m=block_m, precision=precision,
                               normalize=normalize, backend=backend)
    mm = matmul_fn or jnp.matmul
    m, n = X.shape
    pad = (-m) % block_m
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    nblocks = X.shape[0] // block_m
    Xb = X.reshape(nblocks, block_m, n)

    def body(acc, xb):
        return acc + mm(xb.T, xb), None

    # first block initialises the accumulator (keeps the carry type
    # data-derived, so the scan also works inside shard_map)
    init = mm(Xb[0].T, Xb[0])
    if nblocks > 1:
        C, _ = jax.lax.scan(body, init, Xb[1:])
    else:
        C = init
    if normalize:
        C = C / jnp.maximum(m - 1, 1)
    return C


def distributed_covariance(
    X,
    mesh: Mesh,
    data_axis: str = "data",
    matmul_fn: Optional[Callable] = None,
    block_m: int = 128,
) -> jnp.ndarray:
    """Block streaming across the mesh: rows sharded over ``data_axis``;
    each shard runs the local MM-Engine accumulation, then one psum
    completes C.  The result is replicated (C is small: d x d)."""

    def local(x):
        c = blocked_covariance(x, block_m=block_m, matmul_fn=matmul_fn)
        return jax.lax.psum(c, axis_name=data_axis)

    fn = shard_map_compat(
        local,
        mesh=mesh,
        in_specs=P(data_axis, None),
        out_specs=P(),
        check_replication=True,
    )
    return fn(X)
