"""Cycle-approximate analytical model of the MANOJAVAM fabric.

Re-implements the paper's conservative simulator (Sec. VII-A): a worst-case
*sequential* dataflow where total time = data-loading overhead + systolic
compute cycles, with effective access time EAT = p*t_hit + (1-p)*penalty*t_hit
(p = 0.9, penalty = 10x) and the mode-aware write-miss policies of Sec. VI-B.

Also models the design space of Sec. VIII: execution time ~ M*N/(S*T^2),
power/resource scaling fitted to the two published design points
(Artix-7 (4,8) @ 200 MHz / 1.271 W and Virtex US+ (16,32) @ 434 MHz /
16.957 W; Tables I-III).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    T: int = 16                 # tile size (systolic array edge)
    S: int = 32                 # parallelism index (number of arrays)
    freq_mhz: float = 434.0
    cache_hit: float = 0.9      # paper: p = 0.9
    dram_penalty: float = 10.0  # paper: 10x off-chip penalty
    # write-miss policies (Sec. VI-B): write-around makes covariance-phase
    # output stores bypass the cache (1 access, no fill); write-allocate
    # no-fetch-on-write makes rotation-phase read-modify-writes hit after
    # first touch.
    sweeps: int = 50


ARTIX7 = FabricConfig(T=4, S=8, freq_mhz=200.0)
VIRTEX_US = FabricConfig(T=16, S=32, freq_mhz=434.0)

# -- power / resource fits ---------------------------------------------------
# DSP count is exact from the paper: DSP = S*T^2/2 (two MACs per DSP48):
#   (4,8)  ->  64   (Table I)      (16,32) -> 4096  (Table II)
# Power: P = P0 + k * S*T^2 (MAC-array dynamic power dominates; Fig. 9):
#   1.271 = P0 + k*128 ; 16.957 = P0 + k*8192  =>  k ~ 1.945e-3, P0 ~ 1.022
_POWER_K = (16.957 - 1.271) / (32 * 16 ** 2 - 8 * 4 ** 2)
_POWER_0 = 1.271 - _POWER_K * 8 * 4 ** 2
# LUT/FF/BRAM linear fits through the two published points (vs S*T^2):
_LUT_K = (195814 - 9796) / (8192 - 128)
_LUT_0 = 9796 - _LUT_K * 128
_FF_K = (143777 - 23077) / (8192 - 128)
_FF_0 = 23077 - _FF_K * 128
_BRAM_K = (940.5 - 30.5) / (8192 - 128)
_BRAM_0 = 30.5 - _BRAM_K * 128


def power_w(cfg: FabricConfig) -> float:
    return _POWER_0 + _POWER_K * cfg.S * cfg.T ** 2


def resources(cfg: FabricConfig) -> Dict[str, float]:
    st2 = cfg.S * cfg.T ** 2
    return {
        "LUT": _LUT_0 + _LUT_K * st2,
        "FF": _FF_0 + _FF_K * st2,
        "BRAM": _BRAM_0 + _BRAM_K * st2,
        "DSP": st2 / 2,
    }


def _eat(cfg: FabricConfig) -> float:
    """Effective access time multiplier per burst cycle."""
    return cfg.cache_hit + (1.0 - cfg.cache_hit) * cfg.dram_penalty


def covariance_cycles(m: int, n: int, cfg: FabricConfig) -> float:
    """C = X^T X, X in R^{m x n}: block streaming over sample tiles.

    Output grid G x G (G = ceil(n/T)); each of the S arrays owns output
    tiles sequentially; every output tile accumulates K = ceil(m/T) tile
    products.  Per tile product (worst-case sequential, Sec. VII-A):
      * LHS tile burst load, T cycles * EAT, shared across the S arrays of a
        row-block group (one broadcast read serves S arrays: /S)
      * RHS tile burst load, T cycles * EAT, private per array
      * systolic compute: T stream cycles + (2T - 2) fill/drain
    Covariance-phase write-around: output stores stream out once, T cycles
    per tile row, no fill traffic.
    """
    g = math.ceil(n / cfg.T)
    k = math.ceil(m / cfg.T)
    passes = math.ceil(g * g / cfg.S)      # sequential output-tile rounds
    eat = _eat(cfg)
    per_tile = (cfg.T * eat) / cfg.S + cfg.T * eat + (3 * cfg.T - 2)
    store = cfg.T * eat                     # write-around stream-out per tile
    return passes * (k * per_tile + store)


def jacobi_cycles(n: int, cfg: FabricConfig, pivot: str = "cyclic") -> float:
    """Eigendecomposition cycles for an n x n covariance.

    Rotations are applied through the MM-Engine acting as a "parallel
    transformation engine that updates multiple rows and columns
    simultaneously" (Sec. VI-A): the S arrays x T lanes stream the 6
    touched vectors (2 rows + 2 cols of C, 2 cols of V) at S*T elements
    per cycle, while the 12n rotation MACs retire at S*T^2 per cycle.
    The pipelined CORDIC (depth ~32) is amortised to 1 cycle per rotation.
    Rotation-phase write-allocate no-fetch-on-write (Sec. VI-B): EAT
    applies to the 1/T fill fraction of row traffic.

      cyclic   -- the paper's Cyclic Jacobi schedule: no per-rotation scan
      paper    -- classical max-pivot: adds a DLE rescan per rotation,
                  streaming n^2 elements at S*T^2 per cycle (overlapped:
                  cost = max(scan, apply))
    """
    eat = _eat(cfg)
    bw = cfg.S * cfg.T              # streamed elements / cycle
    apply = 12 * n / (cfg.S * cfg.T ** 2) + 1
    row_traffic = (6 * n / bw) * (1 + (eat - 1) / cfg.T)
    per_rotation = max(apply, row_traffic)
    if pivot == "paper":
        scan = n * n / (cfg.S * cfg.T ** 2)
        per_rotation = max(per_rotation, scan)
    rotations = cfg.sweeps * n * (n - 1) // 2
    return rotations * per_rotation


def projection_cycles(m: int, n: int, k: int, cfg: FabricConfig) -> float:
    """O = X V_k: an m x n by n x k matmul on the same fabric."""
    g_m = math.ceil(m / cfg.T)
    g_k = math.ceil(k / cfg.T)
    kk = math.ceil(n / cfg.T)
    passes = math.ceil(g_m * g_k / cfg.S)
    eat = _eat(cfg)
    per_tile = (cfg.T * eat) / cfg.S + cfg.T * eat + (3 * cfg.T - 2)
    return passes * (kk * per_tile + cfg.T * eat)


def pca_seconds(m: int, n: int, cfg: FabricConfig, k: int = None,
                include_projection: bool = True) -> Dict[str, float]:
    """End-to-end PCA latency estimate, split by stage (paper Fig. 1/6)."""
    k = k or max(1, n // 4)
    f = cfg.freq_mhz * 1e6
    cov = covariance_cycles(m, n, cfg) / f
    svd = jacobi_cycles(n, cfg) / f
    proj = projection_cycles(m, n, k, cfg) / f if include_projection else 0.0
    total = cov + svd + proj
    return {"covariance_s": cov, "svd_s": svd, "projection_s": proj,
            "total_s": total, "energy_j": total * power_w(cfg)}
