from .sharding import (Px, REPLICATED, Rules, is_px, pad_to_multiple,
                       rules_for_mesh, split_tree, stack_axes)

__all__ = ["Px", "REPLICATED", "Rules", "is_px", "pad_to_multiple",
           "rules_for_mesh", "split_tree", "stack_axes"]
