"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP on a named mesh.

Every parameter is annotated at init time with per-dimension *roles*
(``Px(value, axes)``); a ``Rules`` object resolves roles onto mesh axes:

  role        meaning                                resolved to
  ----------  -------------------------------------  --------------------
  None        replicated                             ()
  "batch"     data-parallel batch dim                ("pod", "data")
  "fsdp"      ZeRO-style parameter shard dim         "data"
  "tp"        Megatron tensor-parallel dim           "model"
  "vocab"     vocab-parallel embedding/head dim      "model"
  "expert"    expert-parallel MoE dim                "model"
  "seq"       sequence dim (activations)             per-Rules (SP)
  "seq_tp"    sequence-sharded KV cache dim (SP)     "model" (+ "data"
                                                     when batch=1)
  "layers"    stacked-scan layer dim                 ()

The same rule table drives parameter shardings, activation
``with_sharding_constraint``s and the in/out shardings of the jitted steps,
so a single object describes the whole distribution strategy.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

class Px:
    """Parameter leaf: value (array or ShapeDtypeStruct) + logical role per
    dim.  Registered as a pytree node with the roles as static aux data so
    vmap/scan/jit treat the value as the only traced child."""
    __slots__ = ("v", "ax")

    def __init__(self, v, ax):
        self.v = v
        self.ax = tuple(ax)

    def __repr__(self):
        shape = getattr(self.v, "shape", None)
        return f"Px(shape={shape}, ax={self.ax})"


jax.tree_util.register_pytree_node(
    Px, lambda p: ((p.v,), p.ax), lambda ax, ch: Px(ch[0], ax))


def is_px(x) -> bool:
    return isinstance(x, Px)


def is_axes(x) -> bool:
    """A per-dim role annotation: a *plain* tuple of None/str (NamedTuples
    such as KVCache are pytree nodes, not axes leaves)."""
    return type(x) is tuple and all(
        e is None or isinstance(e, str) for e in x)


def split_tree(tree):
    """(params, axes) from a tree of Px leaves."""
    vals = jax.tree.map(lambda p: p.v, tree, is_leaf=is_px)
    axes = jax.tree.map(lambda p: p.ax, tree, is_leaf=is_px)
    return vals, axes


def stack_axes(axes_leaf: Tuple) -> Tuple:
    """Axes for a vmapped/stacked (scan-over-layers) parameter."""
    return ("layers",) + tuple(axes_leaf)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Resolution of logical roles onto a concrete mesh."""
    mesh_axes: Tuple[str, ...] = ("data", "model")
    fsdp: bool = True
    tensor: bool = True
    # long-context decode with global_batch < |data|: shard sequence over
    # the data axis too and replicate batch.
    seq_over_data: bool = False
    # concrete mesh (needed by shard_map-based layers, e.g. MoE dispatch)
    mesh: Any = None

    def _has(self, name: str) -> bool:
        return name in self.mesh_axes

    def axis(self, role: Optional[str]):
        if role is None or role == "layers":
            return None
        if role == "batch":
            if self.seq_over_data:
                return None
            ax = tuple(a for a in ("pod", "data") if self._has(a))
            return ax if ax else None
        if role == "fsdp":
            return "data" if (self.fsdp and self._has("data")) else None
        if role in ("tp", "vocab", "expert"):
            return "model" if (self.tensor and self._has("model")) else None
        if role == "seq":
            return None
        if role == "seq_tp":
            if self.seq_over_data:
                ax = tuple(a for a in ("pod", "data") if self._has(a))
                return ax + ("model",) if self._has("model") else ax
            return "model" if self._has("model") else None
        raise ValueError(f"unknown sharding role {role!r}")

    def spec(self, *roles) -> P:
        return P(*[self.axis(r) for r in roles])

    def shard(self, x, *roles):
        """Activation constraint (requires an enclosing mesh context).
        A no-op under the empty (single-device / REPLICATED) rule set."""
        if x is None or not self.mesh_axes:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(*roles))

    def spec_tree(self, axes_tree):
        return jax.tree.map(lambda ax: self.spec(*ax), axes_tree,
                            is_leaf=is_axes)

    def sharding_tree(self, axes_tree, mesh: Mesh):
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, self.spec(*ax)), axes_tree,
            is_leaf=is_axes)


REPLICATED = Rules(mesh_axes=(), fsdp=False, tensor=False)


def shard_map_compat(f, mesh, in_specs, out_specs,
                     check_replication: bool = False):
    """Version-portable ``shard_map`` (the mesh-API analogue of
    ``repro.kernels.compat``): newer jax spells it ``jax.shard_map`` with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map`` with
    ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             check_vma=check_replication)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_replication)


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on newer jax;
    on 0.4.x a ``Mesh`` is itself the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def rules_for_mesh(mesh: Mesh, **kw) -> Rules:
    return Rules(mesh_axes=tuple(mesh.axis_names), mesh=mesh, **kw)


def batch_axes(tree):
    """Role-annotation tree for batch-leading pytrees: leading dim "batch",
    everything else replicated.

    The solver-pytree counterpart of ``Px`` annotations on parameters: the
    serving executors feed the result straight into ``Rules.spec_tree`` /
    ``Rules.sharding_tree`` to get per-leaf ``P(("data",), None, ...)``
    in/out shardings for the batched Jacobi/PCA solvers, whose every leaf
    (inputs, eigenpairs, moments, off-norms) carries the microbatch S axis
    first.  Accepts arrays or ``ShapeDtypeStruct``s (``jax.eval_shape``
    output trees work directly)."""
    return jax.tree.map(
        lambda x: ("batch",) + (None,) * (getattr(x, "ndim", 0) - 1), tree)


def pad_to_multiple(n: int, m: int) -> int:
    return n + (-n) % m
