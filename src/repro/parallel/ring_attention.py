"""Ring attention: sequence-parallel exact attention via collective_permute.

Q/K/V live sharded on the SEQUENCE dim over a mesh axis; each shard holds
its query block stationary while KV blocks rotate around the ring
(`lax.ppermute`), folding each visiting block into an online softmax --
flash attention's accumulation across devices.  Exact for causal and
non-causal attention at ANY head count (no TP head padding), with
communication = (ring_size - 1) x local *true-KV* bytes per layer
(GQA K/V rotates unexpanded: G x fewer ppermute bytes than rotating
query-head-expanded KV), overlappable with the per-step attention compute.

This is the SP alternative to Megatron head-TP for long-context prefill
(DESIGN.md "Parallelism design"); validated against dense attention in
tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .sharding import shard_map_compat

_NEG = -1e30


def _make_local(axis: str, n_static: int, causal: bool, scale: float,
                unroll: bool = False):
    perm = [(j, (j + 1) % n_static) for j in range(n_static)]

    def local(q, k, v):
        """q: (B, S_l, H, D); k/v: (B, S_l, KV, D) TRUE GQA heads -- only
        the true KV rotates; the group expansion happens implicitly in the
        grouped einsums."""
        idx = lax.axis_index(axis)
        b, s_l, h, d = q.shape
        kv = k.shape[2]
        g = h // kv
        qf = q.reshape(b, s_l, kv, g, d).astype(jnp.float32)
        q_pos = idx * s_l + jnp.arange(s_l)

        def step(i, carry):
            k_cur, v_cur, m, l, acc = carry
            src = (idx - i) % n_static
            k_pos = src * s_l + jnp.arange(s_l)
            s_ = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                            k_cur.astype(jnp.float32)) * scale
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s_ = jnp.where(mask[None, None, None], s_, _NEG)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_cur.astype(jnp.float32))
            k_nxt = lax.ppermute(k_cur, axis, perm)
            v_nxt = lax.ppermute(v_cur, axis, perm)
            return k_nxt, v_nxt, m_new, l_new, acc_new

        m0 = jnp.full((b, kv, g, s_l), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, g, s_l), jnp.float32)
        a0 = jnp.zeros((b, kv, g, s_l, d), jnp.float32)
        carry = (k, v, m0, l0, a0)
        if unroll:  # dry-run cost extraction: no while loops in HLO
            for i in range(n_static):
                carry = step(i, carry)
            _, _, m, l, acc = carry
        else:
            _, _, m, l, acc = lax.fori_loop(0, n_static, step, carry)
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, S_l, D)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, s_l, h, d)
        return out.astype(q.dtype)

    return local


def ring_attention(q, k, v, mesh: Mesh, *, seq_axis: str = "model",
                   batch_axes=("data",), causal: bool = True,
                   scale: Optional[float] = None, unroll: bool = False):
    """q: (B, S, H, D); k/v: (B, S, KV, D) with H % KV == 0 (GQA groups).
    S sharded over ``seq_axis``, B over ``batch_axes``.  Returns
    (B, S, H, D) with the same sharding."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    n = mesh.shape[seq_axis]
    local = _make_local(seq_axis, n, causal, scale, unroll=unroll)
    spec = P(tuple(a for a in batch_axes if a), seq_axis, None, None)
    fn = shard_map_compat(local, mesh=mesh, in_specs=(spec, spec, spec),
                          out_specs=spec)
    return fn(q, k, v)
