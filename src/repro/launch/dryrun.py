import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: SPMD
partitioning must succeed for the 16x16 (single-pod, 256-chip) mesh and the
2x16x16 (512-chip) multi-pod mesh for every assigned architecture and input
shape.  Prints ``compiled.memory_analysis()`` (fits?) and
``compiled.cost_analysis()`` (FLOPs/bytes for the roofline), parses the
collective bytes out of the optimized HLO, and writes one JSON record per
cell into --out (resumable: cells already present are skipped).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out experiments/dryrun]
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time

import jax

# v5e hardware constants (targets; the container runs CPU)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_TYPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|"
                      r"u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str):
    """Sum *operand* bytes of every collective in the (per-device,
    SPMD-partitioned) optimized HLO.  Operands print without types, so a
    first pass builds a symbol table of instruction result sizes; ``-done``/
    ``-update`` halves of async pairs are skipped so each collective counts
    once."""
    sizes = {}
    colls = []  # (kind, line, opname_end)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opname = m.groups()
        sizes[name] = _type_bytes(type_str)
        base = opname.replace("-start", "")
        if opname.endswith(("-done", "-update")):
            continue
        if base in _COLL_KINDS:
            colls.append((base, line, m.end()))
    per_kind = {}
    for kind, line, op_end in colls:
        paren = line.find("(", op_end)
        if paren < 0:
            continue
        depth, end = 0, len(line)
        for i in range(paren, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPND_RE.findall(line[paren:end + 1])
        total = sum(sizes.get(o, 0) for o in operands)
        per_kind[kind] = per_kind.get(kind, 0) + total
    return per_kind


def _compile_cell(cfg, shape, mesh, moments):
    from repro.launch import steps as steps_mod
    from repro.optim.adamw import AdamWConfig

    kw = {}
    if shape.kind == "train":
        kw["opt_cfg"] = AdamWConfig(moment_dtype=moments)
    step, in_sh, out_sh, abstract_args, rules = steps_mod.build_step(
        shape.kind, cfg, mesh, shape, **kw)
    donate = ()
    if shape.kind == "train":
        donate = (0,)
    elif shape.kind == "decode":
        donate = (1,)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*abstract_args)
        compiled = lowered.compile()
    return compiled


def _cost_measures(compiled):
    cost = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), colls)


def _extrapolated_costs(cfg, shape, mesh, moments):
    """lax.scan bodies are counted once by cost_analysis, so lower UNROLLED
    1-group and 2-group variants and extrapolate linearly to the full depth:
    total(G) = c1 + (G - 1) * (c2 - c1).  Exact because groups are
    structurally identical under SPMD."""
    import dataclasses
    from repro.models.transformer import period

    per = period(cfg)
    n_groups = cfg.n_layers // per
    big = 1 << 30
    enc_groups = cfg.encoder_layers  # encoder period is 1
    out = []
    for k in (1, 2):
        cfg_k = dataclasses.replace(
            cfg, n_layers=per * k,
            encoder_layers=(k if enc_groups else 0),
            scan_layers=False, attn_chunk=big, mamba_chunk=big)
        out.append(_cost_measures(_compile_cell(cfg_k, shape, mesh, moments)))
    (f1, b1, c1), (f2, b2, c2) = out
    g = n_groups if not enc_groups else max(n_groups, enc_groups)
    flops = f1 + (g - 1) * (f2 - f1)
    byts = b1 + (g - 1) * (b2 - b1)
    kinds = set(c1) | set(c2)
    colls = {k: c1.get(k, 0) + (g - 1) * (c2.get(k, 0) - c1.get(k, 0))
             for k in kinds}
    return flops, byts, colls


def _parse_overrides(pairs):
    out = {}
    for kv in pairs or ():
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             moments: str = "float32", verbose: bool = True,
             no_cost: bool = False, overrides=None) -> dict:
    import dataclasses as _dc
    from repro.configs import get_config, SHAPES, applicable, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch import accounting

    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    skip = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "moments": moments,
           "overrides": overrides or {}}
    if skip:
        rec["skipped"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh, moments)
    t_compile = time.time() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    scanned_colls = collective_bytes(hlo)

    t1 = time.time()
    if no_cost:
        # multi-pod cells prove compile+memory only; roofline is single-pod
        flops_dev, bytes_dev, colls = 0.0, 0.0, dict(scanned_colls)
    else:
        flops_dev, bytes_dev, colls = _extrapolated_costs(cfg, shape, mesh,
                                                          moments)
    t_cost = time.time() - t1
    coll_dev = float(sum(colls.values()))
    model_f = accounting.model_flops(cfg, shape)
    counts = accounting.param_counts(cfg)

    # cost_analysis/HLO are for the per-device SPMD program; the roofline
    # formulas use global = per-device * chips, so the terms reduce to
    # per-device quantities over per-chip peaks.
    rec.update({
        "chips": chips,
        "compile_s": round(t_compile, 1),
        "cost_extraction_s": round(t_cost, 1),
        "scanned_hlo_collectives": scanned_colls,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "flops_per_device": flops_dev,
        "hlo_flops_global": flops_dev * chips,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": colls,
        "model_flops": model_f,
        "param_count": counts["total"],
        "active_params": counts["active"],
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
        "useful_flops_ratio": (model_f / (flops_dev * chips)
                               if flops_dev else None),
    })
    r = rec["roofline"]
    dom = max(r, key=r.get)
    rec["dominant"] = dom
    if verbose:
        print(f"== {arch} x {shape_name} on {rec['mesh']} "
              f"({shape.kind}) ==")
        print(f"  compile {t_compile:.1f}s (+{t_cost:.1f}s cost extraction)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={flops_dev:.3e}/dev "
              f"bytes={bytes_dev:.3e}/dev")
        print(f"  collectives: { {k: f'{v:.3e}' for k, v in colls.items()} }")
        print(f"  roofline: compute={r['compute_s']:.4f}s "
              f"memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s -> {dom}-bound")
        print(f"  MODEL_FLOPS/HLO_FLOPS = {rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)}")
    return rec


def cell_id(arch, shape, multi_pod, moments="float32"):
    pod = "mp" if multi_pod else "sp"
    return f"{arch}__{shape}__{pod}__{moments}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moments", default="float32")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (hillclimb variants)")
    ap.add_argument("--tag", default=None, help="suffix for the output file")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import ARCH_IDS, SHAPES
        meshes = [False, True]
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    cid = cell_id(arch, shape, mp, args.moments)
                    f = out_dir / f"{cid}.json"
                    if f.exists():
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--moments", args.moments, "--out", str(out_dir)]
                    if mp:
                        cmd.extend(["--multipod", "--no-cost"])
                    print(f">>> {cid}", flush=True)
                    r = subprocess.run(cmd, env={**os.environ},
                                       capture_output=True, text=True)
                    if r.returncode != 0:
                        failures.append(cid)
                        (out_dir / f"{cid}.err").write_text(
                            r.stdout[-4000:] + "\n" + r.stderr[-8000:])
                        print(f"    FAILED (see {cid}.err)", flush=True)
                    else:
                        print(r.stdout[-1200:], flush=True)
        print(f"done; {len(failures)} failures: {failures}")
        return

    rec = run_cell(args.arch, args.shape, args.multipod, args.moments,
                   no_cost=args.no_cost,
                   overrides=_parse_overrides(args.override))
    cid = cell_id(args.arch, args.shape, args.multipod, args.moments)
    if args.tag:
        cid += f"__{args.tag}"
    (out_dir / f"{cid}.json").write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
