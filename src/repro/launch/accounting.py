"""Analytical parameter / FLOP accounting per architecture and shape cell.

MODEL_FLOPS follows the grading convention: 6*N*D for training (N = active
params, D = tokens processed) and 2*N*D for inference lowerings.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig
from repro.configs.shapes import ShapeCell


def _attn_params(cfg: ModelConfig, true_heads: bool = True) -> int:
    H = cfg.n_heads if true_heads else cfg.padded_heads
    d, hd, KV = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    p = d * H * hd * 2              # wq + wo
    p += d * KV * hd * 2            # wk + wv
    if cfg.qkv_bias:
        p += (H + 2 * KV) * hd
    return p


def _mamba_params(cfg: ModelConfig) -> int:
    d, di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return (d * 2 * di + cfg.d_conv * di + di + di * (R + 2 * N)
            + R * di + di + di * N + di + di * d)


def _mlp_params(cfg: ModelConfig) -> int:
    mult = 3 if cfg.mlp == "swiglu" else 2
    return mult * cfg.d_model * cfg.d_ff


def param_counts(cfg: ModelConfig) -> Dict[str, int]:
    """total and active (per-token) parameter counts."""
    total = active = 0
    mixers = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    for mix, ffn in zip(mixers, ffns):
        p_mix = _attn_params(cfg) if mix == "attn" else _mamba_params(cfg)
        total += p_mix
        active += p_mix
        if cfg.d_ff:
            if ffn == "moe":
                expert = _mlp_params(cfg)
                total += cfg.n_experts * expert + cfg.d_model * cfg.n_experts
                active += cfg.top_k * expert
                if cfg.dense_residual:
                    total += _mlp_params(cfg)
                    active += _mlp_params(cfg)
                if cfg.shared_expert:
                    total += _mlp_params(cfg)
                    active += _mlp_params(cfg)
            else:
                total += _mlp_params(cfg)
                active += _mlp_params(cfg)
    if cfg.family == "encdec":
        enc = cfg.encoder_layers * (_attn_params(cfg) + 2 * cfg.d_model
                                    * cfg.d_ff)
        cross = cfg.n_layers * _attn_params(cfg)
        total += enc + cross
        active += enc + cross
    emb = cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else emb
    total += emb + head
    active += emb + head
    return {"total": total, "active": active}


def model_flops(cfg: ModelConfig, shape: ShapeCell) -> float:
    n_active = param_counts(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
