"""End-to-end trainer: data pipeline -> sharded train step -> checkpoints,
with watchdog stall detection, straggler accounting, preemption-safe
SIGTERM handling and elastic resume.

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --global-batch 8 --seq-len 128

On a real cluster the same entry point runs the full config on the
production mesh (--mesh 16x16) across processes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.configs import get_config, reduced_config
from repro.configs.shapes import ShapeCell
from repro.data import DataConfig, TokenPipeline
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.optim import compression as comp
from repro.runtime import STALL_EXIT_CODE, Watchdog, pick_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--moments", default="float32")
    ap.add_argument("--compress-grads", type=int, default=0,
                    help="PCA gradient compression rank (0 = off)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--preempt-at", type=int, default=0,
                    help="simulate preemption: checkpoint + stop after N steps")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    mesh = pick_mesh(args.model_parallel, global_batch=args.global_batch)
    cfg = dataclasses.replace(cfg, tp=mesh.shape["model"])
    shape = ShapeCell("cli", args.seq_len, args.global_batch, "train")
    opt_cfg = adamw.AdamWConfig(lr=args.lr, moment_dtype=args.moments,
                                warmup_steps=max(2, args.steps // 10),
                                decay_steps=args.steps)
    comp_cfg = (comp.CompressionConfig(rank=args.compress_grads)
                if args.compress_grads else None)

    step_fn, in_sh, out_sh, _, rules = steps_mod.build_train_step(
        cfg, mesh, shape, opt_cfg=opt_cfg, comp_cfg=comp_cfg)

    pipe = TokenPipeline(DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed),
        process_index=jax.process_index(),
        process_count=jax.process_count())

    def init_state():
        params = tfm.param_values(
            tfm.init_model(jax.random.PRNGKey(args.seed), cfg))
        comp_state = (comp.init_state(params, comp_cfg,
                                      jax.random.PRNGKey(args.seed + 1))
                      if comp_cfg else None)
        return steps_mod.TrainState(
            params=params, opt=adamw.init(params, opt_cfg),
            step=jnp.zeros((), jnp.int32), comp=comp_state)

    with mesh:
        state = init_state()
        start_step = 0
        if args.ckpt_dir and checkpointer.latest_step(args.ckpt_dir) is not None:
            state, meta = checkpointer.restore(args.ckpt_dir, state)
            pipe.restore(meta.get("data", {"step": 0}))
            start_step = int(meta.get("step", 0))
            print(f"[train] resumed from step {start_step}", flush=True)

        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0,))

        stop = {"flag": False, "reason": None}

        def _sigterm(signum, frame):
            stop["flag"] = True
            stop["reason"] = f"signal {signum}"

        signal.signal(signal.SIGTERM, _sigterm)
        signal.signal(signal.SIGINT, _sigterm)

        def save(step):
            if not args.ckpt_dir:
                return
            checkpointer.save(args.ckpt_dir, step, state,
                              metadata={"step": step, "data": pipe.state(),
                                        "arch": cfg.name})

        wd = Watchdog(on_stall=lambda: None)
        losses = []
        for step in range(start_step, args.steps):
            tokens = pipe.batch_at(step)[:, : args.seq_len]
            batch = {"tokens": jnp.asarray(tokens)}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (tokens.shape[0], cfg.n_patches, cfg.d_model),
                    cfg.jdtype())
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (tokens.shape[0], cfg.n_frames, cfg.d_model),
                    cfg.jdtype())
            wd.start_step(step)
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = wd.end_step()
            losses.append(loss)
            if wd.stalled:
                save(step)
                print("[train] stall detected -> emergency checkpoint",
                      flush=True)
                sys.exit(STALL_EXIT_CODE)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt*1000:.0f} ms, lr {float(metrics['lr']):.2e}, "
                      f"gnorm {float(metrics['grad_norm']):.2f})",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save(step + 1)
            if args.preempt_at and step + 1 >= args.preempt_at:
                save(step + 1)
                print(f"[train] simulated preemption at {step + 1}",
                      flush=True)
                return losses
            if stop["flag"]:
                save(step + 1)
                print(f"[train] preempted ({stop['reason']}); "
                      f"checkpointed at {step + 1}", flush=True)
                sys.exit(STALL_EXIT_CODE)
        save(args.steps)
        print(json.dumps({"final_loss": losses[-1],
                          "first_loss": losses[0],
                          "watchdog": wd.summary()}), flush=True)
        return losses


if __name__ == "__main__":
    main()
