from . import accounting, mesh, steps

__all__ = ["accounting", "mesh", "steps"]
