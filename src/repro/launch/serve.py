"""Batched serving: prefill the prompt batch, then step the decode loop
against the (donated, in-place) KV cache.  Reports prefill and per-token
decode latency/throughput.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import transformer as tfm
from repro.parallel.sharding import rules_for_mesh
from repro.runtime import pick_mesh


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    mesh = pick_mesh(args.model_parallel, global_batch=args.batch)
    cfg = dataclasses.replace(cfg, tp=mesh.shape["model"])
    rules = rules_for_mesh(mesh)

    rng = np.random.default_rng(args.seed)
    params = tfm.param_values(tfm.init_model(jax.random.PRNGKey(args.seed),
                                             cfg))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((args.batch, cfg.n_patches, cfg.d_model),
                                     cfg.jdtype())
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model),
                                    cfg.jdtype())

    cache_len = args.prompt_len + args.gen_len + (
        cfg.n_patches if cfg.family == "vlm" else 0)

    with mesh:
        prefill = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, rules, cache_len=cache_len))
        decode = jax.jit(
            lambda p, s, t: tfm.decode_step(p, s, t, cfg, rules),
            donate_argnums=(1,))

        t0 = time.time()
        logits, state = jax.block_until_ready(prefill(params, batch))
        t_prefill = time.time() - t0

        key = jax.random.PRNGKey(args.seed)
        tok = sample(logits, key, args.temperature)
        out = [np.asarray(tok)]
        # warm-up decode compile outside the timed loop
        logits, state = decode(params, state, tok)
        jax.block_until_ready(logits)
        t0 = time.time()
        for i in range(1, args.gen_len):
            key, sub = jax.random.split(key)
            tok = sample(logits, sub, args.temperature)
            out.append(np.asarray(tok))
            logits, state = decode(params, state, tok)
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    per_tok = t_decode / max(1, args.gen_len - 1)
    print(json.dumps({
        "arch": cfg.name,
        "prefill_s": round(t_prefill, 4),
        "decode_per_token_s": round(per_tok, 5),
        "decode_tokens_per_s": round(args.batch / per_tok, 1),
        "generated_shape": list(gen.shape),
        "sample_tokens": gen[0, :8].tolist(),
    }), flush=True)
    return gen


if __name__ == "__main__":
    main()
