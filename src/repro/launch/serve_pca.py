"""Multi-tenant PCA/SVD serving CLI (the MANOJAVAM fabric as a service).

Feeds a synthetic mixed-shape request stream through ``serving.PCAServer``
and prints the telemetry summary as JSON: requests/s, p50/p99 latency,
padding waste, executable-cache hit rate, and the predicted-vs-measured
comparison against the analytical fabric model.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve_pca --requests 32 --op eigh \
      --max-batch 4 --bucket-policy tile --tile 16

Sharded across a device mesh (one flush retires max-batch requests,
max-batch / n_devices per device):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve_pca --mesh 8 --max-batch 32

Async pipeline (up to N flushes in flight; host batching overlaps device
execution -- N=1 is the synchronous engine):
  PYTHONPATH=src python -m repro.launch.serve_pca --inflight 4

Traffic-driven autotuning (capture a profile of the observed traffic, score
the serving-plan grid analytically, optionally measure the top candidates,
hot-swap the winner onto the live server before the timed pass):
  PYTHONPATH=src python -m repro.launch.serve_pca --autotune analytic \
      --profile-out /tmp/traffic.json
  PYTHONPATH=src python -m repro.launch.serve_pca --autotune measured \
      --profile-in /tmp/traffic.json

Observability (span trace of the timed pass -- load in chrome://tracing or
https://ui.perfetto.dev -- plus Prometheus metrics and goodput under an
SLO; ``--jax-profile DIR`` additionally captures a jax.profiler device
trace):
  PYTHONPATH=src python -m repro.launch.serve_pca --slo-ms 50 \
      --trace-out /tmp/trace.json --metrics-out /tmp/metrics.prom

Open-loop traffic (continuous seeded arrivals through the fairness /
admission frontend instead of the closed-loop burst; requests land on
their own schedule and the report is goodput under the SLO, per tenant):
  PYTHONPATH=src python -m repro.launch.serve_pca --arrivals poisson \
      --rate 200 --requests 256 --tenants "whale:0.9,mouse:0.1" \
      --scheduler wfq --admission shed --slo-ms 50

Autonomous control (the controller re-profiles a sliding telemetry
window, bandit-searches the plan grid, and hot-swaps behind hysteresis +
dwell guards -- --autotune's one-shot search, closed into a loop):
  PYTHONPATH=src python -m repro.launch.serve_pca --arrivals poisson \
      --rate 200 --requests 256 --controller on --reprofile-every 1 \
      --hysteresis 0.1 --slo-ms 50

Spec files (every construction flag resolves into one frozen ServerSpec;
--spec builds from a saved JSON instead, and conflicts with any explicit
construction flag -- the error names the clash):
  PYTHONPATH=src python -m repro.launch.serve_pca --spec server.json

CI smoke (exercises submit/flush/cache + checks results against numpy;
includes a sharded-flush parity leg over every visible device, an
async-pipeline leg -- a mixed burst must match the synchronous engine
bit-for-bit while the in-flight depth telemetry shows real pipelining --
and an autotune leg: the tuned plan must serve the same burst bit-identical
to the default plan, and a mid-stream ``apply_plan`` hot-swap must be
bit-identical to a cold server built with the plan; plus a frontend leg:
a seeded open-loop run under a virtual clock must be bit-identical across
two invocations -- same admitted/shed split, same result bytes -- and WFQ
must bound the starved tenant's p99 where FIFO does not; plus a spec leg:
ServerSpec JSON round trip + spec-vs-kwarg construction parity + the
deprecation shim; plus a controller leg: a regime-shift stream must drive
deterministic, dwell-guarded hot-swaps with admission feedback):
  PYTHONPATH=src python -m repro.launch.serve_pca --selftest
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import warnings

import numpy as np

from repro.core import PCAConfig
from repro.core.memory_model import VIRTEX_US
from repro.obs import Observability, device_profile, validate_trace
from repro.serving import (ADMISSION_MODES, ARRIVALS, BucketPolicy,
                           CacheSpec, ControllerSpec, CostModel,
                           ExecutionSpec, ObsSpec, PCAServer, POLICIES,
                           SCHEDULERS, SchedulingSpec, ServerSpec,
                           SpecConflictError, TenantSpec, TrafficFrontend,
                           TrafficProfile, VirtualClock, aot_supported,
                           autotune, build_server, generate, materialize,
                           merge, mesh_executor, parse_tenants, plan_grid,
                           profile_of, resolve_spec, server_for_plan)
from repro.serving.autotune import synthesize


def mixed_traffic(n_req: int, op: str, dims, seed: int = 0):
    """Synthetic heterogeneous request stream (shared with the benchmark).

    Matrix construction is ``serving.autotune.synthesize`` -- the same
    generator the autotuner's profile replay uses, so CLI traffic and
    replayed traffic stay comparable by construction.
    """
    rng = np.random.default_rng(seed)
    mats = []
    for i in range(n_req):
        n = int(dims[i % len(dims)])
        shape = (n, n) if op == "eigh" else (4 * n, n)
        mats.append(synthesize(op, shape, rng))
    return mats


def selftest() -> int:
    """~2s smoke: mixed shapes through every op; verify against numpy."""
    rng = np.random.default_rng(0)
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=14),
                    policy=BucketPolicy(T=8), max_delay_s=10.0)
    mats = []
    for n in (5, 9, 12, 7, 11, 6, 10, 8):
        a = rng.standard_normal((n, n)).astype(np.float32)
        mats.append((a + a.T) / 2)
    for m, r in zip(mats, srv.solve_many(mats, op="eigh")):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    svd_in = [rng.standard_normal((24, d)).astype(np.float32)
              for d in (5, 9, 7, 6)]
    for a, r in zip(svd_in, srv.solve_many(svd_in, op="svd")):
        ref = np.linalg.svd(a, compute_uv=False)
        np.testing.assert_allclose(r.S, ref, rtol=1e-3, atol=1e-3)
    # steady state: repeated traffic must be all cache hits
    srv.stats.reset()
    srv.solve_many(mats, op="eigh")
    summary = srv.stats.summary()
    assert summary["cache_hit_rate"] == 1.0, summary
    assert summary["mean_batch"] == 4.0, summary

    # sharded leg: the same eigh traffic through a mesh over every visible
    # device must match numpy too (degrades to a 1-device mesh gracefully).
    # From here on, multi-kwarg servers are built through the spec API --
    # the legs double as spec-vs-kwarg parity checks, since every result
    # is compared against the kwarg-built ``srv``
    base_spec = ServerSpec(
        scheduling=SchedulingSpec(T=8, max_batch=4, max_delay_s=10.0),
        execution=ExecutionSpec(sweeps=14))
    sharded = PCAServer.from_spec(dataclasses.replace(
        base_spec, execution=ExecutionSpec(mesh="auto", sweeps=14)))
    ex = sharded.executor
    for m, r in zip(mats, sharded.solve_many(mats, op="eigh")):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    shards = {r.n_shards for r in sharded.stats.records}
    assert shards == {ex.n_shards}, shards

    # async-pipeline leg: the same mixed burst (both ops, two buckets)
    # through a deep pipeline must match the synchronous engine
    # *bit-for-bit* -- the pipeline only reorders work, it runs the
    # identical cached executables on identical slabs -- while the depth
    # telemetry proves flushes really were in flight together
    pipelined = PCAServer.from_spec(dataclasses.replace(
        base_spec, scheduling=dataclasses.replace(base_spec.scheduling,
                                                  max_inflight=4)))
    for op, traffic in (("eigh", mats), ("svd", svd_in)):
        got = pipelined.solve_many(traffic, op=op)
        want = srv.solve_many(traffic, op=op)
        for g, w in zip(got, want):
            for field in (f.name for f in dataclasses.fields(g)):
                np.testing.assert_array_equal(
                    np.asarray(getattr(g, field)),
                    np.asarray(getattr(w, field)),
                    err_msg=f"sync-vs-async {op}.{field}")
    async_summary = pipelined.stats.summary()
    assert async_summary["max_inflight_depth"] > 1, async_summary
    assert pipelined.inflight() == 0

    # autotune leg: capture a profile of the live traffic, tune over the
    # scheduling axes (max_batch / max_inflight; bucketing pinned to the
    # default policy, under which batching and pipelining provably do not
    # change the math), and require the tuned plan to serve the identical
    # burst *bit-for-bit* equal to the default plan.  Then the hot-swap
    # parity: a server that switches onto the plan mid-stream via
    # ``apply_plan`` must match a cold server built with the plan
    # bit-for-bit too (same executables, same slabs), with the switch
    # visible in telemetry.  The profile must survive its JSON round trip
    # exactly -- that is the capture-once / replay-in-CI contract.
    cfg = PCAConfig(T=8, S=4, sweeps=14)
    profile = TrafficProfile.from_stats(srv.stats,
                                        captured=srv.describe_plan())
    assert TrafficProfile.from_json(profile.to_json()) == profile
    sched_grid = plan_grid(modes=("tile",), tiles=(8,),
                           batches=(1, 2, 4, 8), inflights=(1, 2, 4))
    tuned = autotune(profile, grid=sched_grid, config=cfg).best
    default_results = srv.solve_many(mats, op="eigh")
    cold = server_for_plan(tuned, cfg)
    hot = PCAServer(cfg, policy=BucketPolicy(T=8), max_delay_s=10.0)
    early = [hot.submit(m) for m in mats[:3]]   # queued across the swap
    hot.apply_plan(tuned)                       # re-buckets them in place
    for results in (cold.solve_many(mats, op="eigh"),
                    hot.solve_many(mats, op="eigh")):
        for g, w in zip(results, default_results):
            for field in (f.name for f in dataclasses.fields(g)):
                np.testing.assert_array_equal(
                    np.asarray(getattr(g, field)),
                    np.asarray(getattr(w, field)),
                    err_msg=f"tuned-vs-default eigh.{field}")
    # the tickets that crossed the swap retired under the new plan with
    # the same bits the default plan would have produced
    for t, w in zip(early, default_results):
        assert t.done
        np.testing.assert_array_equal(t.result().eigenvalues,
                                      w.eigenvalues)
    assert len(hot.stats.plan_switches) == 1, hot.stats.plan_switches
    assert hot.stats.summary()["plan_switches"] == 1

    # observability leg: the same mixed burst through a fully traced
    # server must be *bitwise identical* to the untraced one (tracing
    # samples clocks and appends to rings -- it must never touch the
    # math), the exported trace must pass the Chrome-schema validator
    # with every request span parented to a flush span, and the metric
    # export must carry the per-(op, bucket, backend) latency series
    traced = PCAServer.from_spec(dataclasses.replace(
        base_spec,
        scheduling=dataclasses.replace(base_spec.scheduling,
                                       max_inflight=2),
        obs=ObsSpec(slo_ms=1000.0)))
    obs = traced.obs
    for op, traffic in (("eigh", mats), ("svd", svd_in)):
        got = traced.solve_many(traffic, op=op)
        want = srv.solve_many(traffic, op=op)
        for g, w in zip(got, want):
            for field in (f.name for f in dataclasses.fields(g)):
                np.testing.assert_array_equal(
                    np.asarray(getattr(g, field)),
                    np.asarray(getattr(w, field)),
                    err_msg=f"traced-vs-untraced {op}.{field}")
    trace = obs.trace_doc()
    errors = validate_trace(trace)
    assert not errors, errors
    by_id = {e["id"]: e for e in trace["traceEvents"]
             if e.get("ph") == "X" and isinstance(e.get("id"), int)}
    requests = [e for e in trace["traceEvents"]
                if e.get("ph") == "X" and e["name"].startswith("request:")]
    assert len(requests) == len(mats) + len(svd_in), len(requests)
    for e in requests:
        parent = by_id[e["args"]["parent"]]
        assert parent["name"].startswith("flush:"), parent["name"]
    prom = obs.prometheus_text()
    assert "serve_request_latency_seconds_bucket" in prom, prom[:400]
    assert 'op="eigh"' in prom and 'op="svd"' in prom
    slo = obs.summary()["slo"]
    assert slo["requests"] == len(mats) + len(svd_in), slo

    # cold-start leg: seed a persistent --cache-dir with one replica's AOT
    # executables, then a *fresh* replica pointed at the same directory
    # must warm up entirely from disk (every warmup key a disk hit, zero
    # compiles) and serve the identical burst *bit-for-bit* equal to the
    # cold-JIT replica -- the AOT serialize/deserialize round trip must
    # never touch the math
    cold_info = {"skipped": True}
    if aot_supported():
        import tempfile
        seed_profile = TrafficProfile.from_shapes(
            [("eigh", m.shape, 1) for m in mats]
            + [("svd", a.shape, 1) for a in svd_in])
        with tempfile.TemporaryDirectory() as cdir:
            cache_spec = dataclasses.replace(
                base_spec, cache=CacheSpec(cache_dir=cdir))
            seeder = PCAServer.from_spec(cache_spec)
            seeded = seeder.warmup(seed_profile)
            assert seeded["compile"] == seeded["executables"], seeded
            stores = seeder.cache_summary()["disk"]["stores"]
            assert stores == seeded["executables"], seeder.cache_summary()
            warm = PCAServer.from_spec(cache_spec)
            warmed = warm.warmup(seed_profile)
            assert warmed["disk"] == warmed["executables"], warmed
            assert warmed["compile"] == 0, warmed
            for op, traffic in (("eigh", mats), ("svd", svd_in)):
                got = warm.solve_many(traffic, op=op)
                want = srv.solve_many(traffic, op=op)
                for g, w in zip(got, want):
                    for field in (f.name for f in dataclasses.fields(g)):
                        np.testing.assert_array_equal(
                            np.asarray(getattr(g, field)),
                            np.asarray(getattr(w, field)),
                            err_msg=f"warm-vs-cold {op}.{field}")
            warm_summary = warm.stats.summary()
            assert warm_summary["cache_hit_rate"] == 1.0, warm_summary
            cold_info = {"skipped": False,
                         "executables": warmed["executables"],
                         "disk_hits": warmed["disk"],
                         "warmup_s": round(warmed["seconds"], 4)}

    # frontend leg: the open-loop path must be *reproducible* -- a seeded
    # arrival stream through admission + WFQ under a virtual clock gives
    # the same admitted/shed split and the same result bytes on every
    # invocation -- and *fair*: with a whale saturating the server, WFQ
    # keeps the mouse's p99 bounded (its queue drains at its weight share)
    # while FIFO parks the mouse behind the whale's whole backlog
    whale = TenantSpec("whale")
    mouse = TenantSpec("mouse", slo_ms=30.0)
    stream = merge(
        generate("poisson", rate=240.0, n=120, tenants=(whale,), seed=3,
                 trace="uniform", lo=24, hi=40),
        generate("poisson", rate=30.0, n=15, tenants=(mouse,), seed=11,
                 trace="uniform", lo=8, hi=12))
    fe_model = CostModel(device_work_per_s=2e6)   # modeled slow device
    open_spec = ServerSpec(
        scheduling=SchedulingSpec(T=16, max_batch=8, max_delay_s=0.02),
        execution=ExecutionSpec(sweeps=6))

    def open_loop(scheduler, admission):
        fsrv = build_server(open_spec, clock=VirtualClock())
        fe = TrafficFrontend(fsrv, (whale, mouse), slo_ms=100.0,
                             scheduler=scheduler, admission=admission,
                             model=fe_model, seed=1)
        return fe.run(stream, pace=False)

    rep_a, rep_b = open_loop("wfq", "shed"), open_loop("wfq", "shed")
    assert rep_a.digest == rep_b.digest, "open-loop run not deterministic"
    assert rep_a.outcomes == rep_b.outcomes
    assert rep_a.shed > 0 and rep_a.served > 0, rep_a.to_json()
    assert (rep_a.served + rep_a.degraded + rep_a.shed + rep_a.throttled
            == rep_a.requests == len(stream))
    wfq_rep, fifo_rep = open_loop("wfq", "none"), open_loop("fifo", "none")
    wfq_p99 = wfq_rep.per_tenant["mouse"]["latency_p99_ms"]
    fifo_p99 = fifo_rep.per_tenant["mouse"]["latency_p99_ms"]
    assert wfq_p99 < 0.5 * fifo_p99, \
        f"WFQ did not bound the starved tenant: {wfq_p99} vs {fifo_p99}"

    # spec leg: the frozen ServerSpec must survive its JSON round trip
    # exactly, a spec-built server must serve the burst bit-identical to
    # the kwarg-built one (several legs above already ran on from_spec
    # servers against ``srv``), and legacy multi-kwarg construction must
    # point at the spec API with a DeprecationWarning
    spec_rt = dataclasses.replace(base_spec, controller=ControllerSpec(
        enabled=True, window_s=1.0, reprofile_every_s=0.25,
        hysteresis=0.02, min_dwell_s=0.5))
    assert ServerSpec.from_json(spec_rt.to_json()) == spec_rt
    spec_srv = PCAServer.from_spec(base_spec)
    for g, w in zip(spec_srv.solve_many(mats, op="eigh"),
                    srv.solve_many(mats, op="eigh")):
        for field in (f.name for f in dataclasses.fields(g)):
            np.testing.assert_array_equal(
                np.asarray(getattr(g, field)), np.asarray(getattr(w, field)),
                err_msg=f"spec-vs-kwarg eigh.{field}")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        PCAServer(PCAConfig(T=8, S=4, sweeps=14), policy=BucketPolicy(T=8),
                  max_delay_s=10.0, max_inflight=2)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught), \
        "multi-kwarg PCAServer construction must DeprecationWarn"

    # controller leg: a regime shift (small interactive traffic, then a
    # flood of large refits) under a virtual clock.  The controller must
    # be bit-deterministic across invocations (same swaps at the same
    # virtual times, same result digest), actually adapt (>= 1 hot-swap),
    # respect the dwell guard between swaps, and push the recalibrated
    # cost model into the frontend's admission controller
    ctrl_spec = ServerSpec(
        scheduling=SchedulingSpec(T=16, max_batch=4, max_delay_s=0.02),
        execution=ExecutionSpec(sweeps=6),
        controller=ControllerSpec(enabled=True, window_s=1.0,
                                  reprofile_every_s=0.25, hysteresis=0.02,
                                  min_dwell_s=0.5))
    shift_stream = merge(
        generate("poisson", rate=80.0, n=80, tenants=(whale,), seed=5,
                 trace="uniform", lo=8, hi=12),
        [dataclasses.replace(a, t=a.t + 1.5) for a in
         generate("poisson", rate=300.0, n=150, tenants=(whale,), seed=9,
                  trace="uniform", lo=28, hi=44)])

    def controlled_run():
        csrv = build_server(ctrl_spec, clock=VirtualClock())
        fe = TrafficFrontend(csrv, (whale,), slo_ms=200.0,
                             admission="none", model=fe_model, seed=1)
        csrv.controller.frontend = fe
        rep = fe.run(shift_stream, pace=False)
        return csrv, fe, rep

    csrv_a, cfe_a, crep_a = controlled_run()
    csrv_b, _, crep_b = controlled_run()
    ctrl = csrv_a.controller
    assert crep_a.digest == crep_b.digest, "controller run not deterministic"
    assert ([round(s["t"], 9) for s in ctrl.swaps]
            == [round(s["t"], 9) for s in csrv_b.controller.swaps])
    assert len(ctrl.swaps) >= 1, ctrl.summary()
    for s1, s2 in zip(ctrl.swaps, ctrl.swaps[1:]):
        assert s2["t"] - s1["t"] >= ctrl.min_dwell_s - 1e-9, ctrl.swaps
    assert cfe_a.model is not fe_model, \
        "swap did not feed the recalibrated cost model back to admission"

    print("serve_pca selftest ok:",
          json.dumps({k: round(v, 4) for k, v in summary.items()}))
    print("serve_pca sharded selftest ok:", json.dumps({
        "executor": ex.describe(), "n_shards": ex.n_shards}))
    print("serve_pca async selftest ok:", json.dumps({
        "max_inflight_depth": async_summary["max_inflight_depth"],
        "overlap_frac": round(async_summary["overlap_frac"], 4)}))
    print("serve_pca autotune selftest ok:", json.dumps({
        "tuned_plan": tuned.describe(),
        "profile_requests": profile.requests,
        "hot_swap_requeued": hot.stats.plan_switches[0]["requeued"]}))
    print("serve_pca obs selftest ok:", json.dumps({
        "spans": len(obs.tracer),
        "trace_events": len(trace["traceEvents"]),
        "request_spans": len(requests),
        "goodput_rps": round(slo["goodput_rps"], 2)}))
    print("serve_pca cold-start selftest ok:", json.dumps(cold_info))
    print("serve_pca frontend selftest ok:", json.dumps({
        "requests": rep_a.requests, "served": rep_a.served,
        "shed": rep_a.shed, "digest": rep_a.digest[:12],
        "mouse_p99_ms": {"wfq": round(wfq_p99, 1),
                         "fifo": round(fifo_p99, 1)}}))
    print("serve_pca spec selftest ok:", json.dumps({
        "round_trip": True, "parity": True, "deprecation_warns": True}))
    print("serve_pca controller selftest ok:", json.dumps({
        "ticks": ctrl.ticks, "swaps": len(ctrl.swaps),
        "first_swap_t": round(ctrl.swaps[0]["t"], 3),
        "plan": ctrl.swaps[-1]["plan"], "digest": crep_a.digest[:12]}))
    return 0


def open_loop_run(args, srv, obs, dims, spec) -> int:
    """Open-loop mode: seeded paced arrivals through the traffic frontend
    (fairness + admission) instead of the closed-loop burst."""
    tenants = parse_tenants(args.tenants)
    stream = generate(args.arrivals, rate=args.rate, n=args.requests,
                      tenants=tenants, seed=args.seed, trace="uniform",
                      op=args.op, lo=min(dims), hi=max(dims))
    # the offered-load profile of this exact stream -- arrival rate
    # included, so plan_grid scores candidates against real load pressure
    profile = profile_of(stream)
    if args.profile_out:
        profile.save(args.profile_out)
    # warm every bucket the stream will touch, then calibrate the
    # admission model from that pass's telemetry: service predictions
    # come from the hardware they will gate
    seen, sample = set(), []
    for a in stream:
        if a.shape not in seen:
            seen.add(a.shape)
            sample.append(materialize(a, seed=args.seed))
    srv.solve_many(sample * max(1, args.max_batch), op=args.op)
    model = CostModel.calibrated(TrafficProfile.from_stats(srv.stats))
    srv.stats.reset()
    accounting = None
    if obs is not None:
        from repro.obs import TenantAccounting
        accounting = TenantAccounting(obs.metrics, clock=obs.clock)
        obs.tracer.clear()
        if obs.slo is not None:
            obs.slo.reset()
    fe = TrafficFrontend(srv, tenants, slo_ms=spec.obs.slo_ms,
                         scheduler=args.scheduler, admission=args.admission,
                         model=model, degrade_frac=args.degrade_frac,
                         accounting=accounting, seed=args.seed)
    if srv.controller is not None:
        # the controller's admission feedback path: after a swap, this
        # frontend's cost model is recalibrated to the new plan
        srv.controller.frontend = fe
    rep = fe.run(stream, pace=True)
    obs_info = None
    if obs is not None:
        accounting.summary(span_s=rep.duration_s)  # refresh goodput gauges
        obs_info = obs.summary()
        if spec.obs.trace_out:
            obs_info["trace_out"] = str(obs.save_trace(spec.obs.trace_out))
        if spec.obs.metrics_out:
            obs_info["metrics_out"] = str(
                obs.save_metrics(spec.obs.metrics_out))
    print(json.dumps({
        "op": args.op,
        "arrivals": args.arrivals,
        "rate_rps": args.rate,
        "tenants": [dataclasses.asdict(t) for t in tenants],
        "scheduler": args.scheduler,
        "admission": args.admission,
        "slo_ms": spec.obs.slo_ms,
        "plan": srv.describe_plan(),
        "controller": (srv.controller.summary()
                       if srv.controller is not None else None),
        "profile": {"requests": profile.requests,
                    "arrival_rate": profile.arrival_rate,
                    "duration_s": profile.duration_s},
        "frontend": rep.to_json(),
        "obs": obs_info,
    }, indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default="eigh", choices=("eigh", "svd", "pca"))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--dims", default="10,14,18,24,29,31",
                    help="comma-separated feature dims of the mixed traffic")
    ap.add_argument("--tile", type=int, default=16,
                    help="bucket tile size (paper T)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="microbatch size (paper S)")
    ap.add_argument("--bucket-policy", default="tile", choices=POLICIES)
    ap.add_argument("--mesh", default="none",
                    help="shard each flush's batch axis across a device "
                         "mesh: 'none' (single device, default), 'auto' "
                         "(every visible device), or an integer N (first N "
                         "devices; clamps to what is visible).  Use "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                         "to carve host devices out of one CPU.")
    ap.add_argument("--inflight", type=int, default=1,
                    help="pipeline depth: how many dispatched flushes may "
                         "be in flight at once, counting the one being "
                         "dispatched.  1 (default) is the synchronous "
                         "engine; N>1 overlaps host-side batching with "
                         "device execution (JAX async dispatch), "
                         "back-pressuring by retiring the oldest flush")
    ap.add_argument("--timeout-ms", type=float, default=10.0,
                    help="flush deadline per queued request")
    ap.add_argument("--sweeps", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", default="off",
                    choices=("off", "analytic", "measured"),
                    help="pick the serving plan from observed traffic "
                         "instead of the CLI flags: 'analytic' scores the "
                         "plan grid with the calibrated cost model; "
                         "'measured' additionally replays the profile "
                         "against live servers for the analytic top-K and "
                         "keeps the measured best.  The winner is "
                         "hot-swapped onto the server (apply_plan) before "
                         "the timed pass")
    ap.add_argument("--measure-top-k", type=int, default=3,
                    help="how many analytic-best plans the 'measured' "
                         "mode replays")
    ap.add_argument("--profile-in", default=None,
                    help="tune against a previously captured traffic "
                         "profile JSON instead of profiling this run")
    ap.add_argument("--profile-out", default=None,
                    help="write the captured traffic profile JSON here "
                         "(capture once, replay in CI)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the timed "
                         "pass here (load in chrome://tracing or "
                         "https://ui.perfetto.dev); implies tracing on")
    ap.add_argument("--metrics-out", default=None,
                    help="write the Prometheus text exposition of the "
                         "serving metrics here; implies metrics on")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="latency SLO target: report goodput (requests/s "
                         "served within the target) and miss counts next "
                         "to raw throughput; implies observability on")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent executable-cache directory: cache "
                         "misses AOT-compile and serialize here "
                         "(atomically), and a fresh replica pointed at a "
                         "warm directory loads its executables without "
                         "touching XLA -- the zero-cold-start path")
    ap.add_argument("--warmup", default=None, metavar="PROFILE",
                    help="pre-build every executable this traffic-profile "
                         "JSON (--profile-out format) implies, before any "
                         "request is accepted; pairs with --cache-dir so "
                         "the warmup is a disk load on every replica after "
                         "the first")
    ap.add_argument("--arrivals", default=None, choices=ARRIVALS,
                    help="open-loop mode: drive the server with this "
                         "seeded arrival process (continuous paced "
                         "traffic through the fairness/admission "
                         "frontend) instead of the closed-loop burst; "
                         "reports goodput under --slo-ms per tenant")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="open-loop mean offered load, requests/s")
    ap.add_argument("--tenants", default="t0",
                    help="tenant spec, comma-separated "
                         "name[:share[:weight]][:p] -- e.g. "
                         "'whale:0.9,mouse:0.1' or 'rt:0.2:1:p,batch:0.8'")
    ap.add_argument("--scheduler", default="wfq", choices=SCHEDULERS,
                    help="cross-tenant scheduling discipline ahead of "
                         "the engine (wfq: weighted virtual-finish-time "
                         "fairness; fifo: arrival order)")
    ap.add_argument("--admission", default="shed", choices=ADMISSION_MODES,
                    help="deadline-feasibility policy at ingress: none "
                         "(queue unboundedly), shed (reject infeasible "
                         "requests), degrade (retry the feasibility "
                         "check at --degrade-frac sweeps first)")
    ap.add_argument("--degrade-frac", type=float, default=0.5,
                    help="sweeps fraction of the degraded variant")
    ap.add_argument("--jax-profile", default=None,
                    help="directory for a jax.profiler device trace "
                         "around the timed pass (TensorBoard/"
                         "Perfetto-loadable); no-op if the jax build "
                         "lacks profiler support")
    ap.add_argument("--spec", default=None, metavar="JSON",
                    help="build the server from a ServerSpec JSON file "
                         "(ServerSpec.to_json / `serve_pca ... --spec-out`-"
                         "less: write one with serving.ServerSpec.save). "
                         "Mutually exclusive with every construction flag "
                         "the spec owns -- conflicts error with the flag "
                         "and the spec fact named")
    ap.add_argument("--controller", default="off", choices=("off", "on"),
                    help="run the autonomous serving controller: "
                         "re-profile a sliding telemetry window every "
                         "--reprofile-every seconds, bandit-search the "
                         "plan grid, and hot-swap when the predicted gain "
                         "clears --hysteresis (anti-thrash: --min-dwell). "
                         "Owns plan search, so conflicts with --autotune")
    ap.add_argument("--profile-window", type=float, default=5.0,
                    help="controller: sliding re-profile window, seconds "
                         "of trailing traffic")
    ap.add_argument("--reprofile-every", type=float, default=1.0,
                    help="controller: tick cadence on the engine clock")
    ap.add_argument("--hysteresis", type=float, default=0.15,
                    help="controller: minimum predicted fractional gain "
                         "before a hot-swap is applied")
    ap.add_argument("--min-dwell", type=float, default=2.0,
                    help="controller: minimum seconds between swaps")
    ap.add_argument("--selftest", action="store_true",
                    help="run the 2-second smoke and exit")
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()

    # every construction flag resolves through the spec layer: one frozen
    # ServerSpec is the single source of truth, whether it came from the
    # flags or a --spec file, and conflicting flag combinations error here
    # with the clash named instead of last-write-winning
    try:
        spec = resolve_spec(args, vars(ap.parse_args([])))
    except SpecConflictError as e:
        print(f"serve_pca: {e}", file=sys.stderr)
        return 2
    dims = [int(d) for d in args.dims.split(",")]
    srv = build_server(spec)
    obs, config, executor = srv.obs, srv.config, srv.executor
    if args.arrivals:
        return open_loop_run(args, srv, obs, dims, spec)
    warmup_info = None
    if spec.cache.warmup_profile:
        # pre-build the profile's executables before the first request --
        # with a warm --cache-dir this is a disk load, not a compile
        warmup_info = srv.warmup(
            TrafficProfile.load(spec.cache.warmup_profile))
    mats = mixed_traffic(args.requests, args.op, dims, args.seed)
    srv.solve_many(mats, op=args.op)       # warmup: compile the buckets
    # the warmup pass doubles as the profiling pass: its telemetry is the
    # traffic profile the autotuner scores plans against.  --profile-out
    # always writes *this run's* captured profile, even when the tuner is
    # fed a replayed one via --profile-in
    captured = TrafficProfile.from_stats(srv.stats,
                                         captured=srv.describe_plan())
    if args.profile_out:
        captured.save(args.profile_out)
    profile = (TrafficProfile.load(args.profile_in) if args.profile_in
               else captured)
    tune_info = None
    if args.autotune != "off":
        # the CLI's mesh choice joins the executor axis of the grid, so a
        # requested mesh is kept unless the tuner finds single-device
        # genuinely better -- never silently dropped
        mesh = spec.execution.mesh
        meshes = ("none",) if mesh in ("none", "local") else ("none", mesh)
        result = autotune(
            profile, grid=plan_grid(meshes=meshes), config=config,
            measure_top_k=(args.measure_top_k
                           if args.autotune == "measured" else 0),
            seed=args.seed, obs=obs)
        # the swap pre-warms the tuned plan's executables from the profile
        # before any ticket is re-bucketed onto them
        srv.apply_plan(result.best, warm_profile=profile)
        srv.solve_many(mats, op=args.op)   # re-warmup under the tuned plan
        tune_info = result.to_json()
    srv.stats.reset()
    if obs is not None:
        # the exported trace/metrics cover the timed pass only, not the
        # warmup/profiling passes (steady-state is what the artifacts mean)
        obs.tracer.clear()
        if obs.slo is not None:
            obs.slo.reset()
    with device_profile(spec.obs.jax_profile):
        srv.solve_many(mats, op=args.op)
    summary = srv.stats.summary()
    pvm = srv.stats.predicted_vs_measured(VIRTEX_US)
    ratios = [r["ratio"] for r in pvm if np.isfinite(r["ratio"])]
    obs_info = None
    if obs is not None:
        obs_info = obs.summary()
        if spec.obs.trace_out:
            obs_info["trace_out"] = str(obs.save_trace(spec.obs.trace_out))
        if spec.obs.metrics_out:
            obs_info["metrics_out"] = str(
                obs.save_metrics(spec.obs.metrics_out))
    print(json.dumps({
        "op": args.op,
        "spec": json.loads(spec.to_json()),
        "plan": srv.describe_plan(),
        "autotune": tune_info,
        "controller": (srv.controller.summary()
                       if srv.controller is not None else None),
        "warmup": warmup_info,
        "cache": srv.cache_summary(),
        "obs": obs_info,
        "summary": summary,
        "fabric_model": {
            "reference": "MANOJAVAM(16,32)@Virtex-US+",
            "median_measured_over_predicted":
                float(np.median(ratios)) if ratios else None,
        },
    }, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
