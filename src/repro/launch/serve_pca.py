"""Multi-tenant PCA/SVD serving CLI (the MANOJAVAM fabric as a service).

Feeds a synthetic mixed-shape request stream through ``serving.PCAServer``
and prints the telemetry summary as JSON: requests/s, p50/p99 latency,
padding waste, executable-cache hit rate, and the predicted-vs-measured
comparison against the analytical fabric model.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve_pca --requests 32 --op eigh \
      --max-batch 4 --bucket-policy tile --tile 16

Sharded across a device mesh (one flush retires max-batch requests,
max-batch / n_devices per device):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve_pca --mesh 8 --max-batch 32

Async pipeline (up to N flushes in flight; host batching overlaps device
execution -- N=1 is the synchronous engine):
  PYTHONPATH=src python -m repro.launch.serve_pca --inflight 4

CI smoke (exercises submit/flush/cache + checks results against numpy;
includes a sharded-flush parity leg over every visible device and an
async-pipeline leg: a mixed burst must match the synchronous engine
bit-for-bit while the in-flight depth telemetry shows real pipelining):
  PYTHONPATH=src python -m repro.launch.serve_pca --selftest
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.core import PCAConfig
from repro.core.memory_model import VIRTEX_US
from repro.serving import BucketPolicy, PCAServer, POLICIES, mesh_executor


def mixed_traffic(n_req: int, op: str, dims, seed: int = 0):
    """Synthetic heterogeneous request stream (shared with the benchmark)."""
    rng = np.random.default_rng(seed)
    mats = []
    for i in range(n_req):
        n = int(dims[i % len(dims)])
        if op == "eigh":
            a = rng.standard_normal((n, n)).astype(np.float32)
            mats.append((a + a.T) / 2)
        else:  # svd / pca: tall rectangular data matrices
            mats.append(rng.standard_normal((4 * n, n)).astype(np.float32))
    return mats


def selftest() -> int:
    """~2s smoke: mixed shapes through every op; verify against numpy."""
    rng = np.random.default_rng(0)
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=14),
                    policy=BucketPolicy(T=8), max_delay_s=10.0)
    mats = []
    for n in (5, 9, 12, 7, 11, 6, 10, 8):
        a = rng.standard_normal((n, n)).astype(np.float32)
        mats.append((a + a.T) / 2)
    for m, r in zip(mats, srv.solve_many(mats, op="eigh")):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    svd_in = [rng.standard_normal((24, d)).astype(np.float32)
              for d in (5, 9, 7, 6)]
    for a, r in zip(svd_in, srv.solve_many(svd_in, op="svd")):
        ref = np.linalg.svd(a, compute_uv=False)
        np.testing.assert_allclose(r.S, ref, rtol=1e-3, atol=1e-3)
    # steady state: repeated traffic must be all cache hits
    srv.stats.reset()
    srv.solve_many(mats, op="eigh")
    summary = srv.stats.summary()
    assert summary["cache_hit_rate"] == 1.0, summary
    assert summary["mean_batch"] == 4.0, summary

    # sharded leg: the same eigh traffic through a mesh over every visible
    # device must match numpy too (degrades to a 1-device mesh gracefully)
    ex = mesh_executor("auto")
    sharded = PCAServer(PCAConfig(T=8, S=4, sweeps=14),
                        policy=BucketPolicy(T=8), max_delay_s=10.0,
                        executor=ex)
    for m, r in zip(mats, sharded.solve_many(mats, op="eigh")):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    shards = {r.n_shards for r in sharded.stats.records}
    assert shards == {ex.n_shards}, shards

    # async-pipeline leg: the same mixed burst (both ops, two buckets)
    # through a deep pipeline must match the synchronous engine
    # *bit-for-bit* -- the pipeline only reorders work, it runs the
    # identical cached executables on identical slabs -- while the depth
    # telemetry proves flushes really were in flight together
    pipelined = PCAServer(PCAConfig(T=8, S=4, sweeps=14),
                          policy=BucketPolicy(T=8), max_delay_s=10.0,
                          max_inflight=4)
    for op, traffic in (("eigh", mats), ("svd", svd_in)):
        got = pipelined.solve_many(traffic, op=op)
        want = srv.solve_many(traffic, op=op)
        for g, w in zip(got, want):
            for field in (f.name for f in dataclasses.fields(g)):
                np.testing.assert_array_equal(
                    np.asarray(getattr(g, field)),
                    np.asarray(getattr(w, field)),
                    err_msg=f"sync-vs-async {op}.{field}")
    async_summary = pipelined.stats.summary()
    assert async_summary["max_inflight_depth"] > 1, async_summary
    assert pipelined.inflight() == 0

    print("serve_pca selftest ok:",
          json.dumps({k: round(v, 4) for k, v in summary.items()}))
    print("serve_pca sharded selftest ok:", json.dumps({
        "executor": ex.describe(), "n_shards": ex.n_shards}))
    print("serve_pca async selftest ok:", json.dumps({
        "max_inflight_depth": async_summary["max_inflight_depth"],
        "overlap_frac": round(async_summary["overlap_frac"], 4)}))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default="eigh", choices=("eigh", "svd", "pca"))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--dims", default="10,14,18,24,29,31",
                    help="comma-separated feature dims of the mixed traffic")
    ap.add_argument("--tile", type=int, default=16,
                    help="bucket tile size (paper T)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="microbatch size (paper S)")
    ap.add_argument("--bucket-policy", default="tile", choices=POLICIES)
    ap.add_argument("--mesh", default="none",
                    help="shard each flush's batch axis across a device "
                         "mesh: 'none' (single device, default), 'auto' "
                         "(every visible device), or an integer N (first N "
                         "devices; clamps to what is visible).  Use "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                         "to carve host devices out of one CPU.")
    ap.add_argument("--inflight", type=int, default=1,
                    help="pipeline depth: how many dispatched flushes may "
                         "be in flight at once, counting the one being "
                         "dispatched.  1 (default) is the synchronous "
                         "engine; N>1 overlaps host-side batching with "
                         "device execution (JAX async dispatch), "
                         "back-pressuring by retiring the oldest flush")
    ap.add_argument("--timeout-ms", type=float, default=10.0,
                    help="flush deadline per queued request")
    ap.add_argument("--sweeps", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--selftest", action="store_true",
                    help="run the 2-second smoke and exit")
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()

    dims = [int(d) for d in args.dims.split(",")]
    config = PCAConfig(T=args.tile, S=args.max_batch, sweeps=args.sweeps)
    executor = mesh_executor(args.mesh)
    srv = PCAServer(config, policy=BucketPolicy(T=args.tile,
                                                mode=args.bucket_policy),
                    max_batch=args.max_batch,
                    max_delay_s=args.timeout_ms / 1e3,
                    executor=executor,
                    max_inflight=args.inflight)
    mats = mixed_traffic(args.requests, args.op, dims, args.seed)
    srv.solve_many(mats, op=args.op)       # warmup: compile the buckets
    srv.stats.reset()
    srv.solve_many(mats, op=args.op)
    summary = srv.stats.summary()
    pvm = srv.stats.predicted_vs_measured(VIRTEX_US)
    ratios = [r["ratio"] for r in pvm if np.isfinite(r["ratio"])]
    print(json.dumps({
        "op": args.op,
        "config": {"T": args.tile, "S": args.max_batch,
                   "policy": args.bucket_policy,
                   "timeout_ms": args.timeout_ms,
                   "executor": executor.describe(),
                   "max_inflight": args.inflight},
        "summary": summary,
        "fabric_model": {
            "reference": "MANOJAVAM(16,32)@Virtex-US+",
            "median_measured_over_predicted":
                float(np.median(ratios)) if ratios else None,
        },
    }, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
