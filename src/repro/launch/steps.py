"""Jittable train / prefill / serve steps with full sharding trees.

``build_train_step`` / ``build_prefill`` / ``build_serve_step`` return
(step_fn, in_shardings, out_shardings, abstract_args) ready for
``jax.jit(...).lower(...)`` -- the single entry point used by the dry-run,
the trainer and the server.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeCell, input_specs
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim import compression as comp
from repro.parallel.sharding import Rules, is_axes, rules_for_mesh

from . import accounting


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    step: jax.Array
    comp: Any = None   # optional PCA gradient-compression state


def rules_for_cell(mesh, cfg: ModelConfig, shape: Optional[ShapeCell] = None,
                   fsdp: bool = True) -> Rules:
    data = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            data *= mesh.shape[ax]
    seq_over_data = bool(shape and shape.kind == "decode"
                         and shape.global_batch < data)
    return rules_for_mesh(mesh, fsdp=fsdp, seq_over_data=seq_over_data)


def batch_specs(cfg: ModelConfig, shape: ShapeCell, rules: Rules):
    """PartitionSpecs for each input of this cell."""
    specs = input_specs(cfg, shape)
    b = rules.axis("batch")
    if shape.kind in ("train", "prefill"):
        out = {"tokens": P(b, None)}
        if "patches" in specs:
            out["patches"] = P(b, None, None)
        if "frames" in specs:
            out["frames"] = P(b, None, None)
        return out
    state_ax = tfm.decode_state_axes(cfg)
    state_spec = jax.tree.map(lambda ax: rules.spec(*ax), state_ax,
                              is_leaf=is_axes)
    return {"token": P(b), "state": state_spec}


def param_spec_tree(cfg: ModelConfig, rules: Rules, abstract_params):
    axes = tfm.param_axes(abstract_params)
    return jax.tree.map(lambda ax: rules.spec(*ax), axes, is_leaf=is_axes)


def train_state_specs(cfg: ModelConfig, rules: Rules, abstract_params,
                      opt_cfg: adamw.AdamWConfig):
    pspec = param_spec_tree(cfg, rules, abstract_params)
    axes = tfm.param_axes(abstract_params)
    m_spec = jax.tree.map(lambda ax: rules.spec(*ax),
                          adamw.moment_axes(axes, opt_cfg, "m"),
                          is_leaf=is_axes)
    v_spec = jax.tree.map(lambda ax: rules.spec(*ax),
                          adamw.moment_axes(axes, opt_cfg, "v"),
                          is_leaf=is_axes)
    return TrainState(params=pspec,
                      opt=adamw.OptState(m=m_spec, v=v_spec, count=P()),
                      step=P())


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh, shape: ShapeCell,
                     opt_cfg: Optional[adamw.AdamWConfig] = None,
                     comp_cfg: Optional[comp.CompressionConfig] = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    rules = rules_for_cell(mesh, cfg, shape)

    def train_step(state: TrainState, batch):
        def loss(p):
            return tfm.loss_fn(p, batch, cfg, rules)

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            state.params)
        new_comp = state.comp
        if comp_cfg is not None:
            grads, new_comp, cmetrics = comp.compress_tree(
                grads, state.comp, comp_cfg)
        new_p, new_opt, opt_metrics = adamw.update(grads, state.opt,
                                                   state.params, opt_cfg)
        metrics = dict(metrics, loss=l, **opt_metrics)
        return (TrainState(new_p, new_opt, state.step + 1, new_comp),
                metrics)

    abstract_params = tfm.param_values(tfm.abstract_init(cfg))
    abstract_opt = jax.eval_shape(
        functools.partial(adamw.init, cfg=opt_cfg), abstract_params)
    abstract_comp = None
    if comp_cfg is not None:
        abstract_comp = jax.eval_shape(
            lambda p: comp.init_state(p, comp_cfg, jax.random.PRNGKey(0)),
            abstract_params)
    abstract_state = TrainState(
        params=abstract_params, opt=abstract_opt,
        step=jax.ShapeDtypeStruct((), jnp.int32), comp=abstract_comp)
    state_specs = train_state_specs(cfg, rules, tfm.abstract_init(cfg),
                                    opt_cfg)
    if comp_cfg is not None:
        comp_specs = jax.tree.map(lambda _: P(), abstract_comp)
        state_specs = state_specs._replace(comp=comp_specs)
    b_specs = batch_specs(cfg, shape, rules)
    abstract_batch = input_specs(cfg, shape)
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                          is_leaf=lambda x: isinstance(x, P)))
    out_sh = (in_sh[0], None)
    return train_step, in_sh, out_sh, (abstract_state, abstract_batch), rules


def build_prefill(cfg: ModelConfig, mesh, shape: ShapeCell):
    rules = rules_for_cell(mesh, cfg, shape)

    def prefill_step(params, batch):
        return tfm.prefill(params, batch, cfg, rules)

    abstract_params = tfm.param_values(tfm.abstract_init(cfg))
    pspec = param_spec_tree(cfg, rules, tfm.abstract_init(cfg))
    b_specs = batch_specs(cfg, shape, rules)
    abstract_batch = input_specs(cfg, shape)
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                          is_leaf=lambda x: isinstance(x, P)))
    return prefill_step, in_sh, None, (abstract_params, abstract_batch), rules


def build_serve_step(cfg: ModelConfig, mesh, shape: ShapeCell):
    """One-token decode against a KV cache of shape.seq_len."""
    rules = rules_for_cell(mesh, cfg, shape)

    def serve_step(params, state, token):
        logits, new_state = tfm.decode_step(params, state, token, cfg, rules)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_state

    abstract_params = tfm.param_values(tfm.abstract_init(cfg))
    specs = input_specs(cfg, shape)
    pspec = param_spec_tree(cfg, rules, tfm.abstract_init(cfg))
    b_specs = batch_specs(cfg, shape, rules)
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs["state"],
                          is_leaf=lambda x: isinstance(x, P)),
             NamedSharding(mesh, b_specs["token"]))
    abstract_args = (abstract_params, specs["state"], specs["token"])
    return serve_step, in_sh, None, abstract_args, rules


def build_step(kind: str, cfg: ModelConfig, mesh, shape: ShapeCell, **kw):
    if kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if kind == "prefill":
        return build_prefill(cfg, mesh, shape)
    return build_serve_step(cfg, mesh, shape)
