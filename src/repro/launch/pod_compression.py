import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""EXPERIMENTS §Perf cell 3: PCA-compressed cross-pod gradient exchange.

The paper's Jacobi/SVD engine applied as a distributed-optimization trick:
on the 2x16x16 multi-pod mesh, the "pod" axis is the slow link.  The whole
step runs in a fully-manual shard_map (data-parallel over all 512 devices
for this experiment); gradients are psum'd over the fast in-pod axes
("data","model"), then the pod exchange is either

  baseline   -- lax.pmean of every gradient leaf over "pod"
  compressed -- PowerSGD-style rank-r exchange: pmean of P (m,r) and
                Q (n,r) factors only, orthonormalised via the MANOJAVAM
                Jacobi engine; error feedback kept pod-local.

Both variants lower+compile on the production multi-pod mesh.  The in-pod
collectives are identical across variants, so the difference in HLO
collective bytes is exactly the pod-exchange saving.

  PYTHONPATH=src python -m repro.launch.pod_compression \
      --arch granite-8b --layers 4 --rank 8
"""
import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.optim import compression as comp
from repro.parallel.sharding import REPLICATED, shard_map_compat, use_mesh


def build(cfg, mesh, seq, global_batch, mode: str, rank: int):
    opt_cfg = adamw.AdamWConfig()
    comp_cfg = comp.CompressionConfig(rank=rank, axis_name="pod",
                                      min_size=65536)
    abstract_params = tfm.param_values(tfm.abstract_init(cfg))
    n_pods = mesh.shape["pod"]
    inpod = ("data", "model")

    def loss_of(p, batch):
        return tfm.loss_fn(p, batch, cfg, REPLICATED)[0]

    def device_local(params, tokens, comp_state):
        grads = jax.grad(loss_of)(params, {"tokens": tokens})
        # fast in-pod reduction (identical in both variants)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, inpod), grads)
        if mode == "compressed":
            state = jax.tree.map(lambda l: l[0], comp_state)
            grads, new_state, _ = comp.compress_tree(grads, state, comp_cfg)
            new_state = jax.tree.map(lambda l: l[None], new_state)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, "pod"), grads)
            new_state = comp_state
        opt = adamw.init(params, opt_cfg)
        new_p, _, _ = adamw.update(grads, opt, params, opt_cfg)
        return new_p, new_state

    ab_comp = jax.eval_shape(
        lambda p: comp.init_state(p, comp_cfg, jax.random.PRNGKey(0)),
        abstract_params)
    ab_comp = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, l.dtype),
        ab_comp)
    tokens = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)

    rep = lambda l: P(*([None] * getattr(l, "ndim", 0)))
    params_spec = jax.tree.map(rep, abstract_params)
    tok_spec = P(("pod", "data", "model"), None)
    comp_spec = jax.tree.map(lambda l: P("pod", *([None] * (l.ndim - 1))),
                             ab_comp)

    fn = shard_map_compat(device_local, mesh=mesh,
                          in_specs=(params_spec, tok_spec, comp_spec),
                          out_specs=(params_spec, comp_spec))
    in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                         (params_spec, tok_spec, comp_spec),
                         is_leaf=lambda x: isinstance(x, P))
    return fn, in_sh, (abstract_params, tokens, ab_comp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch), n_layers=args.layers,
                              remat=False)
    mesh = make_production_mesh(multi_pod=True)
    rec = {"arch": args.arch, "layers": args.layers, "rank": args.rank,
           "seq": args.seq, "batch": args.batch}
    for mode in ("baseline", "compressed"):
        fn, in_sh, ab = build(cfg, mesh, args.seq, args.batch, mode,
                              args.rank)
        with use_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=in_sh).lower(*ab).compile()
        colls = collective_bytes(compiled.as_text())
        rec[mode] = {"collectives": colls,
                     "total_bytes": float(sum(colls.values()))}
        print(f"{mode}: { {k: f'{v:.3e}' for k, v in colls.items()} } "
              f"total={rec[mode]['total_bytes']:.3e}", flush=True)
    b = rec["baseline"]["total_bytes"]
    c = rec["compressed"]["total_bytes"]
    rec["pod_exchange_savings_bytes"] = b - c
    rec["reduction_factor_total"] = b / max(c, 1)
    print(f"pod-exchange saving: {b - c:.3e} bytes/dev "
          f"({b / max(c, 1):.2f}x total-collective reduction)", flush=True)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"pod_compression_{args.arch}_L{args.layers}_r{args.rank}.json"
     ).write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
