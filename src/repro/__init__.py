"""repro — MANOJAVAM on TPU.

A multi-pod JAX framework built around the paper's unified
matmul + Jacobi-SVD engine:

  repro.core       the PCA accelerator (covariance / Jacobi / CORDIC / DLE)
  repro.serving    batched multi-tenant PCA/SVD serving (buckets + S-batches)
  repro.kernels    Pallas TPU kernels (+ jit wrappers and jnp oracles)
  repro.models     dense / MoE / SSM / hybrid / enc-dec / VLM stack
  repro.configs    the ten assigned architectures and shape cells
  repro.parallel   logical-axis sharding rules (DP/FSDP/TP/EP/SP)
  repro.optim      AdamW, PCA gradient compression, spectral telemetry
  repro.data       deterministic checkpointable token pipeline
  repro.checkpoint atomic versioned checkpoints with reshard-on-load
  repro.runtime    watchdog + elastic restart
  repro.launch     mesh / dryrun / train / serve / pod_compression

See README.md for entry points, DESIGN.md for the FPGA->TPU mapping, and
EXPERIMENTS.md for the dry-run, roofline and perf-iteration results.
"""

__version__ = "1.0.0"
