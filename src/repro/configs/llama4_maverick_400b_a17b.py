"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert;
early-fusion multimodality is a no-op for the text-only input specs.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128,
    n_experts=128, top_k=1, moe_every=1, shared_expert=True,
)
