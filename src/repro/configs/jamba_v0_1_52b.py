"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE every other
layer (16e top-2).  [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    n_experts=16, top_k=2, moe_every=2,
    ssm_state=16, d_conv=4, attn_every=8,
    rope_theta=1e6,
)
