"""llava-next-34b [vlm]: LM backbone only; the anyres vision tower is a STUB
(input_specs supplies precomputed patch embeddings prepended to the token
stream).  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, head_dim=128,
    n_patches=576,
)
