"""falcon-mamba-7b [ssm]: attention-free Mamba-1 stack, d_inner = 2*d_model,
no MLP (d_ff=0).  [arXiv:2410.05355; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=0,
    vocab_size=65024, head_dim=128,
    ssm_state=16, d_conv=4, attn_every=-1,
)
