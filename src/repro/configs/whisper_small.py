"""whisper-small [audio]: 12+12 enc-dec backbone; conv audio frontend is a
STUB (input_specs supplies precomputed frame embeddings).  vocab 51865 is
padded to the TP multiple (51872+) for vocab-parallel sharding.
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, encoder_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    norm="layernorm", mlp="gelu", pos_embed="learned", n_frames=1500,
)
