"""granite-34b [dense]: 88-layer MQA (kv=1) code model; the single KV head
is group-replicated across TP shards (exact).  [arXiv:2405.04324; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152, head_dim=128,
)
