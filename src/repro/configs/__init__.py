"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus reduced
smoke-test configs and the MANOJAVAM PCA fabric configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig

from . import (arctic_480b, falcon_mamba_7b, granite_34b, granite_8b,
               jamba_v0_1_52b, llama4_maverick_400b_a17b, llava_next_34b,
               olmo_1b, qwen1_5_32b, whisper_small)
from .shapes import SHAPES, ShapeCell, applicable, input_specs

REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        jamba_v0_1_52b, arctic_480b, llama4_maverick_400b_a17b,
        falcon_mamba_7b, whisper_small, granite_8b, granite_34b, olmo_1b,
        qwen1_5_32b, llava_next_34b)
}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch].validate()


def reduced_config(arch: str, **overrides) -> ModelConfig:
    """Small same-family config for CPU smoke tests: few layers (one full
    interleave period), narrow widths, few experts, tiny vocab."""
    cfg = get_config(arch)
    import math
    per = cfg.attn_every if cfg.family == "hybrid" else 1
    if cfg.n_experts:
        per = math.lcm(per, cfg.moe_every)
    small = dict(
        n_layers=max(2, per),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // cfg.n_heads)),
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        head_dim=16,
        n_experts=0 if cfg.n_experts == 0 else 4,
        top_k=min(cfg.top_k, 2),
        ssm_state=8 if cfg.ssm_state else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_frames=16 if cfg.family == "encdec" else cfg.n_frames,
        n_patches=8 if cfg.family == "vlm" else 0,
        dtype="float32",
        remat=False,
        tp=1,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small).validate()


__all__ = ["ARCH_IDS", "REGISTRY", "SHAPES", "ShapeCell", "applicable",
           "get_config", "input_specs", "reduced_config"]
