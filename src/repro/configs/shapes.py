"""Assigned input-shape cells and their lowering kinds.

  train_4k     seq 4096,    global_batch 256  -> train_step
  prefill_32k  seq 32768,   global_batch 32   -> prefill
  decode_32k   seq 32768,   global_batch 128  -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524288,  global_batch 1    -> serve_step; SSM/hybrid only
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeCell) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason
    (recorded in EXPERIMENTS.md, see DESIGN.md Arch-applicability)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("full-attention arch: 500k dense-attention KV working set is "
                "the quadratic regime this cell excludes (DESIGN.md)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeCell):
    """ShapeDtypeStruct stand-ins for every model input of this cell.
    No device allocation; weak-type-correct and shardable."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.jdtype()
    if shape.kind in ("train", "prefill"):
        specs = {}
        s_text = s
        if cfg.family == "vlm":
            s_text = s - cfg.n_patches
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), dt)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frames, cfg.d_model), dt)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
        return specs
    # decode: one token + the decode state (KV cache of seq_len)
    from repro.models import transformer as tfm
    state = jax.eval_shape(
        lambda: tfm.make_decode_state(cfg, b, s, dtype=dt))
    return {"token": jax.ShapeDtypeStruct((b,), i32), "state": state}
