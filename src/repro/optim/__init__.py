from . import adamw, compression, spectral
from .adamw import AdamWConfig, OptState
