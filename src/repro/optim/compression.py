"""PCA gradient compression for slow (cross-pod) all-reduce.

PowerSGD-style rank-r subspace iteration with error feedback, where the
orthogonalisation / small eigenproblems are solved by the MANOJAVAM Jacobi
engine (repro.core.jacobi) -- the paper's SVD datapath applied as a
distributed-optimization trick (DESIGN.md Sec. 3).

For a 2-D gradient G (m, n), maintain Q (n, r):
    P = G Q            (m, r)   -> all-reduce P      [r/n of the bytes]
    P = orth(P)                  (Gram eigh via Jacobi)
    Q = G^T P          (n, r)   -> all-reduce Q
    G_hat = P Q^T
    error feedback: e <- G - G_hat, folded into the next step's gradient.

``compress_tree`` applies this to every >=2-D parameter above a size
threshold; small parameters are reduced exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.jacobi import jacobi_eigh


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 4
    min_size: int = 65536       # params smaller than this reduce exactly
    axis_name: Optional[str] = None   # collective axis ("pod"); None = local
    error_feedback: bool = True
    jacobi_sweeps: int = 8


class CompressionState(NamedTuple):
    q: Any        # per-param subspace (or None)
    error: Any    # per-param error-feedback buffer (or None)


def _as_matrix(g):
    """Fold leading (e.g. stacked-layer) dims into rows: compress along the
    trailing feature dim, one subspace per parameter tensor."""
    return g.reshape(-1, g.shape[-1]) if g.ndim > 2 else g


def _orthonormalize(p, sweeps: int):
    """Orthonormalise the columns of p (m, r) via Jacobi eigh of p^T p --
    the MANOJAVAM datapath (r x r problem, r <= 16)."""
    gram = p.T @ p                                   # (r, r)
    res = jacobi_eigh(gram.astype(jnp.float32), sweeps=sweeps,
                      pivot="cyclic")
    inv_sqrt = res.eigenvectors @ (
        jnp.diag(jax.lax.rsqrt(jnp.maximum(res.eigenvalues, 1e-12)))
        @ res.eigenvectors.T)
    return p @ inv_sqrt.astype(p.dtype)


def init_state(params, cfg: CompressionConfig, key) -> CompressionState:
    def mk_q(path, p):
        g = _as_matrix(p)
        if p.ndim < 2 or p.size < cfg.min_size:
            return None
        k = jax.random.fold_in(key, hash(str(path)) % (2 ** 31))
        return jax.random.normal(k, (g.shape[1], cfg.rank), jnp.float32)

    q = {k: mk_q(k, v) for k, v in _flatten(params).items()}
    err = {k: (jnp.zeros_like(v, jnp.float32) if q[k] is not None else None)
           for k, v in _flatten(params).items()}
    return CompressionState(q=q, error=err)


def _flatten(tree) -> Dict[Tuple, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {tuple(str(k) for k in path): v for path, v in flat}


def _unflatten_like(tree, flat: Dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    vals = [flat[tuple(str(k) for k in path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), vals)


def _maybe_reduce(x, axis_name):
    return jax.lax.pmean(x, axis_name) if axis_name else x


def compress_tree(grads, state: CompressionState, cfg: CompressionConfig
                  ) -> Tuple[Any, CompressionState, dict]:
    """Returns (approximated+reduced grads, new state, metrics)."""
    gflat = _flatten(grads)
    new_q, new_e, out = {}, {}, {}
    comp_bytes = full_bytes = 0
    for k, g in gflat.items():
        q = state.q.get(k)
        if q is None:
            out[k] = _maybe_reduce(g, cfg.axis_name)
            new_q[k] = None
            new_e[k] = None
            full_bytes += g.size * 4
            continue
        g2 = _as_matrix(g).astype(jnp.float32)
        if cfg.error_feedback:
            g2 = g2 + _as_matrix(state.error[k])
        p = _maybe_reduce(g2 @ q, cfg.axis_name)          # (m, r) all-reduce
        p = _orthonormalize(p, cfg.jacobi_sweeps)
        qn = _maybe_reduce(g2.T @ p, cfg.axis_name)       # (n, r) all-reduce
        g_hat = p @ qn.T
        new_e[k] = ((g2 - g_hat) if cfg.error_feedback
                    else jnp.zeros_like(g2)).reshape(g.shape)
        out[k] = g_hat.reshape(g.shape).astype(g.dtype)
        new_q[k] = qn
        comp_bytes += (p.size + qn.size) * 4
        full_bytes += g.size * 4
    metrics = {"compressed_bytes": comp_bytes, "exact_bytes": full_bytes}
    return (_unflatten_like(grads, out),
            CompressionState(q=new_q, error=new_e), metrics)
