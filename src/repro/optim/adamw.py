"""AdamW, implemented raw (no optax), with ZeRO-sharded moments.

Moments inherit the parameter sharding (which already includes the FSDP
"data"-axis shard), so optimizer state is fully partitioned -- ZeRO-1/3
hybrid.  Moment dtype is configurable:

  float32  -- exact (default)
  bfloat16 -- halves optimizer HBM (enables 400B+ training on one v5e pod)
  int8     -- block-quantised moments (dynamic per-block scale), the
              memory-optimised mode recorded in EXPERIMENTS §Perf

The int8 mode stores (q, scale) per moment with per-row blocks; quantisation
error feeds back through the running average (no error accumulator needed
for moments, unlike gradient compression).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


_QBLOCK = 128  # int8 block size (last-dim blocks)


def _quantize(x):
    """Block-absmax int8 for the sqrt(v) moment (non-negative input).

    Rounds UP: sqrt(v) read back >= truth, so a coordinate whose true
    sqrt(v) is below one quantum still reads as a full quantum instead of
    0.  Round-to-nearest collapses such denominators to eps and the Adam
    update explodes (observed: small-model training diverges within ~15
    steps); rounding up only ever makes the update more conservative.
    """
    shape = x.shape
    last = shape[-1]
    pad = (-last) % _QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = x.reshape(x.shape[:-1] + (-1, _QBLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.ceil(xb / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dequantize(qs, shape):
    x = qs["q"].astype(jnp.float32) * qs["s"]
    x = x.reshape(x.shape[:-2] + (-1,))
    return x[..., : shape[-1]]


def _moment_like(p, dtype: str, which: str):
    # int8 mode quantises only v (positive, slowly varying); m -- whose
    # entries change sign step to step -- stays bf16 (absmax-int8 m was
    # measured to destabilise small-model training; see tests)
    if dtype == "int8":
        if which == "v":
            return _quantize(jnp.zeros(p.shape, jnp.float32))
        return jnp.zeros(p.shape, jnp.bfloat16)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def init(params, cfg: AdamWConfig) -> OptState:
    return OptState(
        m=jax.tree.map(lambda p: _moment_like(p, cfg.moment_dtype, "m"),
                       params),
        v=jax.tree.map(lambda p: _moment_like(p, cfg.moment_dtype, "v"),
                       params),
        count=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads, state: OptState, params, cfg: AdamWConfig
           ) -> Tuple[Any, OptState, dict]:
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def read_moment(mom, p, which):
        if cfg.moment_dtype == "int8" and which == "v":
            r = _dequantize(mom, p.shape)   # stores sqrt(v): halve the
            return r * r                    # dynamic range so small entries
        return mom.astype(jnp.float32)      # keep quanta (no m/eps blowups)

    def write_moment(x, which):
        if cfg.moment_dtype == "int8":
            if which == "v":
                return _quantize(jnp.sqrt(jnp.maximum(x, 0.0)))
            return x.astype(jnp.bfloat16)
        dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
        return x.astype(dt)

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def one(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = cfg.b1 * read_moment(m, p, "m") + (1 - cfg.b1) * g
        vf = cfg.b2 * read_moment(v, p, "v") + (1 - cfg.b2) * g * g
        upd = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        newp = (p.astype(jnp.float32) - lr * (upd + cfg.weight_decay
                                              * p.astype(jnp.float32)))
        return (newp.astype(p.dtype), write_moment(mf, "m"),
                write_moment(vf, "v"))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [one(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), {
        "grad_norm": gnorm, "lr": lr}


def moment_axes(param_axes_tree, cfg: AdamWConfig, which: str = "v"):
    """Sharding roles for a moment tree (mirrors the params; int8 v adds
    the block-scale leaves).

    Quantisation reshapes the param's last dim into (blocks, _QBLOCK), so
    the last dim's role no longer describes either new dim -- the block
    count can even be 1 (last dim <= _QBLOCK), which any shard spec larger
    than 1 would reject at dispatch.  Both new dims are therefore
    replicated; roles on the untouched leading dims carry over.
    """
    if cfg.moment_dtype != "int8" or which == "m":
        return param_axes_tree

    def expand(ax):
        ax = tuple(ax)
        return {"q": ax[:-1] + (None, None), "s": ax[:-1] + (None, None)}

    from repro.parallel.sharding import is_axes
    return jax.tree.map(expand, param_axes_tree, is_leaf=is_axes)
