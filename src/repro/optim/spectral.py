"""Spectral training telemetry: per-parameter gradient-covariance spectra
via the MANOJAVAM Jacobi engine (DESIGN.md Sec. 3, item 4).

For a 2-D (or folded) gradient G (m, n), the right Gram matrix G^T G is
eigendecomposed on a random sketch of rows (keeps the problem <= probe
dim), giving the EVCR curve of the gradient covariance -- a live view of
how low-rank the optimization signal is.  This is the diagnostic behind
choosing the PCA gradient-compression rank: if the top-r EVCR mass is
~1, rank-r compression is near-lossless.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.jacobi import jacobi_eigh
from repro.core.pca import evcr_cvcr


@dataclasses.dataclass(frozen=True)
class SpectralConfig:
    probe_dim: int = 32     # sketch size (Jacobi problem is probe x probe)
    sweeps: int = 10
    min_size: int = 65536


def gradient_spectrum(g, cfg: SpectralConfig = SpectralConfig(), key=None):
    """EVCR of the gradient covariance of one parameter tensor.

    Returns (eigenvalues, evcr, cvcr) of the sketched Gram, descending.
    """
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    m, n = g2.shape
    k = min(cfg.probe_dim, n)
    if n > k:
        key = key if key is not None else jax.random.PRNGKey(0)
        sketch = jax.random.normal(key, (n, k), jnp.float32) / jnp.sqrt(n)
        gs = g2 @ sketch                      # (m, k)
    else:
        gs = g2
    gram = gs.T @ gs                          # (k, k)
    res = jacobi_eigh(gram, sweeps=cfg.sweeps, pivot="parallel")
    evcr, cvcr = evcr_cvcr(res.eigenvalues)
    return res.eigenvalues, evcr, cvcr


def tree_spectra(grads, cfg: SpectralConfig = SpectralConfig(),
                 key=None) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Spectra for every >=2-D parameter above the size threshold.
    Returns {param_path: {eigenvalues, evcr, cvcr, effective_rank}}."""
    key = key if key is not None else jax.random.PRNGKey(0)
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    out = {}
    for i, (path, g) in enumerate(flat):
        if g.ndim < 2 or g.size < cfg.min_size:
            continue
        name = jax.tree_util.keystr(path)
        lam, evcr, cvcr = gradient_spectrum(
            g, cfg, jax.random.fold_in(key, i))
        # entropy-based effective rank
        p = jnp.maximum(evcr, 1e-12)
        eff = jnp.exp(-jnp.sum(p * jnp.log(p)))
        out[name] = {"eigenvalues": lam, "evcr": evcr, "cvcr": cvcr,
                     "effective_rank": eff}
    return out


def suggest_compression_rank(spectra: Dict, coverage: float = 0.9) -> int:
    """Smallest rank whose mean CVCR across parameters reaches coverage."""
    if not spectra:
        return 0
    cvcrs = jnp.stack([s["cvcr"] for s in spectra.values()])
    mean_cvcr = cvcrs.mean(0)
    return int(jnp.argmax(mean_cvcr >= coverage)) + 1
