"""Deterministic, sharded, checkpointable token pipeline.

The batch for global step s is a *pure function* of (seed, s, host shard) --
a stateless index->example map -- so restarts replay exactly from a saved
cursor (no iterator state beyond the step counter), preemption-safe by
construction.  Two sources:

  synthetic  -- Zipf-distributed token stream with a repeating-ngram
                structure (so small LMs show learnable signal)
  memmap     -- flat binary token file (np.memmap), documents drawn
                deterministically by step

Each host reads only its `process_index` slice of the global batch.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 128
    global_batch: int = 8
    vocab_size: int = 256
    seed: int = 0
    source: str = "synthetic"          # "synthetic" | path to token file
    zipf_a: float = 1.2
    ngram_repeat: int = 8              # structure scale for synthetic


class TokenPipeline:
    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count
        self._step = 0
        self._mm = None
        if cfg.source != "synthetic":
            path = pathlib.Path(cfg.source)
            self._mm = np.memmap(path, dtype=np.int32, mode="r")

    # -- stateless map ------------------------------------------------------

    def batch_at(self, step: int) -> np.ndarray:
        """(local_batch, seq_len+1) int32 tokens for global step ``step``."""
        cfg = self.cfg
        rows = []
        for b in range(self.local_batch):
            gidx = (step * cfg.global_batch
                    + self.process_index * self.local_batch + b)
            rows.append(self._example(gidx))
        return np.stack(rows)

    def _example(self, gidx: int) -> np.ndarray:
        cfg = self.cfg
        n = cfg.seq_len + 1
        if self._mm is not None:
            start = (gidx * n) % max(1, len(self._mm) - n)
            return np.asarray(self._mm[start:start + n], np.int32)
        rng = np.random.Generator(np.random.Philox(key=cfg.seed + 1,
                                                   counter=gidx))
        # zipf-distributed unigrams with periodic ngram echo -> learnable
        base = rng.zipf(cfg.zipf_a, size=n).astype(np.int64)
        base = (base - 1) % cfg.vocab_size
        k = cfg.ngram_repeat
        if k > 1:
            echo = np.tile(base[:k], n // k + 1)[:n]
            mask = rng.random(n) < 0.5
            base = np.where(mask, echo, base)
        return base.astype(np.int32)

    # -- iterator protocol with explicit cursor -----------------------------

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        batch = self.batch_at(self._step)
        self._step += 1
        return batch

    def state(self) -> Dict:
        return {"step": self._step}

    def restore(self, state: Dict) -> None:
        self._step = int(state["step"])
