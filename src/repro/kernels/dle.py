"""Data Lookup Engine (DLE) Pallas kernel: single-pass max-|off-diagonal|
pivot search with tile-aware diagonal filtering (paper Sec. VI-C).

The hardware DLE taps accumulator output ports and keeps a running best as
tiles stream by, masking main-diagonal entries only inside tiles whose
row-block index equals their column-block index.  Here the tile stream is the
sequential Pallas grid; each step reduces one (T x T) VMEM tile and folds the
result into an SMEM running-best register pair, exactly one scan of C.

Outputs: best |value| (f32) and flat index (i32); the jit wrapper in
``ops.py`` recovers (p, q, c_pq, c_pp, c_qq).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import compat
from .compat import pl


def _dle_kernel(c_ref, val_ref, idx_ref, best_val, best_idx, *,
                tile: int, n: int, grid_n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _reset():
        # global register initialised on reset (paper Sec. VI-C)
        best_val[0] = jnp.float32(-1.0)
        best_idx[0] = jnp.int32(0)

    block = c_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0) + i * tile
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1) + j * tile
    mag = jnp.abs(block)
    # tile-aware filtering: the main diagonal only exists in row-block ==
    # col-block tiles; padded rows/cols are also invalid candidates.
    invalid = (rows == cols) | (rows >= n) | (cols >= n)
    mag = jnp.where(invalid, -1.0, mag.astype(jnp.float32))

    tmax = jnp.max(mag)
    targ = jnp.argmax(mag.reshape(-1)).astype(jnp.int32)
    tr = targ // tile
    tc = targ % tile
    flat = (i * tile + tr) * n + (j * tile + tc)

    @pl.when(tmax > best_val[0])
    def _update():
        best_val[0] = tmax
        best_idx[0] = flat

    @pl.when((i == grid_n - 1) & (j == grid_n - 1))
    def _emit():
        val_ref[0] = best_val[0]
        idx_ref[0] = best_idx[0]


def dle_scan(c: jax.Array, *, tile: int = 128, interpret: bool = False):
    """Single streaming scan of C; returns (max |off-diag|, flat index)."""
    n = c.shape[0]
    assert c.shape == (n, n)
    pad = (-n) % tile
    if pad:
        c = jnp.pad(c, ((0, pad), (0, pad)))
    npad = n + pad
    grid_n = npad // tile
    val, idx = pl.pallas_call(
        functools.partial(_dle_kernel, tile=tile, n=n, grid_n=grid_n),
        grid=(grid_n, grid_n),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),
            pl.BlockSpec(memory_space=compat.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[
            compat.SMEM((1,), jnp.float32),
            compat.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
        name="dle_scan",
        **compat.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(c)
    return val[0], idx[0]
