"""Flash attention (forward) Pallas kernel.

The framework-side perf-critical kernel: online-softmax blockwise attention
with the KV stream as the innermost (sequential) grid dimension and the
output block stationary in VMEM -- the same output-stationary block-streaming
dataflow as the MM-Engine, applied to attention.  Used for long prefill where
materialising (S x S) scores is impossible.

Layout: q (BH, Sq, D), k/v (BH, Skv, D); the ops.py wrapper folds batch and
heads and repeats KV heads for GQA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import compat
from .compat import pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, block_q: int, block_k: int, causal: bool,
                  scale: float, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    if causal:
        rows = (jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
                + qi * block_q + q_offset)
        cols = (jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
                + ki * block_k)
        s = jnp.where(rows >= cols, s, _NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _emit():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q (BH, Sq, D), k/v (BH, Skv, D) -> (BH, Sq, D).

    ``q_offset``: absolute position of q[0] (for decode/chunked prefill
    against a longer KV prefix).  Sequence lengths must be multiples of the
    block sizes (ops.py pads).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    n_kv = skv // block_k

    grid = (bh, sq // block_q, n_kv)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, n_kv=n_kv, block_q=block_q, block_k=block_k,
            causal=causal, scale=scale, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            compat.VMEM((block_q, 128), jnp.float32),
            compat.VMEM((block_q, 128), jnp.float32),
            compat.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
        **compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
