"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dle as core_dle
from repro.core.cordic import rotation_params


def mm_engine(a, b, out_dtype=None):
    """fp32-accumulated matmul."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def covariance_gram(x, acc_dtype=jnp.float32, out_dtype=None):
    """One-dot Gram matrix C = x^T x with explicit accumulator dtype."""
    out_dtype = out_dtype or acc_dtype
    return lax.dot_general(
        x, x, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=acc_dtype).astype(out_dtype)


def jacobi_sweep_step(C, V, pairs, angle: str = "rutishauser"):
    """One pivot round, unfused: the exact ``core.jacobi`` sweep body."""
    from repro.core.cordic import ANGLE_MODES
    from repro.core.jacobi import _apply_rotations_rowcol, _null_pivot_guard
    p = pairs[:, 0]
    q = pairs[:, 1]
    apq = C[p, q]
    app = C[p, p]
    aqq = C[q, q]
    _, c, s = ANGLE_MODES[angle](apq, app, aqq)
    c, s = _null_pivot_guard(p, q, apq, c, s)
    c = c.astype(C.dtype)
    s = s.astype(C.dtype)
    return _apply_rotations_rowcol(C, V, p, q, c, s)


def dle_scan(c):
    """(max |off-diag|, flat index) over a symmetric matrix."""
    piv = core_dle.find_pivot(c)
    n = c.shape[0]
    return jnp.abs(piv.apq).astype(jnp.float32), (piv.p * n + piv.q).astype(jnp.int32)


def cordic_rotation_params(apq, app, aqq):
    """Float-exact rotation parameters (theta, cos, sin)."""
    th, c, s = rotation_params(jnp.asarray(apq, jnp.float32),
                               jnp.asarray(app, jnp.float32),
                               jnp.asarray(aqq, jnp.float32))
    return th, c, s


def flash_attention(q, k, v, *, causal=True, scale=None, q_offset=0):
    """Dense softmax attention, fp32 math. q/k/v: (BH, S, D)."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        rows = jnp.arange(sq)[:, None] + q_offset
        cols = jnp.arange(skv)[None, :]
        s = jnp.where(rows >= cols, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan(u, delta, A, B, C, D_skip):
    """Sequential lax.scan oracle for the selective scan."""

    def step(x, inputs):
        u_t, dt_t, b_t, c_t = inputs
        decay = jnp.exp(dt_t[:, :, None] * A[None])          # (B, D, N)
        x = decay * x + (dt_t * u_t)[:, :, None] * b_t[:, None, :]
        y = jnp.sum(x * c_t[:, None, :], axis=2) + D_skip[None, :] * u_t
        return x, y

    bsz, L, d = u.shape
    n = A.shape[1]
    x0 = jnp.zeros((bsz, d, n), jnp.float32)
    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          delta.swapaxes(0, 1).astype(jnp.float32),
          B.swapaxes(0, 1).astype(jnp.float32),
          C.swapaxes(0, 1).astype(jnp.float32))
    _, ys = lax.scan(step, x0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype)
