"""Pallas TPU kernels for the perf-critical compute layers.

  mm_engine       -- block-streaming tiled matmul (the paper's MM-Engine)
  dle             -- single-scan max-|off-diagonal| pivot search (DLE)
  cordic          -- fixed-point rotation-parameter pipeline
  flash_attention -- online-softmax blockwise attention (framework hot spot)
  mamba_scan      -- chunked selective-scan for SSM architectures

Import ``repro.kernels.ops`` for the jit'd padded wrappers and
``repro.kernels.ref`` for the pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
