"""Pallas TPU kernels for the perf-critical compute layers.

  mm_engine       -- block-streaming tiled matmul (the paper's MM-Engine)
  dle             -- single-scan max-|off-diagonal| pivot search (DLE)
  cordic          -- fixed-point rotation-parameter pipeline
  flash_attention -- online-softmax blockwise attention (framework hot spot)
  mamba_scan      -- chunked selective-scan for SSM architectures

Import ``repro.kernels.ops`` for the jit'd padded wrappers (each dispatches
through the ``repro.backends`` registry to a ``pallas`` / ``interpret`` /
``ref`` implementation) and ``repro.kernels.ref`` for the pure-jnp oracles.
``repro.kernels.compat`` pins the version-portable Pallas TPU API surface;
kernel modules must import ``pl`` / memory spaces / compiler params from it
rather than from ``jax.experimental.pallas.tpu`` directly.
"""
from . import compat, ops, ref  # noqa: F401
