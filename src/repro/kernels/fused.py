"""Fused hot-path kernels: covariance accumulation and the Jacobi sweep
step (paper Sec. VI -- the unified fabric's one-pass dataflow).

The paper's headline win is architectural *fusion*: MM block streaming and
Jacobi/CORDIC rotations share one fabric, so intermediates never round-trip
through external memory.  The registry ops here close the same gap in the
software hot path:

``fused_covariance``
    C = X^T X in ONE launch and one HBM pass.  The unfused path
    (``core.covariance.blocked_covariance`` over ``mm_engine_matmul``)
    launches one kernel per sample block and materialises each partial C in
    HBM between launches; here the grid streams sample panels along a single
    contraction dimension while the full (n, n) accumulator stays stationary
    in VMEM scratch.  Accumulation is always fp32 (or fp64 on the x64
    reference lane); operands may stream as bf16 (``bf16_fp32acc``), halving
    HBM traffic -- the accumulator dtype never follows the operand dtype.

    Bitwise contract: with fp32 operands and matching ``block_m`` the result
    is bit-identical to ``blocked_covariance`` (same panel partials in the
    same order, fp32 accumulation throughout).

``jacobi_sweep_step``
    One Jacobi pivot round -- gather pivots, rotation angles, null-pivot
    guard, row/col rotation -- in ONE launch over (C, V).  The unfused
    ``_sweep_scan`` body runs the same chain as separate XLA ops with C and
    V round-tripping HBM between them.  The kernel body *is* the unfused
    body (same ``core.jacobi`` / ``core.cordic`` functions, traced inside
    the kernel), which is what makes the fused path bitwise-identical to
    the unfused one for every angle mode and pivot strategy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cordic import ANGLE_MODES, rotation_params_cordic
from repro.core.jacobi import _apply_rotations_rowcol, _null_pivot_guard


def _kernel_angle_fn(angle: str):
    """The angle function, in its Pallas-kernel-safe spelling.

    The CORDIC mode's ``fori_loop`` closes over the fixed-point angle
    table (a constant device array a kernel body cannot capture); its
    unrolled spelling uses per-stage python-int constants and is
    bit-identical (pure int32 micro-rotations)."""
    if angle == "cordic":
        return functools.partial(rotation_params_cordic, unroll=True)
    return ANGLE_MODES[angle]

from . import compat
from .compat import pl


# -- fused covariance -------------------------------------------------------

def _cov_kernel(x1_ref, x2_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one streamed sample panel folded into the stationary (n, n)
    # accumulator: X_k^T X_k with accumulator-dtype accumulation regardless
    # of the operand dtype (bf16 operands still accumulate in fp32)
    acc_ref[...] += jax.lax.dot_general(
        x1_ref[...], x2_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def fused_covariance(
    x: jax.Array,
    *,
    block_m: int = 1024,
    acc_dtype=jnp.float32,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = x^T x in one launch; sample panels stream along the only grid
    dimension while the full Gram accumulator stays in VMEM scratch.

    ``x`` is (m, n) with m a multiple of ``block_m`` (``ops.covariance``
    zero-pads arbitrary m -- zero sample rows add exactly nothing to the
    Gram matrix).  Operand dtype is taken from ``x`` (cast *before* the
    call so bf16 operands stream at half the HBM bytes); accumulation and
    output are ``acc_dtype``/``out_dtype``.
    """
    m, n = x.shape
    assert m % block_m == 0, (m, block_m)
    out_dtype = out_dtype or acc_dtype
    n_k = m // block_m

    return pl.pallas_call(
        functools.partial(_cov_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=(n_k,),
        in_specs=[
            pl.BlockSpec((block_m, n), lambda kk: (kk, 0)),
            pl.BlockSpec((block_m, n), lambda kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda kk: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), out_dtype),
        scratch_shapes=[compat.VMEM((n, n), acc_dtype)],
        interpret=interpret,
        name="fused_covariance",
        **compat.compiler_params(dimension_semantics=("arbitrary",)),
    )(x, x)


# -- fused Jacobi sweep step ------------------------------------------------

def _sweep_kernel(c_ref, v_ref, pairs_ref, co_ref, vo_ref, *, angle: str):
    """One pivot round, fused: gather -> angle -> guard -> rotate.

    The body reuses the exact ``core.jacobi`` / ``core.cordic`` functions
    the unfused ``_sweep_scan`` body runs, so the fused round is
    bit-identical to the unfused one -- including the null-pivot guard that
    keeps bucket zero-padding exact.
    """
    C = c_ref[...]
    V = v_ref[...]
    pairs = pairs_ref[...]
    p = pairs[:, 0]
    q = pairs[:, 1]
    apq = C[p, q]
    app = C[p, p]
    aqq = C[q, q]
    _, c, s = _kernel_angle_fn(angle)(apq, app, aqq)
    c, s = _null_pivot_guard(p, q, apq, c, s)
    c = c.astype(C.dtype)
    s = s.astype(C.dtype)
    C, V = _apply_rotations_rowcol(C, V, p, q, c, s)
    co_ref[...] = C
    vo_ref[...] = V


def jacobi_sweep_step(
    C: jax.Array,
    V: jax.Array,
    pairs: jax.Array,
    *,
    angle: str = "rutishauser",
    interpret: bool = False,
):
    """Apply one round of disjoint pivot rotations in a single launch.

    C, V: (n, n); pairs: (k, 2) int32 pivot indices (disjoint within the
    round for "parallel", a single pair for "cyclic"/"paper" orderings).
    Returns the rotated (C, V).
    """
    n = C.shape[0]
    k = pairs.shape[0]
    struct = jax.ShapeDtypeStruct((n, n), C.dtype)
    return pl.pallas_call(
        functools.partial(_sweep_kernel, angle=angle),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_shape=[struct, struct],
        interpret=interpret,
        name="jacobi_sweep",
        **compat.compiler_params(dimension_semantics=("arbitrary",)),
    )(C, V, pairs)
