"""MM-Engine: block-streaming tiled matmul (paper Sec. VI-A) as a Pallas
TPU kernel.

FPGA -> TPU mapping: each T x T systolic array becomes one MXU pass over an
MXU-aligned (block_m x block_n) output tile held *stationary* in a VMEM
scratch accumulator (the paper's per-array "matrix accumulator"); operand
tiles stream HBM->VMEM along the contraction grid dimension (the paper's
"block streaming"); the LHS block is re-fetched once per (i, k) and re-used
across the whole j grid row -- the shared-LHS-cache broadcast -- while RHS
blocks are private per (j, k).  The parallelism index S maps onto the
parallel (i, j) grid dimensions.

Accumulation is always fp32 (as is the FPGA accumulator), regardless of the
input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import compat
from .compat import pl


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one streamed tile-product accumulated into the stationary output tile
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def mm_engine(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """a @ b with explicit (block_m, block_n, block_k) VMEM tiling.

    Shapes must be multiples of the block sizes (``ops.mm_engine_matmul``
    pads arbitrary shapes -- the paper's Matrix Padding Unit).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or a.dtype
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[compat.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        name="mm_engine",
        **compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, b)
