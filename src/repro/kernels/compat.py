"""Version-compat layer for the Pallas TPU API surface.

jax moved the TPU lowering parameters around between releases:

* jax <= 0.4.x spells the dataclass ``pltpu.TPUCompilerParams`` and accepts
  ``dimension_semantics`` as a constructor field;
* newer jax renames it ``pltpu.CompilerParams`` (same fields).

A jax exposing neither spelling is explicitly unsupported: the resolution
below fails loudly at the first kernel call instead of guessing at an
untestable legacy kwarg.

Every kernel module imports ``pl``/``pltpu`` and builds its
``compiler_params`` through this module -- it is the ONLY place in the repo
that imports ``jax.experimental.pallas.tpu`` directly, so a future API move
is a one-file fix.  ``PALLAS_API_VARIANT`` names the resolved spelling so CI
logs make version drift visible (see ``scripts/ci.sh``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

from jax.experimental import pallas as pl  # noqa: F401  (re-exported)
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (re-exported)

if hasattr(pltpu, "CompilerParams"):          # jax >= 0.5 spelling
    _COMPILER_PARAMS_CLS = pltpu.CompilerParams
    PALLAS_API_VARIANT = "pltpu.CompilerParams"
elif hasattr(pltpu, "TPUCompilerParams"):     # jax 0.4.x spelling
    _COMPILER_PARAMS_CLS = pltpu.TPUCompilerParams
    PALLAS_API_VARIANT = "pltpu.TPUCompilerParams"
else:
    _COMPILER_PARAMS_CLS = None
    PALLAS_API_VARIANT = "unsupported (no CompilerParams spelling found)"

# scratch memory spaces, re-exported so kernels never touch pltpu directly
VMEM = pltpu.VMEM
SMEM = pltpu.SMEM


def compiler_params(
    dimension_semantics: Optional[Sequence[str]] = None,
    **kwargs: Any,
) -> Dict[str, Any]:
    """kwargs for ``pl.pallas_call`` selecting the TPU compiler parameters.

    Returns ``{"compiler_params": <resolved object>}`` (or ``{}`` when
    nothing was requested) so call sites splat it:

        pl.pallas_call(kernel, ..., **compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")))

    ``dimension_semantics`` entries are the portable spellings
    ``"parallel"`` / ``"arbitrary"``.
    """
    if dimension_semantics is None and not kwargs:
        return {}
    if _COMPILER_PARAMS_CLS is None:
        import jax
        raise RuntimeError(
            f"jax {jax.__version__} exposes neither pltpu.CompilerParams "
            "nor pltpu.TPUCompilerParams; add its spelling to "
            "repro.kernels.compat (the single Pallas-TPU import point)")
    dims = tuple(dimension_semantics) if dimension_semantics else None
    return {"compiler_params": _COMPILER_PARAMS_CLS(
        dimension_semantics=dims, **kwargs)}


def describe() -> str:
    """One-line API resolution summary for CI logs."""
    import jax
    return (f"jax {jax.__version__}: compiler params via {PALLAS_API_VARIANT}")
