"""Selective-scan (Mamba-1 SSM) Pallas kernel.

Perf-critical op for the falcon-mamba / jamba architectures.  The recurrence

    x_t = exp(dt_t * A) * x_{t-1} + (dt_t * u_t) B_t
    y_t = x_t . C_t + D_skip * u_t

is chunked along time: the grid is (batch, n_chunks) with the chunk dimension
sequential, and the (D, N) SSM state lives in a VMEM scratch that persists
across grid steps (the TPU grid is executed in order) -- the same
output/state-stationary streaming pattern as the MM-Engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import compat
from .compat import pl


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref, y_ref,
                 x_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        x_ref[...] = jnp.zeros_like(x_ref)

    a = a_ref[...]              # (D, N)
    dskip = dskip_ref[...]      # (1, D)

    def body(t, x):
        u = u_ref[0, t, :].astype(jnp.float32)       # (D,)
        dt = dt_ref[0, t, :].astype(jnp.float32)     # (D,)
        bt = b_ref[0, t, :].astype(jnp.float32)      # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)      # (N,)
        decay = jnp.exp(dt[:, None] * a)             # (D, N)
        x = decay * x + (dt * u)[:, None] * bt[None, :]
        y = jnp.sum(x * ct[None, :], axis=1) + dskip[0] * u
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return x

    x_ref[...] = lax.fori_loop(0, chunk, body, x_ref[...])


def mamba_scan(
    u: jax.Array,       # (B, L, D)
    delta: jax.Array,   # (B, L, D)  (post-softplus)
    A: jax.Array,       # (D, N)     (negative)
    B: jax.Array,       # (B, L, N)
    C: jax.Array,       # (B, L, N)
    D_skip: jax.Array,  # (D,)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (B, L, D).  L must be a multiple of ``chunk`` (ops.py pads)."""
    bsz, L, d = u.shape
    n = A.shape[1]
    assert L % chunk == 0, (L, chunk)
    grid = (bsz, L // chunk)
    dchunk = lambda b, c: (b, c, 0)
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), dchunk),
            pl.BlockSpec((1, chunk, d), dchunk),
            pl.BlockSpec((1, chunk, n), dchunk),
            pl.BlockSpec((1, chunk, n), dchunk),
            pl.BlockSpec((d, n), lambda b, c: (0, 0)),
            pl.BlockSpec((1, d), lambda b, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), dchunk),
        out_shape=jax.ShapeDtypeStruct((bsz, L, d), u.dtype),
        scratch_shapes=[compat.VMEM((d, n), jnp.float32)],
        interpret=interpret,
        name="mamba_scan",
        **compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(u, delta, B, C, A.astype(jnp.float32),
      D_skip.astype(jnp.float32)[None, :])
