"""Jit'd public wrappers around the Pallas kernels.

Each wrapper (a) pads arbitrary shapes up to block multiples (the paper's
Matrix Padding Unit at the cache/MM-Engine interface), (b) dispatches to the
compiled kernel on TPU and to ``interpret=True`` elsewhere, and (c) exposes
the pure-jnp oracle fallback for gradient-needed paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import mm_engine as _mm
from . import dle as _dle
from . import cordic as _cordic
from . import flash_attention as _fa
from . import mamba_scan as _ms
from . import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mults):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def mm_engine_matmul(a, b, block: int = 128, interpret: bool | None = None):
    """Block-streamed a @ b for arbitrary shapes (paper tile size T=block)."""
    interpret = _interpret() if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (block, block))
    bp = _pad_to(b, (block, block))
    out = _mm.mm_engine(ap, bp, block_m=block, block_n=block, block_k=block,
                        interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def dle_find_pivot(c, tile: int = 128, interpret: bool | None = None):
    """Pivot for the Jacobi step: (p, q, c_pq, c_pp, c_qq) via one scan."""
    interpret = _interpret() if interpret is None else interpret
    n = c.shape[0]
    _, idx = _dle.dle_scan(c, tile=tile, interpret=interpret)
    p = (idx // n).astype(jnp.int32)
    q = (idx % n).astype(jnp.int32)
    d = jnp.diagonal(c)
    from repro.core.dle import Pivot
    return Pivot(p, q, c[p, q], d[p], d[q])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cordic_rotation_params(apq, app, aqq, block: int = 256,
                           interpret: bool | None = None):
    interpret = _interpret() if interpret is None else interpret
    return _cordic.cordic_rotation_params(
        jnp.atleast_1d(apq), jnp.atleast_1d(app), jnp.atleast_1d(aqq),
        block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "q_offset", "interpret"))
def flash_attention(q, k, v, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    q_offset: int = 0, interpret: bool | None = None):
    """q (BH, Sq, D), k/v (BH, Skv, D); pads sequence dims as needed."""
    interpret = _interpret() if interpret is None else interpret
    sq, skv = q.shape[1], k.shape[1]
    qp = _pad_to(q, (1, block_q, 1))
    kp = _pad_to(k, (1, block_k, 1))
    vp = _pad_to(v, (1, block_k, 1))
    if kp.shape[1] != skv:
        # padded KV positions must not attract attention: rely on causal
        # masking when causal, else mask via huge negative bias is needed --
        # we simply require multiples for non-causal.
        assert causal, "non-causal flash requires Skv % block_k == 0"
    out = _fa.flash_attention(qp, kp, vp, causal=causal, scale=scale,
                              block_q=block_q, block_k=block_k,
                              q_offset=q_offset, interpret=interpret)
    return out[:, :sq, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(u, delta, A, B, C, D_skip, chunk: int = 128,
               interpret: bool | None = None):
    interpret = _interpret() if interpret is None else interpret
    L = u.shape[1]
    up = _pad_to(u, (1, chunk, 1))
    dp = _pad_to(delta, (1, chunk, 1))
    bp = _pad_to(B, (1, chunk, 1))
    cp = _pad_to(C, (1, chunk, 1))
    y = _ms.mamba_scan(up, dp, A, bp, cp, D_skip, chunk=chunk,
                       interpret=interpret)
    return y[:, :L, :]


ref = _ref
