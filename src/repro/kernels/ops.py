"""Jit'd public wrappers around the kernel layer, dispatched through the
backend registry (``repro.backends``).

Each public op (a) pads arbitrary shapes up to block multiples (the paper's
Matrix Padding Unit at the cache/MM-Engine interface) and (b) resolves a
named backend implementation per call:

  ``pallas``     compiled Pallas TPU kernel
  ``interpret``  the same kernel under the Pallas interpreter (any host)
  ``ref``        the pure-jnp XLA oracle (``repro.kernels.ref``)

``backend=None`` follows the registry's resolution order (process default,
``REPRO_KERNEL_BACKEND``, else pallas-on-TPU / interpret-elsewhere).  The
legacy ``interpret=`` flag is kept as an alias: ``interpret=True`` means
``backend="interpret"``, ``interpret=False`` means ``backend="pallas"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.backends import registry

from . import mm_engine as _mm
from . import dle as _dle
from . import cordic as _cordic
from . import flash_attention as _fa
from . import fused as _fused
from . import mamba_scan as _ms
from . import ref as _ref


def _backend_name(backend: str | None, interpret: bool | None) -> str:
    if backend is None and interpret is not None:
        backend = "interpret" if interpret else "pallas"
    return registry.default_backend() if backend is None else backend


def _pad_to(x, mults):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


# -- mm_engine_matmul -------------------------------------------------------

def _mm_kernel_impl(a, b, *, block: int, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (block, block))
    bp = _pad_to(b, (block, block))
    out = _mm.mm_engine(ap, bp, block_m=block, block_n=block, block_k=block,
                        interpret=interpret)
    return out[:m, :n]


registry.register("mm_engine_matmul", "pallas")(
    functools.partial(_mm_kernel_impl, interpret=False))
registry.register("mm_engine_matmul", "interpret")(
    functools.partial(_mm_kernel_impl, interpret=True))


@registry.register("mm_engine_matmul", "ref")
def _mm_ref_impl(a, b, *, block: int = 0):
    del block
    return _ref.mm_engine(a, b)


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _mm_dispatch(a, b, block, backend):
    return registry.resolve("mm_engine_matmul", backend)(a, b, block=block)


def mm_engine_matmul(a, b, block: int = 128, *,
                     backend: str | None = None,
                     interpret: bool | None = None):
    """Block-streamed a @ b for arbitrary shapes (paper tile size T=block)."""
    return _mm_dispatch(a, b, block, _backend_name(backend, interpret))


# -- covariance (fused one-pass Gram) ---------------------------------------

def _cov_block_m(m: int, block_m: int) -> int:
    """Effective streaming panel size: one sublane-aligned panel when the
    matrix is shorter than the requested block (small serving buckets must
    not pad up to a huge panel)."""
    return min(block_m, -(-m // 8) * 8)


def _cov_kernel_impl(x, *, block_m: int, precision: str, interpret: bool):
    from repro.core import precision as prec
    m, n = x.shape
    bm = _cov_block_m(m, block_m)
    xp = _pad_to(x, (bm, 1))  # zero sample rows add nothing to the Gram
    xp = xp.astype(prec.operand_dtype(precision))
    return _fused.fused_covariance(
        xp, block_m=bm, acc_dtype=prec.acc_dtype(precision),
        interpret=interpret)


registry.register("covariance", "pallas")(
    functools.partial(_cov_kernel_impl, interpret=False))
registry.register("covariance", "interpret")(
    functools.partial(_cov_kernel_impl, interpret=True))


@registry.register("covariance", "ref")
def _cov_ref_impl(x, *, block_m: int = 0, precision: str = "fp32"):
    del block_m
    from repro.core import precision as prec
    xp = x.astype(prec.operand_dtype(precision))
    return _ref.covariance_gram(xp, acc_dtype=prec.acc_dtype(precision))


@functools.partial(jax.jit, static_argnames=("block_m", "precision",
                                             "normalize", "backend"))
def _cov_dispatch(x, block_m, precision, normalize, backend):
    c = registry.resolve("covariance", backend)(x, block_m=block_m,
                                                precision=precision)
    if normalize:
        c = c / jnp.maximum(x.shape[0] - 1, 1).astype(c.dtype)
    return c


def covariance(x, block_m: int = 1024, *, precision: str = "fp32",
               normalize: bool = False, backend: str | None = None,
               interpret: bool | None = None):
    """Fused one-HBM-pass Gram matrix C = x^T x (paper Sec. VI-A fusion).

    Sample panels of ``block_m`` rows stream through a single launch while
    the full (n, n) accumulator stays stationary on-chip -- vs the unfused
    ``core.covariance.blocked_covariance``, which launches one matmul per
    panel and round-trips each partial C through HBM.  ``precision``
    selects the operand streaming dtype (``repro.core.precision``);
    accumulation never narrows below fp32.  With fp32 operands the result
    is bitwise-identical to ``blocked_covariance`` at the same ``block_m``.
    """
    return _cov_dispatch(x, block_m, precision, normalize,
                         _backend_name(backend, interpret))


# -- jacobi_sweep (fused pivot round) ---------------------------------------

def _sweep_kernel_impl(C, V, pairs, *, angle: str, interpret: bool):
    return _fused.jacobi_sweep_step(C, V, pairs, angle=angle,
                                    interpret=interpret)


registry.register("jacobi_sweep", "pallas")(
    functools.partial(_sweep_kernel_impl, interpret=False))
registry.register("jacobi_sweep", "interpret")(
    functools.partial(_sweep_kernel_impl, interpret=True))


@registry.register("jacobi_sweep", "ref")
def _sweep_ref_impl(C, V, pairs, *, angle: str = "rutishauser"):
    return _ref.jacobi_sweep_step(C, V, pairs, angle=angle)


@functools.partial(jax.jit, static_argnames=("angle", "backend"))
def _sweep_dispatch(C, V, pairs, angle, backend):
    return registry.resolve("jacobi_sweep", backend)(C, V, pairs,
                                                     angle=angle)


def jacobi_sweep(C, V, pairs, *, angle: str = "rutishauser",
                 backend: str | None = None,
                 interpret: bool | None = None):
    """One fused Jacobi pivot round: gather + angle + guard + row/col
    rotation over (C, V) in a single launch (paper's fused Jacobian Unit).
    ``pairs`` is (k, 2) disjoint pivot indices.  Bitwise-identical to the
    unfused ``core.jacobi._sweep_scan`` body for every angle mode."""
    return _sweep_dispatch(C, V, pairs, angle,
                           _backend_name(backend, interpret))


# -- dle_find_pivot ---------------------------------------------------------

def _dle_kernel_impl(c, *, tile: int, interpret: bool):
    from repro.core.dle import Pivot
    n = c.shape[0]
    _, idx = _dle.dle_scan(c, tile=tile, interpret=interpret)
    p = (idx // n).astype(jnp.int32)
    q = (idx % n).astype(jnp.int32)
    d = jnp.diagonal(c)
    return Pivot(p, q, c[p, q], d[p], d[q])


registry.register("dle_find_pivot", "pallas")(
    functools.partial(_dle_kernel_impl, interpret=False))
registry.register("dle_find_pivot", "interpret")(
    functools.partial(_dle_kernel_impl, interpret=True))


@registry.register("dle_find_pivot", "ref")
def _dle_ref_impl(c, *, tile: int = 0):
    del tile
    from repro.core import dle as core_dle
    return core_dle.find_pivot(c)


@functools.partial(jax.jit, static_argnames=("tile", "backend"))
def _dle_dispatch(c, tile, backend):
    return registry.resolve("dle_find_pivot", backend)(c, tile=tile)


def dle_find_pivot(c, tile: int = 128, *, backend: str | None = None,
                   interpret: bool | None = None):
    """Pivot for the Jacobi step: (p, q, c_pq, c_pp, c_qq) via one scan."""
    return _dle_dispatch(c, tile, _backend_name(backend, interpret))


# -- cordic_rotate ----------------------------------------------------------

def _cordic_kernel_impl(apq, app, aqq, *, block: int, interpret: bool):
    return _cordic.cordic_rotation_params(
        jnp.atleast_1d(apq), jnp.atleast_1d(app), jnp.atleast_1d(aqq),
        block=block, interpret=interpret)


registry.register("cordic_rotate", "pallas")(
    functools.partial(_cordic_kernel_impl, interpret=False))
registry.register("cordic_rotate", "interpret")(
    functools.partial(_cordic_kernel_impl, interpret=True))


@registry.register("cordic_rotate", "ref")
def _cordic_ref_impl(apq, app, aqq, *, block: int = 0):
    del block
    return _ref.cordic_rotation_params(
        jnp.atleast_1d(apq), jnp.atleast_1d(app), jnp.atleast_1d(aqq))


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _cordic_dispatch(apq, app, aqq, block, backend):
    return registry.resolve("cordic_rotate", backend)(apq, app, aqq,
                                                      block=block)


def cordic_rotation_params(apq, app, aqq, block: int = 256, *,
                           backend: str | None = None,
                           interpret: bool | None = None):
    return _cordic_dispatch(apq, app, aqq, block,
                            _backend_name(backend, interpret))


cordic_rotate = cordic_rotation_params  # registry op name alias


# -- flash_attention --------------------------------------------------------

def _fa_kernel_impl(q, k, v, *, causal, scale, block_q, block_k, q_offset,
                    interpret):
    sq, skv = q.shape[1], k.shape[1]
    qp = _pad_to(q, (1, block_q, 1))
    kp = _pad_to(k, (1, block_k, 1))
    vp = _pad_to(v, (1, block_k, 1))
    if kp.shape[1] != skv:
        # padded KV positions must not attract attention: rely on causal
        # masking when causal, else mask via huge negative bias is needed --
        # we simply require multiples for non-causal.
        assert causal, "non-causal flash requires Skv % block_k == 0"
    out = _fa.flash_attention(qp, kp, vp, causal=causal, scale=scale,
                              block_q=block_q, block_k=block_k,
                              q_offset=q_offset, interpret=interpret)
    return out[:, :sq, :]


registry.register("flash_attention", "pallas")(
    functools.partial(_fa_kernel_impl, interpret=False))
registry.register("flash_attention", "interpret")(
    functools.partial(_fa_kernel_impl, interpret=True))


@registry.register("flash_attention", "ref")
def _fa_ref_impl(q, k, v, *, causal, scale, block_q=0, block_k=0,
                 q_offset=0):
    del block_q, block_k
    return _ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                q_offset=q_offset)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "q_offset", "backend"))
def _fa_dispatch(q, k, v, causal, scale, block_q, block_k, q_offset,
                 backend):
    return registry.resolve("flash_attention", backend)(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, q_offset=q_offset)


def flash_attention(q, k, v, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    q_offset: int = 0, *, backend: str | None = None,
                    interpret: bool | None = None):
    """q (BH, Sq, D), k/v (BH, Skv, D); pads sequence dims as needed."""
    return _fa_dispatch(q, k, v, causal, scale, block_q, block_k, q_offset,
                        _backend_name(backend, interpret))


# -- mamba_scan -------------------------------------------------------------

def _ms_kernel_impl(u, delta, A, B, C, D_skip, *, chunk, interpret):
    L = u.shape[1]
    up = _pad_to(u, (1, chunk, 1))
    dp = _pad_to(delta, (1, chunk, 1))
    bp = _pad_to(B, (1, chunk, 1))
    cp = _pad_to(C, (1, chunk, 1))
    y = _ms.mamba_scan(up, dp, A, bp, cp, D_skip, chunk=chunk,
                       interpret=interpret)
    return y[:, :L, :]


registry.register("mamba_scan", "pallas")(
    functools.partial(_ms_kernel_impl, interpret=False))
registry.register("mamba_scan", "interpret")(
    functools.partial(_ms_kernel_impl, interpret=True))


@registry.register("mamba_scan", "ref")
def _ms_ref_impl(u, delta, A, B, C, D_skip, *, chunk: int = 0):
    del chunk
    return _ref.mamba_scan(u, delta, A, B, C, D_skip)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _ms_dispatch(u, delta, A, B, C, D_skip, chunk, backend):
    return registry.resolve("mamba_scan", backend)(u, delta, A, B, C,
                                                   D_skip, chunk=chunk)


def mamba_scan(u, delta, A, B, C, D_skip, chunk: int = 128, *,
               backend: str | None = None, interpret: bool | None = None):
    return _ms_dispatch(u, delta, A, B, C, D_skip, chunk,
                        _backend_name(backend, interpret))


ref = _ref
