"""Pipelined CORDIC Pallas kernel (paper Sec. VI-C).

Computes theta = -1/2*atan2(2*c_pq, c_pp - c_qq), sin(theta), cos(theta) for
a *batch* of pivots in Q2.29 fixed point -- the vectorised analogue of the
paper's pipelined CORDIC arctangent unit, 1-bit right shifter, and parallel
sin/cos rotators.  On TPU the VPU executes each shift-add micro-rotation
across all lanes at once; the pipeline depth of the RTL becomes the
fori_loop trip count.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cordic import CORDIC_ITERS, _ATAN_FIXED, _GAIN, _FRAC_BITS

from . import compat
from .compat import pl

_ONE_F = float(1 << _FRAC_BITS)


def _cordic_kernel(apq_ref, app_ref, aqq_ref, th_ref, c_ref, s_ref, *,
                   iters: int):
    y = 2.0 * apq_ref[...]
    x = app_ref[...] - aqq_ref[...]

    # front-end barrel shift: shared power-of-two normalisation into Q2.29
    mag = jnp.maximum(jnp.maximum(jnp.abs(y), jnp.abs(x)), 1e-30)
    scale = jnp.exp2(-jnp.ceil(jnp.log2(mag)))
    yn = y * scale
    xn = x * scale
    neg_x = xn < 0
    xi = jnp.round(jnp.where(neg_x, -xn, xn) * _ONE_F).astype(jnp.int32)
    yi = jnp.round(jnp.where(neg_x, -yn, yn) * _ONE_F).astype(jnp.int32)
    zi = jnp.zeros_like(xi)

    # unrolled pipeline stages (as in the RTL); the atan table entries are
    # per-stage scalar constants, not a captured array
    for i in range(iters):
        d = jnp.where(yi >= 0, 1, -1).astype(jnp.int32)
        xi, yi, zi = (xi + d * (yi >> i), yi - d * (xi >> i),
                      zi + d * jnp.int32(int(_ATAN_FIXED[i])))
    ang = zi.astype(jnp.float32) / _ONE_F
    pi = jnp.float32(np.pi)
    ang = jnp.where(neg_x, jnp.where(y >= 0, ang + pi, ang - pi), ang)

    # the 1-bit right shift (sign-corrected, see core/cordic.py)
    theta = -0.5 * ang

    # rotation mode: parallel sin/cos lanes
    zr = jnp.round(theta * _ONE_F).astype(jnp.int32)
    xr = jnp.full(zr.shape, np.int32(round(_ONE_F / _GAIN)), jnp.int32)
    yr = jnp.zeros_like(xr)

    for i in range(iters):
        d = jnp.where(zr >= 0, 1, -1).astype(jnp.int32)
        xr, yr, zr = (xr - d * (yr >> i), yr + d * (xr >> i),
                      zr - d * jnp.int32(int(_ATAN_FIXED[i])))
    th_ref[...] = theta
    c_ref[...] = xr.astype(jnp.float32) / _ONE_F
    s_ref[...] = yr.astype(jnp.float32) / _ONE_F


def cordic_rotation_params(
    apq: jax.Array,
    app: jax.Array,
    aqq: jax.Array,
    *,
    block: int = 256,
    iters: int = CORDIC_ITERS,
    interpret: bool = False,
):
    """(theta, cos, sin) for each pivot; 1-D inputs of any common length."""
    (k,) = apq.shape
    pad = (-k) % block
    if pad:
        apq = jnp.pad(apq, (0, pad))
        app = jnp.pad(app, (0, pad), constant_values=1.0)
        aqq = jnp.pad(aqq, (0, pad))
    n = apq.shape[0]
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    th, c, s = pl.pallas_call(
        functools.partial(_cordic_kernel, iters=iters),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=interpret,
        name="cordic",
        **compat.compiler_params(dimension_semantics=("parallel",)),
    )(apq.astype(jnp.float32), app.astype(jnp.float32),
      aqq.astype(jnp.float32))
    return th[:k], c[:k], s[:k]
