"""Beyond-paper: PCA compression of KV caches for long-context serving.

The head_dim axis of K/V is empirically low-rank for long prompts; the
MANOJAVAM Jacobi engine eigendecomposes the per-head K (and V) covariance
(head_dim x head_dim -- a natural fit for the fabric) and the cache is
stored in the top-r eigenbasis:

    K' = K @ Vk   (B, S, KV, r)      memory ratio r / head_dim

Attention against a compressed cache is exact in the retained subspace:
scores = (q @ Vk) . K', output = (w @ V') @ Vv^T -- two small projections
per step in exchange for an r/head_dim cache.  ``attention_error`` reports
the end-to-end attention-output error so serving can pick r per layer
(same EVCR machinery as the gradient-compression rank suggestion).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.jacobi import jacobi_eigh
from repro.core.pca import evcr_cvcr


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    rank: int = 32
    sweeps: int = 12


class CompressedKV(NamedTuple):
    k: jax.Array        # (B, S, KV, r)
    v: jax.Array        # (B, S, KV, r)
    basis_k: jax.Array  # (KV, hd, r)
    basis_v: jax.Array  # (KV, hd, r)


def _per_head_basis(x, rank: int, sweeps: int):
    """x: (B, S, KV, hd) -> (KV, hd, rank) top-r eigenbasis per head."""
    b, s, kv, hd = x.shape
    xf = x.astype(jnp.float32).transpose(2, 0, 1, 3).reshape(kv, b * s, hd)
    gram = jnp.einsum("ktd,kte->kde", xf, xf) / (b * s)

    def eig_one(c):
        res = jacobi_eigh(c, sweeps=sweeps, pivot="parallel")
        return res.eigenvectors[:, :rank], res.eigenvalues

    bases, eigs = jax.vmap(eig_one)(gram)
    return bases, eigs


def compress(cache_k, cache_v, cfg: KVCompressionConfig) -> CompressedKV:
    bk, _ = _per_head_basis(cache_k, cfg.rank, cfg.sweeps)
    bv, _ = _per_head_basis(cache_v, cfg.rank, cfg.sweeps)
    kc = jnp.einsum("bskd,kdr->bskr", cache_k.astype(jnp.float32), bk)
    vc = jnp.einsum("bskd,kdr->bskr", cache_v.astype(jnp.float32), bv)
    return CompressedKV(kc.astype(cache_k.dtype), vc.astype(cache_v.dtype),
                        bk, bv)


def decompress(c: CompressedKV) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bskr,kdr->bskd", c.k.astype(jnp.float32), c.basis_k)
    v = jnp.einsum("bskr,kdr->bskd", c.v.astype(jnp.float32), c.basis_v)
    return k, v


def attention_compressed(q, c: CompressedKV, scale: float):
    """q: (B, KV, G, hd) grouped query; attention directly in the
    compressed basis (no decompression of the cache)."""
    qk = jnp.einsum("bkgd,kdr->bkgr", q.astype(jnp.float32), c.basis_k)
    s = jnp.einsum("bkgr,bskr->bkgs", qk, c.k.astype(jnp.float32)) * scale
    w = jax.nn.softmax(s, axis=-1)
    out_r = jnp.einsum("bkgs,bskr->bkgr", w, c.v.astype(jnp.float32))
    return jnp.einsum("bkgr,kdr->bkgd", out_r, c.basis_v)


def attention_exact(q, cache_k, cache_v, scale: float):
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * scale
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", w, cache_v.astype(jnp.float32))


def attention_error(q, cache_k, cache_v, cfg: KVCompressionConfig,
                    scale: float):
    """Relative L2 error of attention output under compression + the
    achieved memory ratio.  Serving uses this to pick r per layer."""
    c = compress(cache_k, cache_v, cfg)
    exact = attention_exact(q, cache_k, cache_v, scale)
    approx = attention_compressed(q, c, scale)
    err = jnp.linalg.norm(approx - exact) / jnp.maximum(
        jnp.linalg.norm(exact), 1e-12)
    ratio = cfg.rank / cache_k.shape[-1]
    return err, ratio


def suggest_rank(cache_k, coverage: float = 0.99, sweeps: int = 12) -> int:
    """Smallest rank whose worst-head CVCR reaches ``coverage``."""
    _, eigs = _per_head_basis(cache_k, cache_k.shape[-1], sweeps)
    cvcrs = jax.vmap(lambda e: evcr_cvcr(e)[1])(eigs)
    worst = cvcrs.min(axis=0)
    return int(jnp.argmax(worst >= coverage)) + 1
