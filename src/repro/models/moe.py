"""Expert-parallel Mixture-of-Experts with capacity-based top-k dispatch.

Routing (router logits, top-k gates, load-balance aux) runs as plain SPMD
jnp -- it partitions cleanly.  Dispatch/expert-compute/combine runs inside an
explicit ``shard_map``: activations are sharded over the batch ("data")
axes and *replicated* over the "model" axis, experts are sharded over
"model", so each shard scatters its local tokens into the buffers of its
local experts with NO cross-shard traffic; a single psum over "model"
combines expert outputs.  (The naive pjit scatter forces XLA to all-reduce
the full global dispatch buffer per layer -- measured 17 TB/device/step on
arctic-480b train_4k -- which this formulation eliminates; see EXPERIMENTS
§Perf.)

Supports top-1/top-2, a shared always-on expert (llama4) and a parallel
dense residual FFN (arctic, handled at the block level).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import Px, shard_map_compat
from .config import ModelConfig
from .layers import _normal


def init_moe(key, cfg: ModelConfig):
    dt = cfg.jdtype()
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": Px(_normal(ks[0], (d, E), jnp.float32, si), (None, None)),
        "wi": Px(_normal(ks[1], (E, d, f), dt, si), ("expert", "fsdp", None)),
        "wg": Px(_normal(ks[2], (E, d, f), dt, si), ("expert", "fsdp", None)),
        "wo": Px(_normal(ks[3], (E, f, d), dt, so), ("expert", None, "fsdp")),
    }
    return p


def capacity(tokens: int, cfg: ModelConfig) -> int:
    """Per-expert slot count for ``tokens`` routed tokens."""
    c = math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(1, c)
    if c > 8:
        c += (-c) % 8
    return min(tokens * cfg.top_k, c)


def _routing(p, xf, cfg: ModelConfig):
    """(gate, idx, aux) from flat tokens (T, d)."""
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0) / (xf.shape[0] * k)
    aux = E * jnp.sum(me * ce)
    return gate, idx, aux


def _dispatch_compute_combine(xf, gate, idx, wi, wg, wo, *, E: int, k: int,
                              C: int, e0, E_local: int):
    """Local dispatch -> expert FFN -> combine for ``E_local`` experts
    starting at global id ``e0``.  xf: (T, d) local tokens."""
    T, d = xf.shape
    e_flat = idx.T.reshape(-1)                          # (k*T,) slot-major
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.float32)
    pos = jnp.cumsum(onehot, axis=0) - 1.0
    pos = jnp.einsum("te,te->t", pos, onehot).astype(jnp.int32)
    keep = pos < C
    rel = e_flat - e0
    mine = keep & (rel >= 0) & (rel < E_local)
    relc = jnp.clip(rel, 0, E_local - 1)
    slot = jnp.minimum(pos, C - 1)

    tok_ids = jnp.tile(jnp.arange(T), k)
    buf = jnp.zeros((E_local, C, d), xf.dtype)
    buf = buf.at[relc, slot].add(
        xf[tok_ids] * mine[:, None].astype(xf.dtype))

    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)

    y_tok = y_e[relc, slot] * mine[:, None].astype(y_e.dtype)
    gates_flat = gate.T.reshape(-1)[:, None].astype(y_tok.dtype)
    return (y_tok * gates_flat).reshape(k, T, d).sum(0)


def _dense_partial(x_l, wi, wg, wo, mlp_kind: str):
    """Column/row-parallel dense FFN on a model shard; returns the PARTIAL
    (pre-psum) output so it can share the MoE combine's all-reduce."""
    h = jnp.einsum("td,df->tf", x_l, wi)
    if wg is not None:
        h = jax.nn.silu(jnp.einsum("td,df->tf", x_l, wg)) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("tf,fd->td", h, wo)


def apply_moe(p, x, cfg: ModelConfig, rules, mlp_res=None, mlp_shared=None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    ``mlp_res`` / ``mlp_shared``: optional dense FFN param dicts (arctic's
    dense residual, llama4's shared expert).  When given, their partial
    outputs are summed with the MoE partial INSIDE the shard_map so the
    whole FFN sublayer costs a single (tokens, d) psum per layer
    (EXPERIMENTS §Perf: -1 activation all-reduce per layer fwd+bwd).
    """
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(b * s, d)
    xf = rules.shard(xf, "batch", None)

    ep_axis = rules.axis("expert")
    if ep_axis is None or rules.mesh is None:
        # single-shard path (smoke tests): plain local dispatch
        gate, idx, aux = _routing(p, xf, cfg)
        C = capacity(b * s, cfg)
        y = _dispatch_compute_combine(xf, gate, idx, p["wi"], p["wg"],
                                      p["wo"], E=E, k=k, C=C,
                                      e0=jnp.int32(0), E_local=E)
        for mlp_p in (mlp_res, mlp_shared):
            if mlp_p is not None:
                y = y + _dense_partial(xf, mlp_p["wi"], mlp_p.get("wg"),
                                       mlp_p["wo"], cfg.mlp)
        return y.reshape(b, s, d).astype(x.dtype), aux

    mesh = rules.mesh
    tp = mesh.shape[ep_axis]
    E_local = E // tp
    batch_ax = rules.axis("batch")
    n_batch_shards = 1
    for a in (batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)):
        if a:
            n_batch_shards *= mesh.shape[a]
    T_local = (b * s) // n_batch_shards
    C = capacity(T_local, cfg)
    fsdp_ax = rules.axis("fsdp")
    batch_axes = tuple(a for a in (batch_ax if isinstance(batch_ax, tuple)
                                   else (batch_ax,)) if a)

    n_mlps = (mlp_res is not None) + (mlp_shared is not None)

    def local(xf_l, wi_l, wg_l, wo_l, *mlps):
        # routing stays local to the data shard (no global probs tensor)
        gate_l, idx_l, aux_parts = _routing_local(p["router"], xf_l, cfg)
        if fsdp_ax is not None:
            wi_l = jax.lax.all_gather(wi_l, fsdp_ax, axis=1, tiled=True)
            wg_l = jax.lax.all_gather(wg_l, fsdp_ax, axis=1, tiled=True)
            wo_l = jax.lax.all_gather(wo_l, fsdp_ax, axis=2, tiled=True)
        e0 = jax.lax.axis_index(ep_axis) * E_local
        y_l = _dispatch_compute_combine(
            xf_l, gate_l, idx_l, wi_l, wg_l, wo_l,
            E=E, k=k, C=C, e0=e0, E_local=E_local)
        # dense residual / shared expert share the same psum
        for j in range(n_mlps):
            mwi, mwg, mwo = mlps[3 * j: 3 * j + 3]
            if fsdp_ax is not None:
                mwi = jax.lax.all_gather(mwi, fsdp_ax, axis=0, tiled=True)
                if mwg is not None:
                    mwg = jax.lax.all_gather(mwg, fsdp_ax, axis=0,
                                             tiled=True)
                mwo = jax.lax.all_gather(mwo, fsdp_ax, axis=1, tiled=True)
            y_l = y_l + _dense_partial(xf_l, mwi, mwg, mwo, cfg.mlp)
        y = jax.lax.psum(y_l.astype(xf_l.dtype), ep_axis)
        # aux load-balance loss: (E,)-sized stats reduced over data shards
        me_sum, ce_cnt, n_tok = aux_parts
        if batch_axes:
            me_sum = jax.lax.psum(me_sum, batch_axes)
            ce_cnt = jax.lax.psum(ce_cnt, batch_axes)
            n_tok = jax.lax.psum(n_tok, batch_axes)
        me = me_sum / n_tok
        ce = ce_cnt / (n_tok * cfg.top_k)
        aux = E * jnp.sum(me * ce)
        return y, aux

    tok_spec = P(batch_ax, None)
    mlp_args = []
    mlp_specs = []
    for mlp_p in (mlp_res, mlp_shared):
        if mlp_p is not None:
            mlp_args += [mlp_p["wi"], mlp_p.get("wg"), mlp_p["wo"]]
            mlp_specs += [P(fsdp_ax, ep_axis), P(fsdp_ax, ep_axis),
                          P(ep_axis, fsdp_ax)]
    y, aux = shard_map_compat(
        local, mesh=mesh,
        in_specs=(tok_spec,
                  P(ep_axis, fsdp_ax, None), P(ep_axis, fsdp_ax, None),
                  P(ep_axis, None, fsdp_ax), *mlp_specs),
        out_specs=(tok_spec, P()),
    )(xf, p["wi"], p["wg"], p["wo"], *mlp_args)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _routing_local(router, xf_l, cfg: ModelConfig):
    """Per-shard routing; returns (gate, idx, (me_sum, ce_cnt, n_tokens))
    for the cross-shard aux reduction."""
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xf_l.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    me_sum = probs.sum(0)
    ce_cnt = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    return gate, idx, (me_sum, ce_cnt, jnp.float32(xf_l.shape[0]))
