"""Shared layer primitives: norms, RoPE, MLPs, embeddings.

All parameters are ``Px(value, roles)`` leaves (see parallel/sharding.py);
forward functions take a ``Rules`` object for activation constraints and are
dtype-polymorphic (compute in fp32 where it matters, store in cfg dtype).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import Px
from .config import ModelConfig


def _normal(key, shape, dtype, scale):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig):
    if cfg.norm == "nonparametric":      # olmo: no scale / bias
        return {}
    p = {"scale": Px(jnp.ones((cfg.d_model,), jnp.float32), (None,))}
    if cfg.norm == "layernorm":
        p["bias"] = Px(jnp.zeros((cfg.d_model,), jnp.float32), (None,))
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), -1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        xf = xf * p["scale"] + p["bias"]
    return xf.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D), positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(n_pos: int, d: int):
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


# ---------------------------------------------------------------------------
# dense / MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dt = cfg.jdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(cfg.d_model)
    scale_out = 1.0 / math.sqrt(d_ff)
    p = {
        "wi": Px(_normal(k1, (cfg.d_model, d_ff), dt, scale_in), ("fsdp", "tp")),
        "wo": Px(_normal(k3, (d_ff, cfg.d_model), dt, scale_out), ("tp", "fsdp")),
    }
    if cfg.mlp == "swiglu":
        p["wg"] = Px(_normal(k2, (cfg.d_model, d_ff), dt, scale_in), ("fsdp", "tp"))
    return p


def apply_mlp(p, x, cfg: ModelConfig, rules):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = rules.shard(h, "batch", "seq", "tp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    dt = cfg.jdtype()
    k1, k2 = jax.random.split(key)
    p = {
        "tok": Px(_normal(k1, (cfg.padded_vocab, cfg.d_model), dt, 0.02),
                  ("vocab", "fsdp")),
    }
    if not cfg.tie_embeddings:
        p["head"] = Px(
            _normal(k2, (cfg.d_model, cfg.padded_vocab), dt,
                    1.0 / math.sqrt(cfg.d_model)), ("fsdp", "vocab"))
    if cfg.pos_embed == "learned":
        p["pos"] = Px(_normal(jax.random.fold_in(key, 7),
                              (4096, cfg.d_model), dt, 0.02), (None, "fsdp"))
    return p


def embed_tokens(p, tokens, cfg: ModelConfig, rules):
    x = jnp.take(p["tok"], tokens, axis=0)
    return rules.shard(x, "batch", "seq", None)


def unembed(p, x, cfg: ModelConfig, rules):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = rules.shard(logits, "batch", "seq", "vocab")
    # mask padded vocab entries out of the softmax support
    vmask = jax.lax.broadcasted_iota(jnp.int32, (1, 1, cfg.padded_vocab), 2)
    return jnp.where(vmask < cfg.vocab_size, logits, -1e30)
