"""GQA/MQA/MHA attention with TP head-padding, chunked (flash-style) train
attention, and sequence-sharded KV-cache decode (SP).

Design notes (see DESIGN.md "Parallelism design"):
  * Query heads are padded to a multiple of the TP width; padded-head q
    projections are zero-initialised and the attention output is masked on
    the padded heads, which keeps both the forward math and all gradients
    exact while letting every arch shard heads over "model".
  * K/V projections keep the TRUE head count and are replicated over TP
    (they are small); for train/prefill they are gathered into per-query-head
    form (group replication -- standard Megatron GQA) and sharded.
  * Decode attends with true KV heads against a KV cache sharded on the
    SEQUENCE dim ("seq_tp"): a distributed softmax (partial max/denominator
    reduced by XLA across shards) makes 32k-500k KV fit at any head count.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import Px
from .config import ModelConfig
from .layers import _normal, apply_rope

_NEG = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, S, KV, hd)
    v: jax.Array  # (B, S, KV, hd)


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    dt = cfg.jdtype()
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.padded_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    sq = 1.0 / math.sqrt(d)
    wq = _normal(ks[0], (d, H, hd), dt, sq)
    if H > cfg.n_heads:  # zero the padded head slice (exactness, see above)
        wq = wq.at[:, cfg.n_heads:, :].set(0)
    # ring mode: heads are NOT the parallel dim -> attention weights are
    # replicated over "model" (sharded only via fsdp)
    head_tp = None if cfg.attn_impl == "ring" else "tp"
    p = {
        "wq": Px(wq, ("fsdp", head_tp, None)),
        "wk": Px(_normal(ks[1], (d, KV, hd), dt, sq), ("fsdp", None, None)),
        "wv": Px(_normal(ks[2], (d, KV, hd), dt, sq), ("fsdp", None, None)),
        "wo": Px(_normal(ks[3], (H, hd, d), dt, 1.0 / math.sqrt(H * hd)),
                 (head_tp, None, "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = Px(jnp.zeros((H, hd), dt), (head_tp, None))
        p["bk"] = Px(jnp.zeros((KV, hd), dt), (None, None))
        p["bv"] = Px(jnp.zeros((KV, hd), dt), (None, None))
    return p


def _kv_map(cfg: ModelConfig) -> np.ndarray:
    """query-head -> kv-head index (padded heads clamp to the last group)."""
    g = cfg.group_size
    return np.minimum(np.arange(cfg.padded_heads) // g, cfg.n_kv_heads - 1)


def _head_mask(cfg: ModelConfig, dtype):
    m = (np.arange(cfg.padded_heads) < cfg.n_heads).astype(np.float32)
    return jnp.asarray(m, dtype)[None, None, :, None]


def _qkv(p, x, kv_x, cfg: ModelConfig, rules, positions, kv_positions,
         rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    q = rules.shard(q, "batch", "seq", "tp", None)
    return q, k, v


def _expand_kv(k, cfg: ModelConfig, rules):
    """replicate true KV heads into padded query-head layout, then shard."""
    k = jnp.take(k, jnp.asarray(_kv_map(cfg)), axis=2)
    return rules.shard(k, "batch", "seq", "tp", None)


def _dense_attention(q, k, v, causal: bool, scale: float):
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), sk - sq)
        s = jnp.where(mask[None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(q.dtype)


def _chunked_attention(q, k, v, causal: bool, scale: float, chunk: int):
    """Online-softmax over KV chunks (flash dataflow in pure jnp): keeps the
    peak score tensor at (B, H, Sq, chunk)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    nc = skv // chunk
    assert skv % chunk == 0
    qf = q.astype(jnp.float32)
    kc = k.reshape(b, nc, chunk, h, hd).swapaxes(0, 1).astype(jnp.float32)
    vc = v.reshape(b, nc, chunk, h, hd).swapaxes(0, 1).astype(jnp.float32)

    rows = jnp.arange(sq)[:, None] + (skv - sq)  # absolute q positions

    def step(carry, inputs):
        m, l, acc, j = carry[0], carry[1], carry[2], carry[3]
        kj, vj = inputs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj) * scale
        if causal:
            cols = j * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where((rows >= cols)[None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p_, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p_, vj)
        return (m_new, l, acc, j + 1), None

    m0 = jnp.full((b, h, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc, _), _ = lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)  # (B, Sq, H, hd)


def self_attention(p, x, cfg: ModelConfig, rules, positions, *,
                   causal: bool = True, chunk: Optional[int] = None,
                   return_cache: bool = False):
    """Train / prefill self-attention over the full sequence."""
    chunk = chunk or cfg.attn_chunk
    rope = cfg.pos_embed == "rope"
    q, k_true, v_true = _qkv(p, x, x, cfg, rules, positions, positions, rope)
    scale = cfg.head_dim ** -0.5
    if (cfg.attn_impl == "ring" and rules.mesh is not None
            and rules.axis("seq_tp")):
        from repro.parallel.ring_attention import ring_attention
        # TRUE GQA KV rotates (G x fewer ppermute bytes); group expansion
        # happens inside the ring body
        q = rules.shard(q, "batch", "seq_tp", None, None)
        kx = rules.shard(k_true, "batch", "seq_tp", None, None)
        vx = rules.shard(v_true, "batch", "seq_tp", None, None)
        batch_ax = rules.axis("batch")
        out = ring_attention(
            q, kx, vx, rules.mesh, seq_axis=rules.axis("seq_tp"),
            batch_axes=(batch_ax if isinstance(batch_ax, tuple)
                        else (batch_ax,)),
            causal=causal, scale=scale, unroll=not cfg.scan_layers)
        out = rules.shard(out, "batch", "seq_tp", None, None)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        if not return_cache:
            return y, None
        cache = KVCache(
            rules.shard(k_true, "batch", "seq_tp", None, None),
            rules.shard(v_true, "batch", "seq_tp", None, None))
        return y, cache
    k = _expand_kv(k_true, cfg, rules)
    v = _expand_kv(v_true, cfg, rules)
    if x.shape[1] > chunk and x.shape[1] % chunk == 0:
        out = _chunked_attention(q, k, v, causal, scale, chunk)
    else:
        out = _dense_attention(q, k, v, causal, scale)
    out = out * _head_mask(cfg, out.dtype)
    out = rules.shard(out, "batch", "seq", "tp", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if not return_cache:
        return y, None
    cache = KVCache(
        rules.shard(k_true, "batch", "seq_tp", None, None),
        rules.shard(v_true, "batch", "seq_tp", None, None))
    return y, cache


def cross_attention(p, x, enc_kv: KVCache, cfg: ModelConfig, rules):
    """Decoder->encoder attention against precomputed (cached) enc K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = rules.shard(q, "batch", "seq", "tp", None)
    k = _expand_kv(enc_kv.k, cfg, rules)
    v = _expand_kv(enc_kv.v, cfg, rules)
    out = _dense_attention(q, k, v, False, cfg.head_dim ** -0.5)
    out = out * _head_mask(cfg, out.dtype)
    out = rules.shard(out, "batch", "seq", "tp", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def decode_attention(p, x, cache: KVCache, pos, cfg: ModelConfig, rules, *,
                     cross: bool = False):
    """One-token decode against a sequence-sharded KV cache.

    x: (B, 1, d); cache.k/v: (B, S, KV, hd) sharded ("batch","seq_tp",-,-).
    Distributed softmax: the max/denominator reductions over the sharded S
    dim lower to all-reduces; the new token's self-term is merged in closed
    form, so nothing is ever concatenated across the sharded axis.
    Returns (y, new_cache); for cross attention the cache is static.
    """
    B = x.shape[0]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    H, G = cfg.n_heads, cfg.group_size
    rope = cfg.pos_embed == "rope" and not cross

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, :, :H, :]  # true heads
    if "bq" in p:
        q = q + p["bq"][:H]
    if rope:
        q = apply_rope(q, jnp.broadcast_to(pos[None, None], (B, 1)),
                       cfg.rope_theta)
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scale = hd ** -0.5

    kc = cache.k.astype(jnp.float32)
    vc = cache.v.astype(jnp.float32)
    s_cache = jnp.einsum("bkgd,bskd->bkgs", qg, kc) * scale  # (B,KV,G,S)
    # mask never-written cache slots (prefill length tracked via pos)
    valid = jnp.arange(kc.shape[1])[None, None, None, :] < pos
    s_cache = jnp.where(valid, s_cache, _NEG)

    if cross:
        w = jax.nn.softmax(s_cache, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", w, vc)
        new_cache = cache
    else:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bk" in p:
            k_new = k_new + p["bk"]
            v_new = v_new + p["bv"]
        if rope:
            k_new = apply_rope(k_new, jnp.broadcast_to(pos[None, None], (B, 1)),
                               cfg.rope_theta)
        s_self = jnp.einsum("bkgd,bokd->bkgo", qg,
                            k_new.astype(jnp.float32))[..., 0] * scale
        m = jnp.maximum(jnp.max(s_cache, axis=-1), s_self)      # all-reduce max
        e_cache = jnp.exp(s_cache - m[..., None])
        e_self = jnp.exp(s_self - m)
        denom = jnp.sum(e_cache, axis=-1) + e_self              # all-reduce sum
        out = (jnp.einsum("bkgs,bskd->bkgd", e_cache, vc)
               + e_self[..., None] * v_new.astype(jnp.float32)[:, 0, :, None, :]
               ) / denom[..., None]
        # ring-buffer write of the new token at pos % S
        slot = pos % cache.k.shape[1]
        new_k = lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, 1)
        new_v = lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, 1)
        new_cache = KVCache(rules.shard(new_k, "batch", "seq_tp", None, None),
                            rules.shard(new_v, "batch", "seq_tp", None, None))

    out = out.reshape(B, 1, KV * G, hd).astype(x.dtype)
    if cfg.padded_heads > H:
        out = jnp.pad(out, ((0, 0), (0, 0), (0, cfg.padded_heads - H), (0, 0)))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype) -> KVCache:
    shape = (batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_axes() -> KVCache:
    ax = ("batch", "seq_tp", None, None)
    return KVCache(ax, ax)
