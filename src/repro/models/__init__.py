from .config import ModelConfig
from . import attention, layers, mamba, moe, transformer

__all__ = ["ModelConfig", "attention", "layers", "mamba", "moe",
           "transformer"]
