"""Composable model configuration covering all assigned architecture
families: dense / MoE / SSM / hybrid / encoder-decoder / VLM backbones."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.parallel.sharding import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 64

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    moe_every: int = 1           # MoE replaces the MLP every k-th layer
    dense_residual: bool = False # arctic: parallel dense FFN next to MoE
    shared_expert: bool = False  # llama4: always-on shared expert
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0
    d_conv: int = 4
    attn_every: int = 0          # hybrid: 1 attention layer per this many
                                 # (0 = pure attention, -1 = attention-free)

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    n_frames: int = 1500         # stub audio frontend context

    # --- VLM (llava) ---
    n_patches: int = 0           # stub vision frontend patches

    # --- misc ---
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparametric
    mlp: str = "swiglu"          # swiglu | gelu
    qkv_bias: bool = False
    pos_embed: str = "rope"      # rope | learned
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # lowering knobs: scan_layers=False unrolls the group stack (used by the
    # dry-run cost extraction, where while-loop bodies would be counted once)
    scan_layers: bool = True
    # "chunked" = padded-head TP attention with online-softmax KV chunks;
    # "ring" = sequence-parallel ring attention (no head padding; attention
    # params replicated over "model", activations seq-sharded)
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    mamba_chunk: int = 256
    # SSM scan element dtype: the (B,S,d_inner,N) scan tensors dominate HBM
    # traffic; "bfloat16" halves it (fp32 is the numerically-safe default)
    ssm_dtype: str = "float32"
    # "scan" = jnp chunked associative scan; "kernel_proxy" = lowering stand-
    # in with the Pallas mamba_scan kernel's exact HBM I/O (reads u/dt/B/C
    # once, writes y once; state lives in VMEM) -- used by the dry-run to
    # measure the fused kernel's roofline, NOT a numerics path
    ssm_impl: str = "scan"

    # --- sharding-derived (computed) ---
    tp: int = 16                 # model-axis size the padded dims target

    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def d_inner(self) -> int:   # mamba inner width
        return 2 * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def padded_heads(self) -> int:
        """Query heads padded so TP divides them (zero-padded output rows
        keep the math exact; waste charged in the roofline).  Ring mode
        shards sequence instead of heads -> no padding."""
        if self.attn_impl == "ring":
            return self.n_heads
        return pad_to_multiple(self.n_heads, self.tp)

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.tp * 8)

    @property
    def group_size(self) -> int:  # query heads per KV head (GQA)
        return self.n_heads // self.n_kv_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer sequence of "attn" / "mamba" mixers."""
        if self.family == "ssm":
            return ("mamba",) * self.n_layers
        if self.family == "hybrid":
            k = self.attn_every
            assert k > 0 and self.n_layers % k == 0
            kinds = []
            for i in range(self.n_layers):
                kinds.append("attn" if (i % k) == (k - 1) else "mamba")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def ffn_kinds(self) -> Tuple[str, ...]:
        """Per-layer "mlp" / "moe" feed-forward selector."""
        if self.n_experts == 0:
            return ("mlp",) * self.n_layers
        return tuple(
            "moe" if (i % self.moe_every) == (self.moe_every - 1) else "mlp"
            for i in range(self.n_layers))

    def validate(self):
        assert self.d_model % self.tp == 0, (self.name, "d_model % tp")
        assert self.d_ff % self.tp == 0 or self.d_ff == 0
        if self.n_experts:
            assert self.n_experts % self.tp == 0, (self.name, "experts % tp")
        assert self.n_heads % self.n_kv_heads == 0
        return self
