"""Mamba-1 selective-SSM block (falcon-mamba / jamba mixer layers).

Train/prefill uses a chunked associative scan (sub-quadratic, memory-bounded
by the chunk size); decode is the O(1)-state recurrence.  TP shards the
d_inner channel dim; the scan itself is channel-parallel so no collectives
appear inside the recurrence.  The Pallas `mamba_scan` kernel is the
TPU-optimised equivalent of the chunked path (validated in tests).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import Px
from .config import ModelConfig
from .layers import _normal


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, d_conv - 1, d_inner)
    state: jax.Array  # (B, d_inner, N)


def init_mamba(key, cfg: ModelConfig):
    dt = cfg.jdtype()
    d, di, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.d_conv)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": Px(_normal(ks[0], (d, 2 * di), dt, 1 / math.sqrt(d)),
                      ("fsdp", "tp")),
        "conv_w": Px(_normal(ks[1], (K, di), dt, 1 / math.sqrt(K)),
                     (None, "tp")),
        "conv_b": Px(jnp.zeros((di,), dt), ("tp",)),
        "x_proj": Px(_normal(ks[2], (di, R + 2 * N), dt, 1 / math.sqrt(di)),
                     ("tp", None)),
        "dt_w": Px(_normal(ks[3], (R, di), dt, 1 / math.sqrt(R)),
                   (None, "tp")),
        "dt_b": Px(jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
                   ("tp",)),
        # S4D-real init: A = -(1..N) per channel
        "A_log": Px(jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :],
            (di, N)).copy(), ("tp", None)),
        "D": Px(jnp.ones((di,), jnp.float32), ("tp",)),
        "out_proj": Px(_normal(ks[4], (di, d), dt, 1 / math.sqrt(di)),
                       ("tp", "fsdp")),
    }
    return p


def _ssm_params(p, xc, cfg: ModelConfig):
    """xc: (..., di) conv output -> (dt, B, C) SSM inputs."""
    R, N = cfg.dt_rank, cfg.ssm_state
    proj = jnp.einsum("...d,dr->...r", xc, p["x_proj"]).astype(jnp.float32)
    dt_r, B_ssm, C_ssm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("...r,rd->...d", dt_r, p["dt_w"])
                         + p["dt_b"])
    return dt, B_ssm, C_ssm


def _chunked_scan(a, b, chunk: int):
    """x_t = a_t * x_{t-1} + b_t along axis 1, chunked associative scan.

    a, b: (B, S, di, N) fp32.  Peak live memory ~ (B, chunk, di, N).
    """
    bsz, s, di, n = a.shape
    nc = s // chunk
    ac = a.reshape(bsz, nc, chunk, di, n).swapaxes(0, 1)
    bc = b.reshape(bsz, nc, chunk, di, n).swapaxes(0, 1)

    def combine(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    def step(state, inputs):
        a_j, b_j = inputs
        aa, bb = lax.associative_scan(combine, (a_j, b_j), axis=1)
        x = bb + aa * state[:, None]
        return x[:, -1], x

    _, xs = lax.scan(step, jnp.zeros((bsz, di, n), a.dtype), (ac, bc))
    return xs.swapaxes(0, 1).reshape(bsz, s, di, n)


def apply_mamba(p, x, cfg: ModelConfig, rules, *, chunk: Optional[int] = None,
                return_cache: bool = False):
    """Train/prefill path.  x: (B, S, d) -> (y, cache|None)."""
    chunk = chunk or cfg.mamba_chunk
    bsz, s, _ = x.shape
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = rules.shard(xz, "batch", "seq", "tp")
    xin, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over S
    xpad = jnp.pad(xin, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + s, :] * p["conv_w"][i] for i in range(K))
    xc = jax.nn.silu(xc + p["conv_b"])

    dt, B_ssm, C_ssm = _ssm_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"])                                  # (di, N)
    xf = xc.astype(jnp.float32)
    sdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.ssm_dtype]
    # cast the SMALL operands once; every (B,S,di,N)-sized op then runs
    # natively in ssm_dtype (casting after an f32 compute would materialise
    # the f32 intermediate and ADD traffic -- measured in EXPERIMENTS §Perf)
    dtc = dt.astype(sdt)
    if cfg.ssm_impl == "kernel_proxy":
        # HBM-I/O stand-in for kernels/mamba_scan.py (state in VMEM): one
        # read of each input, one write of y; flops negligible vs the MXU
        # terms.  Dry-run measurement instrument only (see config).
        mix = jnp.einsum("bsn,bsn->bs", B_ssm, C_ssm)
        y = xc.astype(jnp.float32) * dt * mix[..., None] + p["D"] * xf
        states = None
    else:
        a = jnp.exp(dtc[..., None] * A.astype(sdt)[None, None])  # (B,S,di,N)
        b = ((dtc * xc.astype(sdt))[..., None]
             * B_ssm.astype(sdt)[:, :, None, :])
        cs = max(1, min(chunk, s))
        while s % cs:
            cs -= 1
        states = _chunked_scan(a, b, cs)
        y = jnp.einsum("bsdn,bsn->bsd", states, C_ssm.astype(sdt),
                       preferred_element_type=jnp.float32) + p["D"] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = rules.shard(y, "batch", "seq", "tp")
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if not return_cache:
        return out, None
    final_state = (states[:, -1] if states is not None else
                   jnp.zeros((bsz, di, N), jnp.float32))
    cache = MambaCache(
        conv=xpad[:, s:, :],  # last K-1 raw inputs (xpad has length s+K-1)
        state=rules.shard(final_state, "batch", "tp", None))
    return out, cache


def decode_mamba(p, x, cache: MambaCache, cfg: ModelConfig, rules):
    """One-token decode.  x: (B, 1, d) -> (y, new_cache)."""
    K = cfg.d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz[:, 0], 2, axis=-1)                  # (B, di)

    window = jnp.concatenate([cache.conv, xin[:, None, :]], axis=1)  # (B,K,di)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])

    dt, B_ssm, C_ssm = _ssm_params(p, xc, cfg)                # (B,di),(B,N)
    A = -jnp.exp(p["A_log"])
    xf = xc.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * A[None])                  # (B, di, N)
    state = decay * cache.state + (dt * xf)[..., None] * B_ssm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", state, C_ssm) + p["D"] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    new_cache = MambaCache(conv=window[:, 1:, :],
                           state=rules.shard(state, "batch", "tp", None))
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        state=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32))


def mamba_cache_axes() -> MambaCache:
    return MambaCache(conv=("batch", None, "tp"),
                      state=("batch", "tp", None))
