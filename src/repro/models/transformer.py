"""Composable transformer stack covering dense / MoE / SSM / hybrid /
encoder-decoder / VLM-backbone families with scan-over-groups layers.

Layers are grouped into a repeating period (hybrid interleave x MoE
alternation); groups are stacked and scanned, keeping the HLO size constant
in depth.  Caches are pytrees stacked over the group dim so prefill/decode
scan over (params, cache) together.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import Px, Rules, is_px
from .config import ModelConfig
from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from .layers import (apply_mlp, apply_norm, embed_tokens, init_embedding,
                     init_mlp, init_norm, sinusoidal_embedding, unembed)

AUX_COEF = 0.01  # MoE load-balance loss weight


def period(cfg: ModelConfig) -> int:
    p = cfg.attn_every if cfg.family == "hybrid" else 1
    if cfg.n_experts:
        p = math.lcm(p, cfg.moe_every)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    return p


def _stack_px(tree):
    """Prepend the scanned-layers role to every stacked Px leaf."""
    return jax.tree.map(lambda p: Px(p.v, ("layers",) + p.ax), tree,
                        is_leaf=is_px)


# ---------------------------------------------------------------------------
# block init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, mixer: str, ffn: str,
                decoder: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_norm(cfg)}
    if mixer == "attn":
        p["mixer"] = init_attention(ks[0], cfg)
    else:
        p["mixer"] = mamba_mod.init_mamba(ks[0], cfg)
    if decoder:
        p["norm_x"] = init_norm(cfg)
        p["cross"] = init_attention(ks[1], cfg, cross=True)
    if cfg.d_ff:
        p["norm2"] = init_norm(cfg)
        if ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[2], cfg)
            if cfg.dense_residual:
                p["mlp_res"] = init_mlp(ks[3], cfg)
            if cfg.shared_expert:
                p["mlp_shared"] = init_mlp(ks[4], cfg)
        else:
            p["ffn"] = init_mlp(ks[2], cfg)
    return p


def init_attention(key, cfg, cross=False):  # re-export for _init_layer
    return attn_mod.init_attention(key, cfg, cross=cross)


def _init_group(key, cfg: ModelConfig, decoder: bool = False):
    per = period(cfg)
    mixers = cfg.layer_kinds()[:per]
    ffns = cfg.ffn_kinds()[:per]
    keys = jax.random.split(key, per)
    return {f"l{j}": _init_layer(keys[j], cfg, mixers[j], ffns[j], decoder)
            for j in range(per)}


def init_model(key, cfg: ModelConfig):
    cfg.validate()
    n_groups = cfg.n_layers // period(cfg)
    k_emb, k_blocks, k_enc = jax.random.split(key, 3)
    decoder = cfg.family == "encdec"
    blocks = jax.vmap(
        lambda k: _init_group(k, cfg, decoder=decoder)
    )(jax.random.split(k_blocks, n_groups))
    params = {
        "embed": init_embedding(k_emb, cfg),
        "blocks": _stack_px(blocks),
        "norm_f": init_norm(cfg),
    }
    if decoder:
        enc_cfg = encoder_view(cfg)
        enc_blocks = jax.vmap(
            lambda k: _init_group(k, enc_cfg, decoder=False)
        )(jax.random.split(k_enc, cfg.encoder_layers))
        params["encoder"] = {"blocks": _stack_px(enc_blocks),
                             "norm_f": init_norm(cfg)}
    return params


def encoder_view(cfg: ModelConfig) -> ModelConfig:
    """Encoder layers: same widths, non-causal attention, single-layer
    period, no MoE."""
    import dataclasses
    return dataclasses.replace(cfg, family="dense", n_layers=cfg.encoder_layers,
                               n_experts=0, attn_every=0)


def abstract_init(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def param_axes(params):
    return jax.tree.map(lambda p: p.ax, params, is_leaf=is_px)


def param_values(params):
    return jax.tree.map(lambda p: p.v, params, is_leaf=is_px)


def merge_axes(values, axes):
    return jax.tree.map(Px, values, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------

def _apply_layer(lp, x, cfg: ModelConfig, rules: Rules, positions, mixer: str,
                 ffn_kind: str, mode: str, cache=None, enc_out=None,
                 enc_kv=None, pos=None, causal=True):
    """Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    h = apply_norm(lp["norm1"], x, cfg)
    if mixer == "attn":
        if mode == "decode":
            y, new_c = attn_mod.decode_attention(lp["mixer"], h, cache, pos,
                                                 cfg, rules)
        else:
            y, new_c = attn_mod.self_attention(
                lp["mixer"], h, cfg, rules, positions, causal=causal,
                return_cache=(mode == "prefill"))
    else:
        if mode == "decode":
            y, new_c = mamba_mod.decode_mamba(lp["mixer"], h, cache, cfg,
                                              rules)
        else:
            y, new_c = mamba_mod.apply_mamba(
                lp["mixer"], h, cfg, rules, return_cache=(mode == "prefill"))
    x = x + y
    new_enc_kv = None
    if "cross" in lp:
        hx = apply_norm(lp["norm_x"], x, cfg)
        if mode == "decode":
            yx, _ = attn_mod.decode_attention(lp["cross"], hx, enc_kv,
                                              enc_kv.k.shape[1], cfg, rules,
                                              cross=True)
            new_enc_kv = enc_kv
        else:
            kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
            vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
            ekv = attn_mod.KVCache(kx, vx)
            yx = attn_mod.cross_attention(lp["cross"], hx, ekv, cfg, rules)
            # encoder KV is short (n_frames) -> batch-sharded, seq replicated
            new_enc_kv = attn_mod.KVCache(
                rules.shard(kx, "batch", None, None, None),
                rules.shard(vx, "batch", None, None, None)
            ) if mode == "prefill" else None
        x = x + yx
    if cfg.d_ff and "ffn" in lp:
        h2 = apply_norm(lp["norm2"], x, cfg)
        if ffn_kind == "moe":
            # dense residual / shared expert run INSIDE the MoE shard_map
            # so the whole FFN sublayer shares one activation psum
            y2, aux = moe_mod.apply_moe(lp["ffn"], h2, cfg, rules,
                                        mlp_res=lp.get("mlp_res"),
                                        mlp_shared=lp.get("mlp_shared"))
        else:
            y2 = apply_mlp(lp["ffn"], h2, cfg, rules)
        x = x + y2
    x = rules.shard(x, "batch", "seq", None)
    return x, new_c, new_enc_kv, aux


def _apply_group(gp, x, cfg, rules, positions, mode, caches=None,
                 enc_out=None, enc_kvs=None, pos=None, causal=True):
    per = period(cfg)
    mixers = cfg.layer_kinds()[:per]
    ffns = cfg.ffn_kinds()[:per]
    new_caches: Dict[str, Any] = {}
    new_ekvs: Dict[str, Any] = {}
    aux_total = jnp.float32(0.0)
    for j in range(per):
        cache_j = caches[f"l{j}"] if caches is not None else None
        ekv_j = enc_kvs[f"l{j}"] if enc_kvs is not None else None
        x, c, ekv, aux = _apply_layer(
            gp[f"l{j}"], x, cfg, rules, positions, mixers[j], ffns[j], mode,
            cache=cache_j, enc_out=enc_out, enc_kv=ekv_j, pos=pos,
            causal=causal)
        if c is not None:
            new_caches[f"l{j}"] = c
        if ekv is not None:
            new_ekvs[f"l{j}"] = ekv
        aux_total = aux_total + aux
    return x, new_caches, new_ekvs, aux_total


# ---------------------------------------------------------------------------
# forward / prefill / decode
# ---------------------------------------------------------------------------

def _run_stack(blocks, x, cfg, rules, positions, mode, caches=None,
               enc_out=None, enc_kvs=None, pos=None, causal=True,
               remat=False):
    """Scan the group stack. caches/enc_kvs are group-stacked pytrees."""

    def body(carry, scanned):
        xc, aux_acc = carry
        gp = scanned["p"]
        cin = scanned.get("c")
        ekv = scanned.get("e")
        xc, new_c, new_e, aux = _apply_group(
            gp, xc, cfg, rules, positions, mode, caches=cin, enc_out=enc_out,
            enc_kvs=ekv, pos=pos, causal=causal)
        ys = {}
        if new_c:
            ys["c"] = new_c
        if new_e:
            ys["e"] = new_e
        return (xc, aux_acc + aux), ys

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    xs: Dict[str, Any] = {"p": blocks}
    if caches is not None:
        xs["c"] = caches
    if enc_kvs is not None:
        xs["e"] = enc_kvs

    if not cfg.scan_layers:
        # unrolled path (dry-run cost extraction: no while loops in HLO)
        n_groups = jax.tree.leaves(blocks)[0].shape[0]
        carry = (x, jnp.float32(0.0))
        ys_list = []
        for i in range(n_groups):
            xs_i = jax.tree.map(lambda l: l[i], xs)
            carry, ys_i = body(carry, xs_i)
            ys_list.append(ys_i)
        x, aux = carry
        if ys_list and jax.tree.leaves(ys_list[0]):
            ys = jax.tree.map(lambda *ls: jnp.stack(ls), *ys_list)
        else:
            ys = {}
        return x, aux, ys.get("c"), ys.get("e")

    (x, aux), ys = lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, aux, ys.get("c"), ys.get("e")


def _embed_input(params, batch, cfg: ModelConfig, rules: Rules):
    """Token (+stub-modality) embedding; returns (x, positions, n_prefix)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg, rules)
    n_prefix = 0
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        n_prefix = patches.shape[1]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos_embed == "learned":
        table = params["embed"]["pos"]
        x = x + jnp.take(table, positions[0] % table.shape[0], axis=0)[None]
    x = rules.shard(x, "batch", "seq", None)
    return x, positions, n_prefix


def _encode(params, batch, cfg: ModelConfig, rules: Rules):
    """Stub-frontend encoder pass (whisper): frames (B, F, d) -> enc_out."""
    frames = batch["frames"].astype(cfg.jdtype())
    b, f, _ = frames.shape
    pe = sinusoidal_embedding(f, cfg.d_model).astype(frames.dtype)
    x = frames + pe[None]
    x = rules.shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
    enc_cfg = encoder_view(cfg)
    x, _, _, _ = _run_stack(params["encoder"]["blocks"], x, enc_cfg, rules,
                            positions, "train", causal=False,
                            remat=cfg.remat)
    return apply_norm(params["encoder"]["norm_f"], x, enc_cfg)


def forward(params, batch, cfg: ModelConfig, rules: Rules,
            mode: str = "train"):
    """Full-sequence forward. Returns (logits, aux, caches, enc_kvs)."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, batch, cfg, rules)
    x, positions, n_prefix = _embed_input(params, batch, cfg, rules)
    caches = None
    x, aux, new_caches, enc_kvs = _run_stack(
        params["blocks"], x, cfg, rules, positions, mode, caches=caches,
        enc_out=enc_out, remat=(cfg.remat and mode == "train"))
    x = apply_norm(params["norm_f"], x, cfg)
    logits = unembed(params["embed"], x, cfg, rules)
    return logits, aux, new_caches, enc_kvs, n_prefix


def loss_fn(params, batch, cfg: ModelConfig, rules: Rules):
    logits, aux, _, _, n_prefix = forward(params, batch, cfg, rules, "train")
    tokens = batch["tokens"]
    preds = logits[:, n_prefix:, :][:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(preds.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        preds.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + AUX_COEF * aux, {"ce": ce, "aux": aux}


class DecodeState(NamedTuple):
    caches: Any           # group-stacked layer caches
    enc_kvs: Any          # cross-attn KV (encdec) or None
    pos: jax.Array        # scalar int32: next position to write


def prefill(params, batch, cfg: ModelConfig, rules: Rules,
            cache_len: Optional[int] = None):
    """Run the prompt, build the decode state.  Returns (last_logits, state).

    ``cache_len``: total KV capacity (>= prompt length); extra slots are
    zero-filled and masked by the position check in decode_attention.
    """
    logits, _, caches, enc_kvs, n_prefix = forward(params, batch, cfg, rules,
                                                   "prefill")
    prompt_len = batch["tokens"].shape[1] + n_prefix
    if cache_len and cache_len > prompt_len:
        pad = cache_len - prompt_len

        def pad_kv(c):
            if isinstance(c, attn_mod.KVCache):
                # cache leaves are group-stacked: (..., S, KV, hd); grow S
                width = [(0, 0)] * c.k.ndim
                width[-3] = (0, pad)
                return attn_mod.KVCache(jnp.pad(c.k, width),
                                        jnp.pad(c.v, width))
            return c

        caches = jax.tree.map(pad_kv, caches,
                              is_leaf=lambda x: isinstance(
                                  x, (attn_mod.KVCache, mamba_mod.MambaCache)))
    state = DecodeState(caches=caches, enc_kvs=enc_kvs,
                        pos=jnp.int32(prompt_len))
    return logits[:, -1, :], state


def decode_step(params, state: DecodeState, token, cfg: ModelConfig,
                rules: Rules):
    """token: (B,) int32 -> (logits (B, vocab), new state)."""
    x = embed_tokens(params["embed"], token[:, None], cfg, rules)
    if cfg.pos_embed == "learned":
        table = params["embed"]["pos"]
        x = x + jnp.take(table, state.pos % table.shape[0], axis=0)[None, None]
    b = x.shape[0]
    positions = jnp.broadcast_to(state.pos[None, None], (b, 1))
    x, _, new_caches, _ = _run_stack(
        params["blocks"], x, cfg, rules, positions, "decode",
        caches=state.caches, enc_kvs=state.enc_kvs, pos=state.pos)
    x = apply_norm(params["norm_f"], x, cfg)
    logits = unembed(params["embed"], x, cfg, rules)[:, 0, :]
    return logits, DecodeState(caches=new_caches, enc_kvs=state.enc_kvs,
                               pos=state.pos + 1)


# ---------------------------------------------------------------------------
# abstract decode-state construction (dry-run: no allocation)
# ---------------------------------------------------------------------------

def make_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None):
    """Zero-initialised decode state with KV capacity ``cache_len``."""
    dtype = dtype or cfg.jdtype()
    per = period(cfg)
    n_groups = cfg.n_layers // per
    mixers = cfg.layer_kinds()[:per]

    def stack(make):
        one = make()
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_groups,) + l.shape).copy()
            if isinstance(l, jax.Array) else l, one)

    caches = {}
    for j in range(per):
        if mixers[j] == "attn":
            caches[f"l{j}"] = stack(
                lambda: attn_mod.init_cache(cfg, batch, cache_len, dtype))
        else:
            caches[f"l{j}"] = stack(
                lambda: mamba_mod.init_mamba_cache(cfg, batch, dtype))
    enc_kvs = None
    if cfg.family == "encdec":
        enc_kvs = {f"l{j}": stack(
            lambda: attn_mod.init_cache(cfg, batch, cfg.n_frames, dtype))
            for j in range(per)}
    return DecodeState(caches=caches, enc_kvs=enc_kvs,
                       pos=jnp.int32(cache_len))


def decode_state_axes(cfg: ModelConfig):
    """Sharding roles for every leaf of the decode state."""
    per = period(cfg)
    mixers = cfg.layer_kinds()[:per]
    kv_ax = attn_mod.KVCache(*[("layers",) + a for a in attn_mod.cache_axes()])
    mb = mamba_mod.mamba_cache_axes()
    mb_ax = mamba_mod.MambaCache(*[("layers",) + a for a in mb])
    caches = {f"l{j}": kv_ax if mixers[j] == "attn" else mb_ax
              for j in range(per)}
    enc_kvs = None
    if cfg.family == "encdec":
        # encoder KV: short (n_frames, not a multiple of tp) -> replicate seq
        enc_ax = ("layers", "batch", None, None, None)
        enc_kvs = {f"l{j}": attn_mod.KVCache(enc_ax, enc_ax)
                   for j in range(per)}
    return DecodeState(caches=caches, enc_kvs=enc_kvs, pos=())
