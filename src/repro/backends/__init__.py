"""Backend-dispatch subsystem: one kernel API, many execution targets.

``repro.backends.registry`` maps every perf-critical op to named
implementations (``pallas`` / ``interpret`` / ``ref``) with a process-level
default, per-call override, and the ``REPRO_KERNEL_BACKEND`` environment
escape hatch.  See ``repro.kernels.ops`` for the registered ops and
``repro.serving`` for per-bucket backend routing.
"""
from .registry import (BACKENDS, ENV_VAR, available, backends_for,
                       default_backend, describe, register, registered_ops,
                       reset_resolution_counts, resolution_counts, resolve,
                       set_default_backend, use_backend)

__all__ = [
    "BACKENDS", "ENV_VAR", "available", "backends_for", "default_backend",
    "describe", "register", "registered_ops", "reset_resolution_counts",
    "resolution_counts", "resolve", "set_default_backend", "use_backend",
]
