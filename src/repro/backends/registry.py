"""Backend-dispatch registry for the kernel layer.

The paper's fabric is *unified*: one engine serves matmul and Jacobi/CORDIC
SVD on both deployment targets (Artix-7 edge, Virtex-US+ HPC).  The software
image of that property is this registry: every perf-critical op resolves, at
call time, to one of several named implementations:

  ``pallas``     compiled Pallas TPU kernel (requires a TPU backend)
  ``interpret``  the same Pallas kernel under the Pallas interpreter
                 (runs anywhere; exact kernel semantics, CPU speed)
  ``ref``        the pure-jnp XLA reference (``repro.kernels.ref``)

Resolution order for the backend name:

  1. per-call override (``backend=`` on the op wrapper, or the serving
     layer's per-bucket router);
  2. process-level default (``set_default_backend`` / ``use_backend``);
  3. the ``REPRO_KERNEL_BACKEND`` environment variable;
  4. auto: ``pallas`` when jax runs on TPU, else ``interpret``.

This replaces the old per-wrapper ``interpret = backend != "tpu"``
heuristic in ``repro.kernels.ops`` with one inspectable policy point.
"""
from __future__ import annotations

import collections
import contextlib
import os
import threading
from typing import Callable, Dict, Optional, Tuple

BACKENDS: Tuple[str, ...] = ("pallas", "interpret", "ref")

ENV_VAR = "REPRO_KERNEL_BACKEND"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_STATE = threading.local()
_PROCESS_DEFAULT: Optional[str] = None

# per-(op, backend) resolution counts -- which implementation every kernel
# call actually landed on.  A plain Counter increment (~100ns) so it can
# sit inside resolve() unconditionally; repro.obs mirrors it into the
# metric registry at export time (``kernel_backend_resolutions_total``).
_RESOLUTIONS: "collections.Counter[Tuple[str, str]]" = collections.Counter()


def _check_backend(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {BACKENDS}")
    return name


def register(op: str, backend: str) -> Callable[[Callable], Callable]:
    """Decorator: ``@register("mm_engine_matmul", "ref")``."""
    _check_backend(backend)

    def deco(fn: Callable) -> Callable:
        _REGISTRY.setdefault(op, {})[backend] = fn
        return fn

    return deco


_BUILTINS_LOADED = False


def _ensure_populated() -> None:
    # built-in implementations register themselves when repro.kernels.ops
    # imports; resolve() must work even if the caller never imported it
    # explicitly (and even if custom ops registered first)
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        import repro.kernels.ops  # noqa: F401


def registered_ops() -> Tuple[str, ...]:
    _ensure_populated()
    return tuple(sorted(_REGISTRY))


def backends_for(op: str) -> Tuple[str, ...]:
    _ensure_populated()
    if op not in _REGISTRY:
        raise KeyError(f"unknown op {op!r}; registered: {registered_ops()}")
    impls = _REGISTRY[op]
    return tuple(b for b in BACKENDS if b in impls)


def available() -> Tuple[str, ...]:
    """Backends runnable on this host (``pallas`` needs a real TPU; the
    interpreter and the XLA reference run anywhere)."""
    import jax
    return tuple(b for b in BACKENDS
                 if b != "pallas" or jax.default_backend() == "tpu")


def default_backend() -> str:
    """The backend used when no per-call override is given."""
    override = getattr(_STATE, "backend", None)
    if override is not None:
        return override
    if _PROCESS_DEFAULT is not None:
        return _PROCESS_DEFAULT
    env = os.environ.get(ENV_VAR)
    if env:
        return _check_backend(env)
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def set_default_backend(name: Optional[str]) -> None:
    """Set (or with ``None`` clear) the process-level default backend."""
    global _PROCESS_DEFAULT
    _PROCESS_DEFAULT = None if name is None else _check_backend(name)


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped (thread-local) backend override, strongest non-per-call rule."""
    _check_backend(name)
    prev = getattr(_STATE, "backend", None)
    _STATE.backend = name
    try:
        yield
    finally:
        _STATE.backend = prev


def resolve(op: str, backend: Optional[str] = None) -> Callable:
    """The implementation of ``op`` for ``backend`` (None = resolution order
    above)."""
    _ensure_populated()
    if op not in _REGISTRY:
        raise KeyError(f"unknown op {op!r}; registered: {registered_ops()}")
    name = default_backend() if backend is None else _check_backend(backend)
    impls = _REGISTRY[op]
    if name not in impls:
        raise KeyError(
            f"op {op!r} has no {name!r} backend; available: "
            f"{backends_for(op)}")
    _RESOLUTIONS[(op, name)] += 1
    return impls[name]


def resolution_counts() -> Dict[Tuple[str, str], int]:
    """Lifetime (op, backend) -> resolve() count; the observability layer
    exports this as ``kernel_backend_resolutions_total``."""
    return dict(_RESOLUTIONS)


def reset_resolution_counts() -> None:
    _RESOLUTIONS.clear()


def describe() -> str:
    """Multi-line op x backend availability table for CI logs."""
    _ensure_populated()
    lines = [f"default backend: {default_backend()}"
             f" (env {ENV_VAR}={os.environ.get(ENV_VAR, '<unset>')})"]
    for op in registered_ops():
        lines.append(f"  {op:<20s} {', '.join(backends_for(op))}")
    return "\n".join(lines)
