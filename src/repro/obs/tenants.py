"""Tenant-labeled metric families for the traffic frontend.

The PR 6 registry keys serving telemetry by (op, bucket, backend) -- the
*device's* view of traffic.  The open-loop frontend needs the *tenant's*
view: who was admitted, who was shed, who made their SLO, and at what
per-tenant goodput.  ``TenantAccounting`` owns those families inside a
shared ``MetricRegistry`` (so one ``--metrics-out`` export carries both
views) and keeps exact per-tenant aggregates on the side -- the metric
histograms are fixed-bucket approximations, but fairness assertions
("WFQ bounds the starved tenant's p99 where FIFO does not") want exact
percentiles over bounded runs.

Families:

  frontend_requests_total{tenant, outcome}    admission outcomes
      (outcome: served | degraded | shed | throttled)
  frontend_tenant_latency_seconds{tenant}     ingress-to-completion
  frontend_tenant_slo_total{tenant, status}   per-tenant SLO verdicts
      (status: ok | miss)
  frontend_tenant_goodput_rps{tenant}         set at report time
  frontend_tenant_queue_depth{tenant}         scheduler queue depth
"""
from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional

import numpy as np

from .metrics import MetricRegistry

OUTCOMES = ("served", "degraded", "shed", "throttled")


def _pctl(values: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(values, float), p))


class TenantAccounting:
    """Per-tenant admission/latency/goodput accounting, mirrored into a
    ``MetricRegistry``.

    Args:
      registry: registry to register the families in; a private one is
        created when omitted (standalone use in tests).
      clock: timestamp source for the registry's windowed event rings --
        pass the server's clock so tenant series line up with serving
        telemetry, including under an injected test clock.
    """

    def __init__(self, registry: Optional[MetricRegistry] = None,
                 clock=time.monotonic):
        self.registry = (registry if registry is not None
                         else MetricRegistry(clock=clock))
        self.clock = clock
        reg = self.registry
        self._m_requests = reg.counter(
            "frontend_requests_total",
            "Frontend admission outcomes by tenant.",
            ("tenant", "outcome"))
        self._m_latency = reg.histogram(
            "frontend_tenant_latency_seconds",
            "Frontend ingress-to-completion latency by tenant.",
            ("tenant",))
        self._m_slo = reg.counter(
            "frontend_tenant_slo_total",
            "Per-tenant SLO verdicts for served requests.",
            ("tenant", "status"))
        self._m_goodput = reg.gauge(
            "frontend_tenant_goodput_rps",
            "SLO-compliant served requests/s by tenant (report time).",
            ("tenant",))
        self._m_depth = reg.gauge(
            "frontend_tenant_queue_depth",
            "Scheduler queue depth by tenant.",
            ("tenant",))
        self._outcomes: Dict[str, collections.Counter] = \
            collections.defaultdict(collections.Counter)
        self._latencies: Dict[str, List[float]] = \
            collections.defaultdict(list)
        self._slo_ok: Dict[str, int] = collections.defaultdict(int)

    def outcome(self, tenant: str, outcome: str,
                now: Optional[float] = None) -> None:
        if outcome not in OUTCOMES:
            raise ValueError(
                f"unknown outcome {outcome!r}; one of {OUTCOMES}")
        self._outcomes[tenant][outcome] += 1
        self._m_requests.labels(tenant=tenant, outcome=outcome).inc(now=now)

    def served(self, tenant: str, latency_s: float, slo_ok: bool,
               now: Optional[float] = None) -> None:
        """One completed (served or degraded) request's latency + SLO
        verdict.  Callers record the admission ``outcome`` separately."""
        self._latencies[tenant].append(float(latency_s))
        self._m_latency.labels(tenant=tenant).observe(latency_s, now=now)
        self._m_slo.labels(
            tenant=tenant, status="ok" if slo_ok else "miss").inc(now=now)
        if slo_ok:
            self._slo_ok[tenant] += 1

    def queue_depth(self, tenant: str, depth: int,
                    now: Optional[float] = None) -> None:
        self._m_depth.labels(tenant=tenant).set(depth, now=now)

    def goodput(self, tenant: str, rps: float,
                now: Optional[float] = None) -> None:
        self._m_goodput.labels(tenant=tenant).set(rps, now=now)

    def tenants(self) -> List[str]:
        return sorted(set(self._outcomes) | set(self._latencies))

    def summary(self, span_s: Optional[float] = None) -> Dict[str, Dict]:
        """Exact per-tenant aggregates (plain JSON).  With ``span_s`` the
        per-tenant goodput gauges are also refreshed from it."""
        doc = {}
        for tenant in self.tenants():
            counts = self._outcomes[tenant]
            lats = self._latencies[tenant]
            ok = self._slo_ok[tenant]
            row = {
                "served": counts["served"],
                "degraded": counts["degraded"],
                "shed": counts["shed"],
                "throttled": counts["throttled"],
                "slo_ok": ok,
                "latency_p50_ms": (_pctl([l * 1e3 for l in lats], 50)
                                   if lats else 0.0),
                "latency_p99_ms": (_pctl([l * 1e3 for l in lats], 99)
                                   if lats else 0.0),
            }
            if span_s is not None and span_s > 0:
                row["goodput_rps"] = ok / span_s
                self.goodput(tenant, row["goodput_rps"])
            doc[tenant] = row
        return doc
