"""Span tracing for the serving pipeline, exportable as Chrome trace JSON.

The paper's evaluation stands on *stage-timed* breakdowns (per-unit cycle
counts from the fabric model, per-stage wall time in the benchmarks); the
serving stack needs the same per-stage visibility on live traffic.  This
module records one span per pipeline stage into a bounded ring buffer:

  request   submit -> fulfil, with a "queued" child covering the
            pre-dispatch wait; linked (``parent``) to the flush span
            that retired it.
  flush     dispatch -> retire-complete, with "dispatch" (stack / pad /
            cache-lookup / launch), "inflight" (launched, host free),
            "wait" (blocked on the device) and "retire" (gather / unpack /
            fulfil) children.  On a cache miss the executable build gets
            its own "compile" child; the XLA compilation itself runs
            inside the miss flush's first launch, so its cost lands in
            that flush's dispatch span.
  control   plan swaps (``PCAServer.apply_plan``) and autotune searches.

Recording is O(1) per span (an append into a ``deque(maxlen=...)``); a
long-running server's trace is the *most recent* window, never unbounded.
``Tracer(enabled=False)`` turns every call into a cheap no-op, and the
serving engine skips instrumentation entirely when no observability object
is attached -- the disabled fast path costs one attribute check.

``export()`` emits the Chrome trace-event format (the JSON
``chrome://tracing`` and https://ui.perfetto.dev load directly): complete
``"X"`` events with microsecond timestamps, plus ``"M"`` metadata events
naming the tracks.  Overlapping root spans of one track are fanned out
across sub-lanes at export time so concurrent requests/flushes render as
parallel rows instead of a false flame stack; children stay on their
parent's lane so each span nests under its parent.  ``validate_trace``
checks the schema contract the selftest and CI enforce: required keys,
non-decreasing ``ts``, non-negative ``dur``, matched B/E stacks, and
parent links that reference real spans and end inside their parent.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import pathlib
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

# export-time comparison slack for float timestamps (seconds)
_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished span (recorded at end time; clock units = seconds)."""
    id: int
    name: str
    cat: str
    track: str
    ts: float                  # start, on the tracer's clock
    dur: float
    parent: Optional[int] = None
    args: Tuple[Tuple[str, object], ...] = ()

    @property
    def end(self) -> float:
        return self.ts + self.dur


class _SpanHandle:
    """An open span: ``end()`` records it (usable as a context manager)."""

    __slots__ = ("_tracer", "id", "name", "cat", "track", "parent",
                 "ts", "_args", "_open")

    def __init__(self, tracer: "Tracer", id: int, name: str, cat: str,
                 track: str, parent: Optional[int], ts: float, args: Dict):
        self._tracer = tracer
        self.id = id
        self.name = name
        self.cat = cat
        self.track = track
        self.parent = parent
        self.ts = ts
        self._args = args
        self._open = True

    def end(self, ts: Optional[float] = None, **args) -> Optional[Span]:
        if not self._open:
            return None
        self._open = False
        if args:
            self._args.update(args)
        ts = self._tracer.clock() if ts is None else ts
        return self._tracer.complete(
            self.name, ts=self.ts, end=max(ts, self.ts), cat=self.cat,
            track=self.track, parent=self.parent, id=self.id, **self._args)

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class _NullHandle:
    """Shared no-op handle returned by a disabled tracer."""

    __slots__ = ()
    id = None

    def end(self, ts=None, **args) -> None:
        return None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_HANDLE = _NullHandle()


class Tracer:
    """Bounded ring buffer of pipeline spans.

    Args:
      capacity: ring size; the oldest spans fall off under sustained load
        so a long-running server holds the most recent window.
      clock: monotonic seconds source (tests inject a manual clock -- use
        the same one the server runs on so span timestamps line up with
        its telemetry).
      enabled: ``False`` turns every recording call into a no-op; flip
        ``tracer.enabled`` at runtime to pause/resume capture.
    """

    def __init__(self, capacity: int = 65536, clock=time.monotonic,
                 enabled: bool = True):
        self.capacity = capacity
        self.clock = clock
        self.enabled = enabled
        self.spans: Deque[Span] = deque(maxlen=capacity)
        self.dropped = 0           # spans the ring displaced
        self._ids = itertools.count(1)

    def __len__(self) -> int:
        return len(self.spans)

    def new_id(self) -> int:
        """Reserve a span id before the span is recorded (so children can
        name their parent while it is still open)."""
        return next(self._ids)

    def begin(self, name: str, cat: str = "serving", track: str = "serving",
              parent: Optional[int] = None, ts: Optional[float] = None,
              **args):
        """Open a span; ``.end()`` (or context-manager exit) records it."""
        if not self.enabled:
            return _NULL_HANDLE
        return _SpanHandle(self, self.new_id(), name, cat, track, parent,
                           self.clock() if ts is None else ts, args)

    def complete(self, name: str, ts: float, end: float,
                 cat: str = "serving", track: str = "serving",
                 parent: Optional[int] = None, id: Optional[int] = None,
                 **args) -> Optional[Span]:
        """Record an already-finished span from explicit timestamps (the
        engine samples its own clock at stage boundaries; spans reuse those
        samples instead of re-reading the clock)."""
        if not self.enabled:
            return None
        if len(self.spans) == self.capacity:
            self.dropped += 1
        span = Span(id=self.new_id() if id is None else id, name=name,
                    cat=cat, track=track, ts=ts, dur=max(end - ts, 0.0),
                    parent=parent,
                    args=tuple(sorted(args.items())) if args else ())
        self.spans.append(span)
        return span

    def instant(self, name: str, cat: str = "serving",
                track: str = "serving", ts: Optional[float] = None,
                **args) -> Optional[Span]:
        """A zero-duration marker (plan swap, admission decision, ...)."""
        t = self.clock() if ts is None else ts
        return self.complete(name, ts=t, end=t, cat=cat, track=track, **args)

    def clear(self) -> None:
        self.spans.clear()
        self.dropped = 0

    # -- export -------------------------------------------------------------
    def export(self, process_name: str = "repro.serving") -> Dict:
        """The ring's spans as a Chrome trace-event JSON document."""
        # ties broken by id: a parent reserves its id before its children
        # record (new_id), so on a frozen test clock -- every ts equal --
        # parents still lane-assign before the children that ride them
        spans = sorted(self.spans, key=lambda s: (s.ts, -s.dur, s.id))
        by_id = {s.id: s for s in spans}
        t0 = min((s.ts for s in spans), default=0.0)

        # lane allocation: root spans of one track fan out over sub-lanes
        # so concurrent spans render side by side; children ride their
        # parent's lane so every span nests under its parent
        tracks = sorted({s.track for s in spans})
        lane_of: Dict[int, Tuple[str, int]] = {}
        lanes_per_track: Dict[str, List[float]] = {t: [] for t in tracks}
        for s in spans:
            parent = by_id.get(s.parent) if s.parent is not None else None
            if (parent is not None and parent.track == s.track
                    and parent.id in lane_of):
                lane_of[s.id] = lane_of[parent.id]
                continue
            busy = lanes_per_track[s.track]
            for i, busy_until in enumerate(busy):
                if busy_until <= s.ts + _EPS:
                    busy[i] = s.end
                    lane_of[s.id] = (s.track, i)
                    break
            else:
                busy.append(s.end)
                lane_of[s.id] = (s.track, len(busy) - 1)

        tid_of: Dict[Tuple[str, int], int] = {}
        events: List[Dict] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0, "ts": 0,
            "args": {"name": process_name},
        }]
        for track in tracks:
            for lane in range(len(lanes_per_track[track])):
                tid = len(tid_of) + 1
                tid_of[(track, lane)] = tid
                events.append({
                    "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                    "ts": 0,
                    "args": {"name": track if lane == 0
                             else f"{track} ~{lane + 1}"},
                })
        for s in spans:
            args = dict(s.args)
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X", "pid": 0,
                "tid": tid_of[lane_of[s.id]],
                "ts": round((s.ts - t0) * 1e6, 3),
                "dur": round(s.dur * 1e6, 3),
                "id": s.id,
                "args": args,
            })
        events.sort(key=lambda e: (e["ts"], -e.get("dur", 0)))
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"spans": len(spans), "dropped": self.dropped,
                          "clock_origin_s": t0},
        }

    def save(self, path, process_name: str = "repro.serving") -> pathlib.Path:
        """Validate, then write the trace JSON (Perfetto-loadable)."""
        doc = self.export(process_name)
        errors = validate_trace(doc)
        if errors:
            raise ValueError(f"trace failed schema validation: {errors[:5]}")
        path = pathlib.Path(path)
        path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
        return path


def validate_trace(doc: Dict) -> List[str]:
    """Chrome trace-event schema check; returns a list of violations.

    The contract CI enforces on every exported trace: the document holds a
    non-empty ``traceEvents`` list; every event carries name / ph / ts /
    pid / tid; ``ts`` is non-decreasing in document order; ``"X"`` events
    carry a non-negative ``dur``; ``"B"``/``"E"`` events match per
    (pid, tid) stack; a span's ``args.parent`` references a real span id
    whose interval contains the child's end (same-track parents must
    contain the child's start too -- cross-track links, e.g. request ->
    retiring flush, legitimately start before their parent).
    """
    errors: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]
    last_ts = None
    stacks: Dict[Tuple, List[str]] = {}
    xspans: Dict[int, Dict] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"event {i} ({ev.get('name')!r}): missing "
                              f"required key {key!r}")
        ph = ev.get("ph")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i}: ts must be a non-negative number, "
                          f"got {ts!r}")
            continue
        if ph != "M":               # metadata events sit outside the timeline
            if last_ts is not None and ts < last_ts - 1e-6:
                errors.append(f"event {i}: ts {ts} < previous {last_ts} "
                              f"(must be non-decreasing)")
            last_ts = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({ev.get('name')!r}): X event "
                              f"needs a non-negative dur, got {dur!r}")
            elif isinstance(ev.get("id"), int):
                xspans[ev["id"]] = ev
        elif ph == "B":
            stacks.setdefault((ev.get("pid"), ev.get("tid")), []).append(
                ev.get("name"))
        elif ph == "E":
            stack = stacks.setdefault((ev.get("pid"), ev.get("tid")), [])
            if not stack:
                errors.append(f"event {i}: E without matching B on tid "
                              f"{ev.get('tid')}")
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            errors.append(f"unmatched B events on (pid, tid) {key}: {stack}")
    for sid, ev in xspans.items():
        parent_id = (ev.get("args") or {}).get("parent")
        if parent_id is None:
            continue
        parent = xspans.get(parent_id)
        if parent is None:
            errors.append(f"span {sid} ({ev['name']!r}): parent "
                          f"{parent_id} not in trace")
            continue
        end, pend = ev["ts"] + ev["dur"], parent["ts"] + parent["dur"]
        if end > pend + 1.0:       # 1 us slack on rounded timestamps
            errors.append(f"span {sid} ({ev['name']!r}): ends at {end} "
                          f"after its parent {parent_id} at {pend}")
        if parent["tid"] == ev["tid"] and ev["ts"] < parent["ts"] - 1.0:
            errors.append(f"span {sid} ({ev['name']!r}): starts before "
                          f"its same-track parent {parent_id}")
    return errors


@contextlib.contextmanager
def device_profile(logdir: Optional[str] = None):
    """Optional ``jax.profiler`` session around a traced serve run.

    With a log directory, starts a JAX profiler trace so the device-side
    picture (XLA op timings, TensorBoard/Perfetto-loadable) lands next to
    the host-side span trace; a ``None``/empty logdir -- or a jax build
    without profiler support -- is a no-op, so callers can wrap
    unconditionally.
    """
    if not logdir:
        yield
        return
    import jax
    try:
        jax.profiler.start_trace(str(logdir))
    except Exception as e:          # pragma: no cover - backend-dependent
        import warnings
        warnings.warn(f"jax.profiler unavailable ({e}); device profile "
                      f"skipped")
        yield
        return
    try:
        yield
    finally:
        jax.profiler.stop_trace()
