"""SLO accounting: deadline misses and goodput-under-SLO.

ROADMAP item 2 reframes the headline serving metric from raw throughput to
**goodput under an SLO** -- the rate of requests served *within* a latency
target -- and PR 5 left the seam open (``TrafficProfile.arrival_rate`` is
captured but feeds no deadline/latency term).  This module closes it with
arithmetic over the same per-request data the spans and ``ServingStats``
records already carry:

  SLO compliance   a request served with ``latency_s <= slo_s`` is
                   compliant; ``goodput_rps`` is compliant requests per
                   second of serving span (or per trailing window).
  deadline misses  independent of the SLO: a request whose *flush
                   deadline* (``submit + max_delay_s``, the knob that
                   drives microbatching) passed before it was fulfilled.
                   Deadlines used to shape batching only; now misses are
                   counted (see also ``ServingStats.summary``).

``SLOTracker`` is fed by the serving engine at retire time (one
``observe`` per fulfilled request) and mirrors its counts into the metric
registry (``slo_requests_total`` / ``slo_miss_total`` /
``deadline_miss_total`` by op) so the Prometheus export and the SLO
summary can never disagree.  ``from_records`` computes the same summary
offline from ``ServingStats`` records -- the replay/CI path where no
tracker was attached.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, Iterable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLORecord:
    """One fulfilled request, as the SLO math sees it."""
    op: str
    t_done: float
    latency_s: float
    compliant: bool
    deadline_missed: bool


def _summary(records, slo_s: Optional[float], span_s: float,
             window_s: Optional[float] = None) -> Dict:
    n = len(records)
    compliant = sum(1 for r in records if r.compliant)
    deadline_missed = sum(1 for r in records if r.deadline_missed)
    return {
        "slo_ms": slo_s * 1e3 if slo_s is not None else None,
        "window_s": window_s,
        "requests": n,
        "compliant": compliant,
        "slo_miss_count": n - compliant,
        "slo_miss_frac": (n - compliant) / n if n else 0.0,
        "deadline_miss_count": deadline_missed,
        "deadline_miss_frac": deadline_missed / n if n else 0.0,
        "goodput_rps": compliant / span_s if span_s > 0 else 0.0,
        "throughput_rps": n / span_s if span_s > 0 else 0.0,
    }


class SLOTracker:
    """Streaming SLO accounting over fulfilled requests.

    Args:
      slo_s: the latency target; ``None`` means "no SLO" (every request
        compliant -- goodput degenerates to throughput, deadline misses
        still count).
      registry: optional ``metrics.MetricRegistry`` to mirror counters
        into (``slo_requests_total{op}``, ``slo_miss_total{op}``,
        ``deadline_miss_total{op}``).
      clock: only used for the default ``now`` of windowed summaries;
        inject the server's clock in tests.
      capacity: bounded record ring (windowed summaries look back at most
        this many requests).
    """

    def __init__(self, slo_s: Optional[float] = None, registry=None,
                 clock=time.monotonic, capacity: int = 65536):
        if slo_s is not None and slo_s <= 0:
            raise ValueError(f"slo_s must be positive, got {slo_s}")
        self.slo_s = slo_s
        self.clock = clock
        self.records: Deque[SLORecord] = deque(maxlen=capacity)
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._m_requests = self._m_miss = self._m_deadline = None
        if registry is not None:
            self._m_requests = registry.counter(
                "slo_requests_total", "Requests fulfilled (SLO accounting).",
                ("op",))
            self._m_miss = registry.counter(
                "slo_miss_total", "Requests fulfilled over the SLO target.",
                ("op",))
            self._m_deadline = registry.counter(
                "deadline_miss_total",
                "Requests fulfilled after their flush deadline.", ("op",))

    def observe(self, op: str, latency_s: float, t_done: float,
                t_submit: Optional[float] = None,
                deadline: Optional[float] = None) -> SLORecord:
        """Account one fulfilled request.

        ``deadline`` is the request's flush-by time on the same clock as
        ``t_done`` (None = no deadline tracking for this request).
        """
        compliant = self.slo_s is None or latency_s <= self.slo_s
        missed = deadline is not None and t_done > deadline
        rec = SLORecord(op=op, t_done=t_done, latency_s=latency_s,
                        compliant=compliant, deadline_missed=missed)
        self.records.append(rec)
        t_start = t_done - latency_s if t_submit is None else t_submit
        self._t_first = (t_start if self._t_first is None
                         else min(self._t_first, t_start))
        self._t_last = (t_done if self._t_last is None
                        else max(self._t_last, t_done))
        if self._m_requests is not None:
            self._m_requests.labels(op=op).inc(now=t_done)
            if not compliant:
                self._m_miss.labels(op=op).inc(now=t_done)
            if missed:
                self._m_deadline.labels(op=op).inc(now=t_done)
        return rec

    def summary(self, window_s: Optional[float] = None,
                now: Optional[float] = None) -> Dict:
        """Goodput/miss accounting, lifetime or over a trailing window.

        Lifetime goodput divides by the served span (first submit to last
        fulfil); a windowed summary divides by the window length -- the
        quantity a controller compares against the arrival rate.
        """
        if window_s is None:
            span = ((self._t_last - self._t_first)
                    if self._t_first is not None else 0.0)
            return _summary(list(self.records), self.slo_s, span)
        now = self.clock() if now is None else now
        recent = [r for r in self.records if r.t_done >= now - window_s]
        return _summary(recent, self.slo_s, window_s, window_s=window_s)

    def reset(self) -> None:
        self.records.clear()
        self._t_first = self._t_last = None


def from_records(records: Iterable, slo_s: Optional[float]) -> Dict:
    """The SLO summary computed offline from ``ServingStats`` records
    (``RequestRecord`` rows carry t_submit/t_done/deadline already)."""
    recs = list(records)
    rows = [SLORecord(
        op=r.op, t_done=r.t_done, latency_s=r.latency_s,
        compliant=slo_s is None or r.latency_s <= slo_s,
        deadline_missed=(getattr(r, "deadline", math.inf) < r.t_done))
        for r in recs]
    if recs:
        span = max(r.t_done for r in recs) - min(r.t_submit for r in recs)
    else:
        span = 0.0
    return _summary(rows, slo_s, span)
