"""Process-local metric registry: counters, gauges, fixed-bucket histograms.

The serving pipeline's quantitative telemetry, in the Prometheus data
model: a ``MetricRegistry`` holds metric *families* (one name + label
schema each); a family holds one child series per label-value tuple (the
serving layer keys latency histograms by ``(op, bucket, backend,
executor)``).  Recording is a dict lookup plus an integer/float update --
cheap enough to sit on the flush hot path -- and every child additionally
keeps a bounded deque of ``(t, value)`` events so ``snapshot(window_s=...)``
can answer *windowed* questions (recent rate, recent p99) for the
sliding-window re-profiling controller (ROADMAP item 3) without a second
collection system.

Exports:

  ``to_prometheus()``  the text exposition format (``# HELP``/``# TYPE``,
                       ``_bucket``/``_sum``/``_count`` histogram series
                       with cumulative ``le`` buckets) -- scrapeable as-is.
  ``to_json()``        the same content as a plain dict.
  ``snapshot(...)``    per-series aggregates over a trailing window
                       (rates, histogram percentiles), or lifetime totals
                       when no window is given.

Histogram percentiles are bucket-interpolated (the PromQL
``histogram_quantile`` rule): exact to within one bucket width, constant
memory, and identical math for lifetime and windowed readouts.
"""
from __future__ import annotations

import math
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

# latency-flavoured default buckets (seconds): 50us .. 30s
DEFAULT_BUCKETS: Tuple[float, ...] = (
    5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 30.0)


def fmt_label(v) -> str:
    """Canonical label-value spelling: tuples (shape buckets) join with
    'x', None means the plain XLA datapath, everything else is str()."""
    if v is None:
        return "xla"
    if isinstance(v, (tuple, list)):
        return "x".join(str(int(d)) for d in v)
    return str(v)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def histogram_quantile(q: float, uppers: Sequence[float],
                       counts: Sequence[int]) -> float:
    """PromQL-style bucket-interpolated quantile.

    ``counts[i]`` is the count in ``(uppers[i-1], uppers[i]]``;
    ``counts[-1]`` is the +Inf overflow bucket.  Linear interpolation
    inside the winning bucket; the overflow bucket clamps to its lower
    bound (there is no upper edge to interpolate toward).
    """
    total = sum(counts)
    if total == 0:
        return float("nan")
    rank = q * total
    cum = 0.0
    for i, c in enumerate(counts):
        if cum + c >= rank and c > 0:
            lo = uppers[i - 1] if i > 0 else 0.0
            if i >= len(uppers):          # +Inf bucket
                return float(uppers[-1]) if uppers else float("nan")
            hi = uppers[i]
            return lo + (hi - lo) * (rank - cum) / c
        cum += c
    return float(uppers[-1]) if uppers else float("nan")


class _Series:
    """Shared per-child state: labels, the windowed event ring, and the
    owning registry's clock (used when an observation has no explicit
    timestamp, so injected-clock registries stamp consistently)."""

    __slots__ = ("labels", "events", "clock")

    def __init__(self, labels: Tuple[str, ...], capacity: int,
                 clock: Callable[[], float]):
        self.labels = labels
        self.events: Deque[Tuple[float, float]] = deque(maxlen=capacity)
        self.clock = clock

    def window(self, now: float,
               window_s: Optional[float]) -> List[Tuple[float, float]]:
        if window_s is None:
            return list(self.events)
        cut = now - window_s
        return [(t, v) for t, v in self.events if t >= cut]


class Counter(_Series):
    """Monotone count; ``inc`` appends the delta to the event ring so a
    windowed snapshot can report a recent rate."""

    __slots__ = ("total",)

    def __init__(self, labels, capacity, clock):
        super().__init__(labels, capacity, clock)
        self.total = 0.0

    def inc(self, v: float = 1.0, now: Optional[float] = None) -> None:
        self.total += v
        self.events.append((now if now is not None else self.clock(), v))

    def set_total(self, v: float) -> None:
        """Mirror an externally-maintained monotone count (collectors)."""
        self.total = float(v)


class Gauge(_Series):
    __slots__ = ("value",)

    def __init__(self, labels, capacity, clock):
        super().__init__(labels, capacity, clock)
        self.value = 0.0

    def set(self, v: float, now: Optional[float] = None) -> None:
        self.value = float(v)
        self.events.append(
            (now if now is not None else self.clock(), self.value))

    def inc(self, v: float = 1.0, now: Optional[float] = None) -> None:
        self.set(self.value + v, now)


class Histogram(_Series):
    """Fixed-bucket histogram with p50/p90/p99 readout.

    ``buckets`` are upper bounds; an implicit +Inf bucket catches the
    overflow.  Lifetime bucket counts serve the Prometheus export; the
    event ring re-buckets on demand for windowed percentiles.
    """

    __slots__ = ("uppers", "counts", "sum", "count")

    def __init__(self, labels, capacity, clock, uppers: Tuple[float, ...]):
        super().__init__(labels, capacity, clock)
        self.uppers = uppers
        self.counts = [0] * (len(uppers) + 1)
        self.sum = 0.0
        self.count = 0

    def _bucket_index(self, v: float) -> int:
        for i, hi in enumerate(self.uppers):
            if v <= hi:
                return i
        return len(self.uppers)

    def observe(self, v: float, now: Optional[float] = None) -> None:
        self.counts[self._bucket_index(v)] += 1
        self.sum += v
        self.count += 1
        self.events.append((now if now is not None else self.clock(), v))

    def percentile(self, p: float, now: Optional[float] = None,
                   window_s: Optional[float] = None) -> float:
        """p in [0, 100]; windowed when ``window_s`` is given."""
        if window_s is None:
            counts = self.counts
        else:
            counts = [0] * (len(self.uppers) + 1)
            for _, v in self.window(
                    now if now is not None else self.clock(), window_s):
                counts[self._bucket_index(v)] += 1
        return histogram_quantile(p / 100.0, self.uppers, counts)


class Family:
    """One metric name + label schema; children keyed by label values."""

    def __init__(self, registry: "MetricRegistry", name: str, help: str,
                 kind: str, labelnames: Tuple[str, ...],
                 uppers: Optional[Tuple[float, ...]] = None):
        self.registry = registry
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = labelnames
        self.uppers = uppers
        self._children: Dict[Tuple[str, ...], _Series] = {}

    def labels(self, *values, **kv):
        """The child series for one label-value tuple (created on first
        use).  Accepts positional values in schema order or keywords."""
        if kv:
            if values:
                raise TypeError("pass labels positionally or by keyword, "
                                "not both")
            values = tuple(kv[n] for n in self.labelnames)
        key = tuple(fmt_label(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got "
                f"{values!r}")
        child = self._children.get(key)
        if child is None:
            cap = self.registry.window_capacity
            clock = self.registry.clock
            if self.kind == "counter":
                child = Counter(key, cap, clock)
            elif self.kind == "gauge":
                child = Gauge(key, cap, clock)
            else:
                child = Histogram(key, cap, clock, self.uppers)
            self._children[key] = child
        return child

    def items(self):
        return sorted(self._children.items())

    def _label_str(self, key: Tuple[str, ...], extra: str = "") -> str:
        pairs = [f'{n}="{_escape(v)}"' for n, v in zip(self.labelnames, key)]
        if extra:
            pairs.append(extra)
        return "{" + ",".join(pairs) + "}" if pairs else ""


class MetricRegistry:
    """Process-local registry; families are idempotent by name.

    Args:
      clock: timestamp source for the windowed event rings (inject the
        server's clock so windows line up with its telemetry).
      window_capacity: per-series event-ring size; beyond it the oldest
        observations leave the *window* view (lifetime totals and bucket
        counts are unaffected).
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 window_capacity: int = 8192):
        self.clock = clock
        self.window_capacity = window_capacity
        self._families: Dict[str, Family] = {}
        self._collectors: List[Callable[["MetricRegistry"], None]] = []

    # -- family constructors ------------------------------------------------
    def _family(self, name: str, help: str, kind: str, labels,
                uppers=None) -> Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.labelnames != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}"
                    f"{fam.labelnames}, not {kind}{tuple(labels)}")
            return fam
        fam = Family(self, name, help, kind, tuple(labels), uppers)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "", labels=()) -> Family:
        return self._family(name, help, "counter", labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Family:
        return self._family(name, help, "gauge", labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        uppers = tuple(sorted(float(b) for b in buckets))
        fam = self._family(name, help, "histogram", labels, uppers)
        if fam.uppers != uppers:
            raise ValueError(f"metric {name!r} already registered with "
                             f"buckets {fam.uppers}")
        return fam

    def register_collector(self, fn: Callable[["MetricRegistry"], None]):
        """``fn(registry)`` runs before every export/snapshot -- the hook
        that pulls externally-maintained counts (e.g. the kernel backend
        registry's resolution counters) into the export."""
        self._collectors.append(fn)
        return fn

    def families(self) -> List[Family]:
        return [self._families[n] for n in sorted(self._families)]

    def _collect(self) -> None:
        for fn in self._collectors:
            fn(self)

    # -- exports ------------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        self._collect()
        out: List[str] = []
        for fam in self.families():
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.items():
                if fam.kind == "counter":
                    out.append(f"{fam.name}{fam._label_str(key)} "
                               f"{_num(child.total)}")
                elif fam.kind == "gauge":
                    out.append(f"{fam.name}{fam._label_str(key)} "
                               f"{_num(child.value)}")
                else:
                    cum = 0
                    for hi, c in zip(child.uppers, child.counts):
                        cum += c
                        le = 'le="%s"' % _num(hi)
                        out.append(f"{fam.name}_bucket"
                                   f"{fam._label_str(key, le)} {cum}")
                    cum += child.counts[-1]
                    le = 'le="+Inf"'
                    out.append(f"{fam.name}_bucket"
                               f"{fam._label_str(key, le)} {cum}")
                    out.append(f"{fam.name}_sum{fam._label_str(key)} "
                               f"{_num(child.sum)}")
                    out.append(f"{fam.name}_count{fam._label_str(key)} "
                               f"{cum}")
        return "\n".join(out) + "\n"

    def snapshot(self, window_s: Optional[float] = None,
                 now: Optional[float] = None) -> Dict:
        """Per-series aggregates, windowed to the trailing ``window_s``.

        Counters report the window's delta and rate; gauges their latest
        value (and window min/max); histograms windowed count/sum/p50/p90/
        p99.  ``window_s=None`` means lifetime (rates use the span between
        the series' first and last events).  The structure is plain JSON
        for the controller loop and tests.
        """
        self._collect()
        now = self.clock() if now is None else now
        doc: Dict = {"window_s": window_s, "now": now, "series": {}}
        for fam in self.families():
            fdoc = doc["series"].setdefault(
                fam.name, {"kind": fam.kind, "labels": fam.labelnames,
                           "children": {}})
            for key, child in fam.items():
                label_str = ",".join(key) if key else ""
                events = child.window(now, window_s)
                if fam.kind == "counter":
                    delta = sum(v for _, v in events)
                    if window_s is not None:
                        rate = delta / window_s if window_s > 0 else 0.0
                    else:
                        span = (events[-1][0] - events[0][0]
                                if len(events) > 1 else 0.0)
                        rate = delta / span if span > 0 else 0.0
                    fdoc["children"][label_str] = {
                        "total": child.total, "delta": delta,
                        "rate_per_s": rate}
                elif fam.kind == "gauge":
                    vals = [v for _, v in events]
                    fdoc["children"][label_str] = {
                        "value": child.value,
                        "min": min(vals) if vals else child.value,
                        "max": max(vals) if vals else child.value}
                else:
                    vals = [v for _, v in events]
                    counts = [0] * (len(child.uppers) + 1)
                    for v in vals:
                        counts[child._bucket_index(v)] += 1
                    fdoc["children"][label_str] = {
                        "count": len(vals),
                        "sum": float(sum(vals)),
                        "p50": histogram_quantile(.50, child.uppers, counts),
                        "p90": histogram_quantile(.90, child.uppers, counts),
                        "p99": histogram_quantile(.99, child.uppers, counts),
                        "lifetime_count": child.count,
                    }
        return doc

    def series_events(self, name: str, window_s: Optional[float] = None,
                      now: Optional[float] = None
                      ) -> List[Tuple[Dict[str, str],
                                      List[Tuple[float, float]]]]:
        """Raw windowed events of one family, with *structured* labels.

        ``snapshot`` keys children by a comma-joined label string -- fine
        for JSON eyeballs, lossy for programs.  This accessor returns
        ``[(labels_dict, [(t, value), ...]), ...]`` per child so the
        sliding-window re-profiler (``TrafficProfile.from_registry``) can
        recover (op, bucket) tuples without string parsing.  Children are
        in sorted label order; an unknown family is an empty list, and an
        empty window is an empty event list per child (the child itself is
        still reported, which is what lets the re-profiler distinguish
        "series went quiet" from "series never existed").
        """
        self._collect()
        now = self.clock() if now is None else now
        fam = self._families.get(name)
        if fam is None:
            return []
        return [(dict(zip(fam.labelnames, key)), child.window(now, window_s))
                for key, child in fam.items()]

    def to_json(self) -> Dict:
        """Lifetime snapshot as a plain dict (JSON-clean: NaN-free)."""
        doc = self.snapshot(window_s=None)
        return _denan(doc)


def _num(v: float) -> str:
    """Prometheus number spelling: integers without the trailing .0."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _denan(x):
    if isinstance(x, dict):
        return {k: _denan(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_denan(v) for v in x]
    if isinstance(x, float) and math.isnan(x):
        return None
    return x
