"""repro.obs -- observability for the serving pipeline.

The paper co-designs its MM and SVD stages around *measured per-stage*
latency and energy; this package gives the software serving stack the same
per-stage eyes on live traffic, as three small, composable pieces:

  ``tracing``   span-based tracing of the request/flush/control lifecycle
                into a bounded ring, exportable as Chrome trace-event JSON
                (``chrome://tracing`` / Perfetto-loadable), with
                parent/child links tying each request to the flush that
                retired it.
  ``metrics``   a process-local registry of counters / gauges /
                fixed-bucket histograms with labeled series, windowed
                snapshots, and Prometheus-text + JSON export.
  ``slo``       deadline-miss counting and goodput-under-SLO
                (SLO-compliant requests/s) from the same per-request data.

``Observability`` bundles one of each behind a single object the serving
engine threads through its stages: ``PCAServer(obs=Observability.enabled(
slo_ms=50))``.  The default (``obs=None``) keeps the engine on an
uninstrumented fast path -- one attribute check per stage, measured within
3% of bare throughput (``tests/test_obs.py``).  All three pieces take the
same injectable clock so spans, metric windows and SLO accounting line up
with the server's own telemetry, including under a manual test clock.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, Optional

from .metrics import (DEFAULT_BUCKETS, Counter, Family, Gauge, Histogram,
                      MetricRegistry, fmt_label, histogram_quantile)
from .slo import SLORecord, SLOTracker, from_records as slo_from_records
from .tenants import OUTCOMES as TENANT_OUTCOMES, TenantAccounting
from .tracing import Span, Tracer, device_profile, validate_trace


def backend_resolution_collector(registry: MetricRegistry) -> None:
    """Mirror the kernel backend registry's per-(op, backend) resolution
    counts into ``kernel_backend_resolutions_total`` at export time."""
    from repro.backends import registry as kernel_registry
    fam = registry.counter(
        "kernel_backend_resolutions_total",
        "Kernel-op backend resolutions by (op, backend).",
        ("op", "backend"))
    for (op, backend), n in sorted(
            kernel_registry.resolution_counts().items()):
        fam.labels(op=op, backend=backend).set_total(n)


@dataclasses.dataclass
class Observability:
    """One tracer + one metric registry + (optionally) one SLO tracker.

    Build with ``Observability.enabled(...)``; pass to
    ``PCAServer(obs=...)`` and/or use standalone.  ``clock`` is the shared
    timestamp source -- give the server the same one.
    """
    tracer: Tracer
    metrics: MetricRegistry
    slo: Optional[SLOTracker] = None
    clock: "callable" = time.monotonic

    @classmethod
    def enabled(cls, slo_ms: Optional[float] = None,
                clock=time.monotonic, trace_capacity: int = 65536,
                window_capacity: int = 8192) -> "Observability":
        """An armed observability bundle (the CLI's ``--trace-out`` /
        ``--metrics-out`` / ``--slo-ms`` path).  The kernel backend
        resolution counters are wired in as an export-time collector."""
        metrics = MetricRegistry(clock=clock,
                                 window_capacity=window_capacity)
        metrics.register_collector(backend_resolution_collector)
        slo = (SLOTracker(slo_s=slo_ms / 1e3, registry=metrics, clock=clock)
               if slo_ms is not None
               else SLOTracker(slo_s=None, registry=metrics, clock=clock))
        return cls(tracer=Tracer(capacity=trace_capacity, clock=clock),
                   metrics=metrics, slo=slo, clock=clock)

    # -- exports ------------------------------------------------------------
    def trace_doc(self, process_name: str = "repro.serving") -> Dict:
        return self.tracer.export(process_name)

    def save_trace(self, path) -> pathlib.Path:
        """Validate against the Chrome trace schema, then write."""
        return self.tracer.save(path)

    def prometheus_text(self) -> str:
        return self.metrics.to_prometheus()

    def save_metrics(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(self.prometheus_text())
        return path

    def summary(self, window_s: Optional[float] = None) -> Dict:
        """Compact JSON-able status: span/series counts + SLO accounting."""
        doc = {
            "spans": len(self.tracer),
            "spans_dropped": self.tracer.dropped,
            "metric_series": sum(len(f._children)
                                 for f in self.metrics.families()),
        }
        if self.slo is not None:
            doc["slo"] = self.slo.summary(window_s=window_s)
        return doc

    def save_summary(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.summary(), indent=2, sort_keys=True)
                        + "\n")
        return path


__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Family", "Gauge", "Histogram",
    "MetricRegistry", "Observability", "SLORecord", "SLOTracker", "Span",
    "TENANT_OUTCOMES", "TenantAccounting",
    "Tracer", "backend_resolution_collector", "device_profile", "fmt_label",
    "histogram_quantile", "slo_from_records", "validate_trace",
]
