"""Flush executors: where a bucket's microbatch actually runs.

The paper scales MANOJAVAM by replicating S systolic arrays behind one
fabric; ``MeshExecutor`` is the next rung of that ladder -- replicate the
*whole fabric* across a device mesh and shard the microbatch (S) axis over
it, so one flush retires ``S x n_devices`` requests.  ``PCAServer`` owns
queueing, bucketing and deadlines and delegates compile/placement/dispatch
to an executor:

  * ``LocalExecutor`` -- the original single-device path: plain ``jax.jit``
    per (op, bucket, batch, config).  The default; zero distribution cost.
  * ``MeshExecutor`` -- owns a ``jax.sharding.Mesh`` and jits the batched
    solvers with batch-axis ``NamedSharding`` in/out specs resolved through
    the ``parallel.sharding`` ``Rules`` machinery ("batch" role -> data
    axis).  Partial flushes are padded up to a multiple of the data-axis
    size so every shard receives an identical slab and the executable never
    sees a ragged batch.

Executables cache under a key that includes ``cache_token()`` (mesh axis
sizes + device ids), so one server can swap meshes -- or route some buckets
locally and others onto the mesh -- without ever reusing an executable
compiled for different placement.

The executor seam is where the "async device streams" follow-on landed:
``submit`` launches a flush without blocking (JAX async dispatch returns
device futures the moment the computation is enqueued) and hands back an
``inflight.InFlightFlush`` whose ``ready()``/``result()`` the engine's
in-flight and retire stages drive.  ``run`` remains as the blocking
compatibility path -- exactly ``submit(...).result()``.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.pca import PCAConfig
from repro.parallel.sharding import (batch_axes, pad_to_multiple,
                                     rules_for_mesh)
from .cache import SolverKey
from .inflight import InFlightFlush
from .solver import build_solver_fn


def solver_structs(bucket: Tuple[int, ...],
                   batch: int) -> Tuple[jax.ShapeDtypeStruct, ...]:
    """Abstract input signature of one flush: the padded slab plus one
    int32 per-problem true-size vector per bucket dimension (the uniform
    ``build_solver_fn`` calling convention)."""
    return (
        jax.ShapeDtypeStruct((batch, *bucket), jnp.float32),
        *(jax.ShapeDtypeStruct((batch,), jnp.int32) for _ in bucket),
    )


def _donate_kwargs() -> dict:
    """Donate the flush's input slab to its executable.

    The engine never reuses a dispatched batch, so XLA may alias the input
    buffer for outputs -- one less allocation per in-flight flush, which is
    what keeps a deep pipeline's memory footprint flat on accelerators.
    CPU PJRT cannot alias host buffers and logs a warning per compiled
    executable, so donation is reserved for real device backends.
    """
    if jax.default_backend() == "cpu":
        return {}
    return {"donate_argnums": (0,)}


class LocalExecutor:
    """Single-device flush execution (the seed behavior).

    Near-stateless: the engine owns the executable cache; the executor
    decides batch rounding, compilation and dispatch, and memoizes its
    shape-polymorphic ``jax.jit`` wrappers per solver (see ``compile``).
    """

    n_shards: int = 1
    # optional repro.obs.Observability bundle; the engine attaches its own
    # when it carries one, so launches are traced where they happen
    obs = None

    def cache_token(self):
        """Executor identity mixed into the engine's executable-cache key."""
        return None

    def round_batch(self, b: int) -> int:
        """Device batch the engine must pad a b-request flush up to."""
        return b

    def compile(self, op: str, config: PCAConfig,
                bucket: Tuple[int, ...], batch: int) -> Callable:
        del bucket, batch  # single device: shape-polymorphic jit is enough
        # one wrapper per solver, NOT per call: the engine's cache keys on
        # (op, bucket, batch, ...) and used to receive a fresh jit wrapper
        # for every key -- so two batch sizes of one bucket (or two buckets
        # of one solver) each re-built and re-traced an identical solver
        # closure with its own private jit trace cache.  Memoizing on the
        # solver identity hands every key the *same* wrapper, whose shared
        # trace cache compiles each distinct input shape exactly once no
        # matter how many engine keys route through it.
        memo = self.__dict__.setdefault("_solvers", {})
        key = (op, SolverKey.from_config(config))
        fn = memo.get(key)
        if fn is None:
            fn = memo[key] = jax.jit(build_solver_fn(op, config),
                                     **_donate_kwargs())
        return fn

    def aot_compile(self, op: str, config: PCAConfig,
                    bucket: Tuple[int, ...], batch: int):
        """Ahead-of-time compile one concrete (bucket, batch) executable.

        The ``jax.stages.Compiled`` this returns is what the persistent
        cache tier serializes (``serving.cache.DiskCache``) and what
        ``PCAServer.warmup`` pre-builds: calling it runs zero tracing and
        zero XLA work.  It shares the memoized polymorphic wrapper, so a
        later same-shape JIT call reuses the identical compilation.
        """
        return self.compile(op, config, bucket, batch).lower(
            *solver_structs(bucket, batch)).compile()

    def submit(self, fn: Callable, batch, n_active) -> InFlightFlush:
        """Launch a flush without blocking (the pipeline's dispatch stage).

        JAX async dispatch returns the output tree as device futures, so
        the host goes straight back to batching while the device crunches.
        The returned handle exposes ``ready()`` for completion detection
        and ``result()`` for the single host gather -- per-request slicing
        happens on the host copy, because slicing a device array per ticket
        is O(batch) dispatches, and on a sharded array each one is a
        cross-device gather that costs more than the flush's compute
        (measured ~3x the solve time at 8 host devices).
        """
        obs = self.obs
        if obs is None:
            out = fn(jnp.asarray(batch), *map(jnp.asarray, n_active))
        else:
            t0 = obs.clock()
            out = fn(jnp.asarray(batch), *map(jnp.asarray, n_active))
            obs.tracer.complete(
                "launch", ts=t0, end=obs.clock(), cat="launch",
                track="launch", executor=self.describe(),
                batch=int(np.shape(batch)[0]), n_shards=self.n_shards)
            obs.metrics.counter(
                "serve_launches_total", "Device launches by executor.",
                ("executor", )).labels(self.describe()).inc()
        return InFlightFlush(out, n_shards=self.n_shards)

    def run(self, fn: Callable, batch, n_active):
        """Blocking compatibility path: ``submit(...).result()``."""
        return self.submit(fn, batch, n_active).result()

    def describe(self) -> str:
        return "local(1 device)"


class MeshExecutor(LocalExecutor):
    """Shard the flush's batch (S) axis across a named device mesh.

    Args:
      mesh: mesh to run on; ``data_axis`` must be one of its axis names.
        Default: a 1-D "data" mesh over ``devices`` (or every visible
        device), i.e. pure data parallelism over the sample axis -- the
        regime where PCA throughput actually scales (Martel et al.).
      devices: devices for the default mesh (ignored when ``mesh`` given).
      data_axis: mesh axis the batch dim shards over.

    Numerics are placement-invariant: each problem in the batch lives
    entirely on one shard (the batch dim is the only sharded dim), so a
    sharded flush is bit-for-bit the same math as the single-device flush
    on every problem -- parity is tested per op in
    ``tests/test_sharded_serving.py``.
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 devices: Optional[Sequence] = None,
                 data_axis: str = "data"):
        if mesh is None:
            devs = list(devices if devices is not None else jax.devices())
            mesh = Mesh(np.asarray(devs), (data_axis,))
        if data_axis not in mesh.axis_names:
            raise ValueError(
                f"data_axis {data_axis!r} not in mesh axes {mesh.axis_names}")
        self.mesh = mesh
        self.data_axis = data_axis
        self.rules = rules_for_mesh(mesh)
        axes = self.rules.axis("batch")
        if not axes or data_axis not in (
                (axes,) if isinstance(axes, str) else tuple(axes)):
            raise ValueError(
                "the batch role must resolve onto the data axis; name the "
                f"mesh axis 'data' (got mesh axes {mesh.axis_names})")
        self.n_shards = int(np.prod(
            [mesh.shape[a] for a in ((axes,) if isinstance(axes, str)
                                     else axes)]))

    def cache_token(self):
        # axis sizes + concrete device ids: same-shaped meshes over
        # different devices must not share executables
        return ("mesh", tuple(self.mesh.shape.items()),
                tuple(d.id for d in self.mesh.devices.flat))

    def round_batch(self, b: int) -> int:
        return pad_to_multiple(max(b, 1), self.n_shards)

    def compile(self, op: str, config: PCAConfig,
                bucket: Tuple[int, ...], batch: int) -> Callable:
        if batch % self.n_shards:
            raise ValueError(
                f"batch {batch} not a multiple of the data-axis size "
                f"{self.n_shards}; round with round_batch() first")
        fn = build_solver_fn(op, config)
        in_struct = solver_structs(bucket, batch)
        out_struct = jax.eval_shape(fn, *in_struct)
        in_sh = self.rules.sharding_tree(batch_axes(in_struct), self.mesh)
        out_sh = self.rules.sharding_tree(batch_axes(out_struct), self.mesh)
        return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       **_donate_kwargs())

    def describe(self) -> str:
        shape = "x".join(f"{k}={v}" for k, v in self.mesh.shape.items())
        return f"mesh({shape}; {self.n_shards} shards)"


def host_mesh(n_devices: Optional[int] = None,
              data_axis: str = "data") -> Mesh:
    """A 1-D data mesh over the first ``n_devices`` visible devices
    (None/0 = all).  Degrades gracefully: asking for more devices than
    visible clamps rather than raising, so the same launch line works on a
    laptop (1 device) and under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    devs = jax.devices()
    n = len(devs) if not n_devices else min(n_devices, len(devs))
    return Mesh(np.asarray(devs[:n]), (data_axis,))


def mesh_executor(spec) -> LocalExecutor:
    """Executor from a CLI-style mesh spec.

    ``None``/``"none"``/``"1"`` -> ``LocalExecutor``; ``"auto"`` -> a mesh
    over every visible device; an int(-string) N -> a mesh over the first
    min(N, visible) devices.
    """
    if spec is None or spec in ("none", "local"):
        return LocalExecutor()
    if spec == "auto":
        n = None
    else:
        n = int(spec)
        if n <= 1:
            return LocalExecutor()
    return MeshExecutor(mesh=host_mesh(n))
