"""repro.serving -- batched multi-tenant PCA/SVD serving.

The paper's S-arrays-plus-Matrix-Padding-Unit scalability story as a service:
heterogeneous requests are padded into T-multiple shape buckets
(``batching``), up to S same-bucket requests stack into one vmapped device
batch (``solver``), and ``engine.PCAServer`` runs the queue with
deadline-aware microbatching, a compiled-executable cache, and full
telemetry (``stats``).
"""
from .batching import (BucketPolicy, POLICIES, pad_to_bucket, padding_waste,
                       stack_requests)
from .engine import (BackendRouter, OPS, PCAServer, ServedEigh, ServedPCA,
                     ServedSVD, Ticket, threshold_router)
from .solver import (BatchedEighResult, BatchedPCAResult, BatchedSVDResult,
                     jacobi_eigh_batched, jacobi_svd_batched, pca_fit_batched,
                     pca_transform_batched)
from .stats import RequestRecord, ServingStats, percentile

__all__ = [
    "BackendRouter", "BatchedEighResult", "BatchedPCAResult",
    "BatchedSVDResult", "BucketPolicy", "OPS", "PCAServer", "POLICIES",
    "RequestRecord", "ServedEigh", "ServedPCA", "ServedSVD", "ServingStats",
    "Ticket", "jacobi_eigh_batched", "jacobi_svd_batched", "pad_to_bucket",
    "padding_waste", "pca_fit_batched", "pca_transform_batched",
    "percentile", "stack_requests", "threshold_router",
]
