"""repro.serving -- batched multi-tenant PCA/SVD serving.

The paper's S-arrays-plus-Matrix-Padding-Unit scalability story as a service:
heterogeneous requests are padded into T-multiple shape buckets
(``batching``), up to S same-bucket requests stack into one vmapped device
batch (``solver``), and ``engine.PCAServer`` runs the queue with
deadline-aware microbatching, a compiled-executable cache, and full
telemetry (``stats``).  Flush placement is an executor (``sharded``): the
default ``LocalExecutor`` runs on one device; ``MeshExecutor`` shards the
batch axis across a named device mesh so one flush retires S x n_devices
requests.  Flush *timing* is a pipeline (``inflight``): executors launch
without blocking, a bounded in-flight queue holds launched flushes, and
retirement unpacks them into tickets -- ``PCAServer(max_inflight=N)``
overlaps host-side batching with device execution (N=1 is the synchronous
engine).  The whole (policy, T, pow2 cap, S, inflight, executor) tuple is a
``ServingPlan`` the traffic-driven autotuner (``autotune``) searches from a
captured ``TrafficProfile`` and hot-swaps onto a live server via
``PCAServer.apply_plan``.  Executables live in a two-tier cache
(``cache``): a bounded in-memory LRU plus an optional persistent
disk tier of serialized AOT executables, so a fresh replica pointed at a
warm ``cache_dir`` -- or pre-built via ``PCAServer.warmup(profile)`` --
serves its first request without ever touching XLA.

Configuration is one frozen ``spec.ServerSpec`` (scheduling / execution /
cache / obs / controller sub-specs): ``PCAServer.from_spec(spec)`` builds
the whole stack, ``ServerSpec.from_args`` maps the CLI onto it, and
``to_json``/``from_json`` round-trip it for config files.  The
``controller.ServingController`` closes the autotune loop autonomously:
re-profile a sliding telemetry window, bandit-search the plan grid, and
hot-swap behind hysteresis + dwell guards.
"""
from .autotune import (AutotuneResult, CostModel, ServingPlan,
                       TrafficProfile, TRACE_KINDS, autotune, bandit_search,
                       plan_grid, replay, server_for_plan, solve_work,
                       subsample, synthetic_trace, trace_dims)
from .batching import (BucketPolicy, POLICIES, pad_to_bucket, padding_waste,
                       stack_requests)
from .cache import (DiskCache, ExecutableCache, LRUCache, SolverKey,
                    aot_supported, content_hash, environment_fingerprint)
from .controller import ServingController
from .engine import (BackendRouter, OPS, PCAServer, ServedEigh, ServedPCA,
                     ServedSVD, Ticket, threshold_router)
from .frontend import (ADMISSION_MODES, ARRIVALS, AdmissionController,
                       AdmissionDecision, Arrival, FairQueue,
                       FrontendReport, SCHEDULERS, TenantSpec, TokenBucket,
                       TrafficFrontend, VirtualClock, arrival_times,
                       generate, materialize, merge, parse_tenants,
                       profile_of)
from .inflight import InFlightFlush, InFlightQueue
from .sharded import LocalExecutor, MeshExecutor, host_mesh, mesh_executor
from .spec import (CacheSpec, ControllerSpec, ExecutionSpec, ObsSpec,
                   SchedulingSpec, ServerSpec, SpecConflictError,
                   build_server, resolve_spec, validate_args)
from .solver import (BatchedEighResult, BatchedPCAResult, BatchedSVDResult,
                     build_solver_fn, jacobi_eigh_batched,
                     jacobi_svd_batched, pca_fit_batched,
                     pca_transform_batched)
from .stats import FlushRecord, RequestRecord, ServingStats, percentile

__all__ = [
    "ADMISSION_MODES", "ARRIVALS", "AdmissionController",
    "AdmissionDecision", "Arrival", "FairQueue", "FrontendReport",
    "SCHEDULERS", "TenantSpec", "TokenBucket", "TrafficFrontend",
    "VirtualClock", "arrival_times", "generate", "materialize", "merge",
    "parse_tenants", "profile_of",
    "AutotuneResult", "BackendRouter", "BatchedEighResult",
    "BatchedPCAResult", "BatchedSVDResult", "BucketPolicy", "CacheSpec",
    "ControllerSpec", "CostModel", "DiskCache", "ExecutableCache",
    "ExecutionSpec", "FlushRecord", "InFlightFlush", "InFlightQueue",
    "LRUCache", "LocalExecutor", "MeshExecutor", "OPS", "ObsSpec",
    "PCAServer", "POLICIES", "RequestRecord", "SchedulingSpec",
    "ServedEigh", "ServedPCA", "ServedSVD", "ServerSpec",
    "ServingController", "ServingPlan", "ServingStats", "SolverKey",
    "SpecConflictError", "Ticket", "TrafficProfile", "TRACE_KINDS",
    "aot_supported", "autotune", "bandit_search", "build_server",
    "build_solver_fn", "content_hash", "environment_fingerprint",
    "host_mesh", "jacobi_eigh_batched", "jacobi_svd_batched",
    "mesh_executor", "pad_to_bucket", "padding_waste", "pca_fit_batched",
    "pca_transform_batched", "percentile", "plan_grid", "replay",
    "resolve_spec", "server_for_plan", "solve_work", "stack_requests",
    "subsample", "synthetic_trace", "threshold_router", "trace_dims",
    "validate_args",
]
