"""PCAServer: deadline-aware microbatching over shape-bucketed traffic.

The serving loop is the software image of the paper's fabric: the Matrix
Padding Unit (``batching``) normalizes heterogeneous requests into T-multiple
buckets, and the S-array axis (``solver``) retires up to S same-bucket
requests per dispatch.  Requests queue per (op, bucket); a queue flushes when
it reaches S (full microbatch) or when its oldest request's deadline expires
(``poll``).  Each (op, bucket, batch) triple maps to one jitted executable
held in an explicit cache -- with ``pad_batches=True`` partial flushes are
zero-padded up to S so steady-state traffic runs entirely on cached
executables and never recompiles.

A flush is a three-stage pipeline, the software image of the paper's
block-streaming (keep the S arrays busy while the next block streams in):

  dispatch   ``_dispatch_key``: stack/pad, grab the cached executable,
             launch via ``executor.submit`` -- non-blocking, the host goes
             straight back to batching while the device crunches.
  in-flight  a bounded ``inflight.InFlightQueue`` of launched flushes
             (``max_inflight`` is the back-pressure valve).
  retire     ``_retire``: one host gather per flush, unpack into tickets,
             record telemetry.  ``poll``/``drain`` retire completed
             flushes; ``Ticket.result()``/``Ticket.wait()`` force exactly
             their own flush home.

With ``max_inflight=1`` (the default) every dispatch immediately retires
its own flush -- exactly the synchronous engine this pipeline replaced --
so the clock-injectable deterministic test story is unchanged: callers
drive time via ``submit``/``poll``/``drain``.

Where a flush *runs* is the executor's business (``sharded``): the default
``LocalExecutor`` is the single-device path; ``MeshExecutor`` shards the
batch axis across a device mesh so one flush retires S x n_devices
requests.  The engine only asks the executor to round the batch, compile
the solver, and launch it -- queueing/bucketing/deadlines never see devices.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pca import PCAConfig
from .batching import BucketPolicy, padding_waste, stack_requests
from .cache import DEFAULT_MAX_ENTRIES, ExecutableCache, SolverKey
from .inflight import InFlightFlush, InFlightQueue
from .sharded import LocalExecutor
from .stats import RequestRecord, ServingStats

OPS = ("eigh", "svd", "pca")

# sentinel distinguishing "caller passed this kwarg" from the default --
# the deprecation shim below counts explicit spec-covered kwargs
_UNSET = object()

# how many spec-covered kwargs a direct PCAServer(...) call may pass
# before the construction is spec-shaped enough that the shim asks for a
# ServerSpec instead (1-2 kwargs is a tweak; 3+ is a configuration)
SPEC_SHIM_THRESHOLD = 3

_spec_depth = 0  # >0 while spec.build_server / server_for_plan constructs


@contextlib.contextmanager
def spec_construction():
    """Suppress the multi-kwarg ``DeprecationWarning`` for construction
    paths that already went through the spec layer (``PCAServer.from_spec``
    builds with many kwargs internally -- that is the blessed path, not
    the deprecated one)."""
    global _spec_depth
    _spec_depth += 1
    try:
        yield
    finally:
        _spec_depth -= 1

# a backend router maps (op, bucket_shape) -> kernel backend name for that
# bucket's executable (None = plain XLA matmul datapath); see
# ``repro.backends`` for the names
BackendRouter = Callable[[str, Tuple[int, ...]], Optional[str]]


def threshold_router(min_dim: int, large: Optional[str] = "auto",
                     small: Optional[str] = None) -> BackendRouter:
    """Route big buckets to one backend, small ones to another.

    The ROADMAP "multi-backend dispatch" follow-on: kernel-launch overhead
    dominates tiny problems (keep them on plain XLA) while large tiles win
    on the Pallas MM-Engine.  A bucket whose largest dim reaches ``min_dim``
    routes to ``large``; everything else to ``small``.  ``"auto"`` resolves
    per host via the registry (``pallas`` on TPU, ``interpret`` elsewhere)
    so ``threshold_router(128)`` is safe on any machine; ``None`` means the
    plain XLA matmul datapath.

    ``"auto"`` is resolved *once, here at construction*, pinning the
    routing decision for the router's lifetime: a later
    ``set_default_backend``/``use_backend`` must not silently re-route a
    live server's buckets (build a new router to pick up a changed
    default), and ``RequestRecord.backend`` telemetry always names the
    concrete backend, never the sentinel.
    """
    def resolve(name: Optional[str]) -> Optional[str]:
        if name == "auto":
            from repro.backends import default_backend
            return default_backend()
        return name

    large = resolve(large)
    small = resolve(small)

    def route(op: str, bucket: Tuple[int, ...]) -> Optional[str]:
        del op
        return large if max(bucket) >= min_dim else small
    return route


@dataclasses.dataclass(frozen=True)
class ServedEigh:
    eigenvalues: np.ndarray
    eigenvectors: np.ndarray
    off_norm: float


@dataclasses.dataclass(frozen=True)
class ServedSVD:
    U: np.ndarray
    S: np.ndarray
    Vt: np.ndarray


@dataclasses.dataclass(frozen=True)
class ServedPCA:
    components: np.ndarray
    eigenvalues: np.ndarray
    mean: np.ndarray
    scale: np.ndarray
    evcr: np.ndarray
    cvcr: np.ndarray
    off_norm: float


class Ticket:
    """Handle returned by ``submit``; fulfilled when its flush retires.

    A ticket moves through the pipeline stages with its request: *queued*
    (waiting in its bucket queue), *in flight* (its microbatch was
    dispatched and is executing), *done* (its flush retired).  ``result()``
    on an in-flight ticket forces exactly its own flush home; ``wait()``
    additionally dispatches a still-queued partial batch, so it always
    makes progress.
    """

    __slots__ = ("rid", "op", "shape", "bucket", "sweeps", "record",
                 "_result", "_done", "_flush", "_server")

    def __init__(self, rid: int, op: str, shape, bucket, sweeps: int = 0):
        self.rid = rid
        self.op = op
        self.shape = shape
        self.bucket = bucket
        self.sweeps = sweeps
        self.record: Optional[RequestRecord] = None
        self._result = None
        self._done = False
        self._flush: Optional[InFlightFlush] = None
        self._server = None

    @property
    def done(self) -> bool:
        return self._done

    @property
    def inflight(self) -> bool:
        """Dispatched but not yet retired."""
        return self._flush is not None

    def result(self):
        """The served result; retires this ticket's own flush if it is in
        flight, raises if the request is still queued (un-dispatched)."""
        if not self._done:
            flush = self._flush
            if flush is None:
                depth = (self._server._queue_depth(self.op, self.bucket,
                                                   self.sweeps)
                         if self._server is not None else 0)
                raise RuntimeError(
                    f"request {self.rid} (op={self.op!r}, bucket "
                    f"{self.bucket}) is still queued ({depth} request(s) "
                    f"in its bucket queue); call wait(), or poll()/drain() "
                    f"the server, to flush it")
            flush.retire()
        return self._result

    def wait(self, timeout: Optional[float] = None):
        """Block until this request's result is available and return it.

        A still-queued request first has its bucket queue dispatched (a
        partial flush, like a deadline expiry).  ``timeout`` -- measured on
        the host wall clock, not the server's injectable clock, since it
        bounds a real device wait -- raises ``TimeoutError`` if the flush
        has not completed in time (the flush stays in flight and a later
        ``wait``/``poll``/``drain`` can still retire it).
        """
        if self._done:
            return self._result
        if self._flush is None:
            if self._server is None:
                raise RuntimeError(
                    f"request {self.rid} is not attached to a server")
            self._server._dispatch_key((self.op, self.bucket, self.sweeps))
        if self._done:  # dispatch back-pressure may already have retired us
            return self._result
        if timeout is not None:
            deadline = time.monotonic() + timeout
            while not self._flush.ready():
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"request {self.rid} (op={self.op!r}, bucket "
                        f"{self.bucket}) still in flight after "
                        f"{timeout:g}s")
                time.sleep(50e-6)
        self._flush.retire()
        return self._result

    def _fulfil(self, result, record: RequestRecord) -> None:
        self._result = result
        self.record = record
        self._done = True
        self._flush = None
        self._server = None


@dataclasses.dataclass
class _Pending:
    rid: int
    matrix: np.ndarray
    ticket: Ticket
    t_submit: float
    flush_by: float


class PCAServer:
    """Multi-tenant PCA/SVD/eigh service over one PCAConfig.

    Args:
      config: solver configuration; ``config.S`` is the default microbatch
        size (the fabric's S arrays), ``config.T`` the default bucket tile.
      policy: bucket policy (default: tile-mode with T = config.T).
      max_batch: requests per device batch (default: config.S).
      max_delay_s: default flush deadline for a queued request.
      pad_batches: zero-pad partial flushes up to max_batch so every bucket
        uses a single cached executable (no recompiles on timeout flushes).
      backend_router: optional (op, bucket) -> backend-name routing so
        different buckets run on different kernel backends in one server
        (e.g. ``threshold_router(128)``: big buckets on Pallas, small ones
        on plain XLA).  Default: every bucket uses ``config.backend``.  The
        executable cache key is backend-qualified.
      executor: where flushes compile and run (default:
        ``LocalExecutor()``, the single-device path).  Pass a
        ``sharded.MeshExecutor`` to shard each flush's batch axis across a
        device mesh, retiring ``max_batch`` requests per flush with
        ``max_batch / n_devices`` per device.  The cache key is
        executor-qualified (mesh shape + devices), so swapping executors
        never reuses an executable compiled for different placement.
      max_inflight: pipeline depth -- how many dispatched flushes may
        exist simultaneously, counting the one being dispatched.  ``1``
        (the default) is the synchronous engine: every dispatch
        immediately blocks on its own retirement.  ``N > 1`` lets up to
        ``N - 1`` flushes stay in flight while the host batches the next,
        overlapping host-side stacking/padding/unpacking with device
        execution; dispatching beyond the cap back-pressures by retiring
        the oldest flush first.
      obs: optional ``repro.obs.Observability`` bundle.  When given, every
        pipeline stage emits spans (request submit->fulfil, flush
        dispatch/inflight/wait/retire with compile children on cache
        misses, plan swaps) into its tracer and per-(op, bucket, backend,
        executor) counters/histograms into its metric registry, and each
        fulfilled request is SLO-accounted.  ``None`` (the default) is the
        uninstrumented fast path: one attribute check per stage, measured
        within 3% of bare throughput.  Give the bundle the same ``clock``
        as the server so spans line up with telemetry.
      cache_dir: optional directory for the persistent executable tier
        (``serving.cache.DiskCache``).  When set (and the installed jax
        can serialize executables), cache misses compile ahead-of-time and
        serialize to disk, so the *next* replica pointed at the same
        directory loads them without touching XLA -- the cold-start
        answer.  ``None`` (the default) is memory-tier-only serving.
      max_cached_executables: in-memory executable cap; least-recently-
        dispatched entries are evicted beyond it (a plan-churning server
        used to leak every executable it ever compiled).  ``None`` =
        unbounded.
      clock: injectable monotonic clock (tests drive deadlines manually).
    """

    def __init__(
        self,
        config: PCAConfig = PCAConfig(),
        policy: Optional[BucketPolicy] = _UNSET,
        max_batch: Optional[int] = _UNSET,
        max_delay_s: float = _UNSET,
        pad_batches: bool = _UNSET,
        backend_router: Optional[BackendRouter] = _UNSET,
        executor: Optional[LocalExecutor] = _UNSET,
        max_inflight: int = _UNSET,
        obs=_UNSET,
        cache_dir=_UNSET,
        max_cached_executables: Optional[int] = _UNSET,
        clock: Callable[[], float] = time.monotonic,
    ):
        # compatibility shim: this 13-kwarg signature predates
        # serving.spec.ServerSpec.  Each spec-covered kwarg defaults to a
        # sentinel so explicitly-passed kwargs are countable; passing
        # SPEC_SHIM_THRESHOLD or more of them outside the spec layer is a
        # spec-shaped construction and earns a DeprecationWarning pointing
        # at PCAServer.from_spec.
        explicit = sum(
            v is not _UNSET
            for v in (policy, max_batch, max_delay_s, pad_batches,
                      backend_router, executor, max_inflight, obs,
                      cache_dir, max_cached_executables))
        if explicit >= SPEC_SHIM_THRESHOLD and not _spec_depth:
            warnings.warn(
                f"PCAServer(...) with {explicit} construction kwargs is "
                "deprecated: build a serving.spec.ServerSpec and call "
                "PCAServer.from_spec(spec) (or spec.build_server(spec))",
                DeprecationWarning, stacklevel=2)
        policy = None if policy is _UNSET else policy
        max_batch = None if max_batch is _UNSET else max_batch
        max_delay_s = 0.01 if max_delay_s is _UNSET else max_delay_s
        pad_batches = True if pad_batches is _UNSET else pad_batches
        backend_router = (None if backend_router is _UNSET
                          else backend_router)
        executor = None if executor is _UNSET else executor
        max_inflight = 1 if max_inflight is _UNSET else max_inflight
        obs = None if obs is _UNSET else obs
        cache_dir = None if cache_dir is _UNSET else cache_dir
        max_cached_executables = (DEFAULT_MAX_ENTRIES
                                  if max_cached_executables is _UNSET
                                  else max_cached_executables)
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.config = config
        self.policy = policy or BucketPolicy(T=config.T)
        self.max_batch = max_batch or config.S
        self.max_delay_s = max_delay_s
        self.pad_batches = pad_batches
        self.backend_router = backend_router
        self.executor = executor or LocalExecutor()
        self.max_inflight = max_inflight
        self.obs = obs
        self.clock = clock
        self.stats = ServingStats(clock=clock)
        self._queues: Dict[Tuple, List[_Pending]] = {}
        self._inflight = InFlightQueue()
        self._cache = ExecutableCache(max_entries=max_cached_executables,
                                      cache_dir=cache_dir)
        self._rid = itertools.count()
        self._seq = itertools.count()
        self._exec_label = self.executor.describe()
        # optional serving.controller.ServingController; poll() ticks it
        # so the re-profile/search/swap loop rides the engine's own clock
        self.controller = None
        # declarative construction record when built via from_spec/
        # build_server (None for direct kwarg construction)
        self.spec = None
        if obs is not None:
            self._wire_obs()

    @classmethod
    def from_spec(cls, spec, clock: Optional[Callable[[], float]] = None,
                  frontend=None) -> "PCAServer":
        """Build a server (plus obs bundle and controller, when the spec
        asks for them) from a declarative ``serving.spec.ServerSpec`` --
        the blessed construction path the 13-kwarg ``__init__`` shims.
        ``clock`` injects a shared clock (tests pass a ``VirtualClock``);
        ``frontend`` wires the controller's admission feedback."""
        from .spec import build_server
        return build_server(spec, clock=clock, frontend=frontend)

    def _wire_obs(self) -> None:
        """Create the engine's metric families once (per-call recording is
        then a dict lookup) and hand the executor the bundle so launches
        are traced where they happen."""
        m = self.obs.metrics
        self._m_submitted = m.counter(
            "serve_requests_total", "Requests accepted by submit().",
            ("op",))
        self._m_flushes = m.counter(
            "serve_flushes_total", "Microbatch flushes dispatched.",
            ("op", "bucket", "backend", "executor", "cache"))
        self._m_latency = m.histogram(
            "serve_request_latency_seconds",
            "Submit-to-fulfil latency per request.",
            ("op", "bucket", "backend", "executor"))
        self._m_queue = m.histogram(
            "serve_queue_seconds",
            "Submit-to-dispatch wait per request.",
            ("op", "bucket", "backend", "executor"))
        self._m_wait = m.histogram(
            "serve_flush_wait_seconds",
            "Blocked-on-device time per retired flush.",
            ("op", "bucket", "backend", "executor"))
        self._m_batch = m.histogram(
            "serve_flush_batch_size", "Live requests per flush.",
            ("op", "bucket"), buckets=(1, 2, 4, 8, 16, 32, 64, 128))
        self._m_depth = m.gauge(
            "serve_inflight_depth",
            "In-flight flushes after a dispatch.").labels()
        self._m_queued = m.gauge(
            "serve_queued_requests",
            "Requests queued, not yet dispatched.").labels()
        self._m_swaps = m.counter(
            "serve_plan_swaps_total", "apply_plan hot-swaps.").labels()
        self._m_exec_cached = m.gauge(
            "serve_executables_cached",
            "Executables held in the in-memory cache tier.").labels()
        self._m_disk = m.counter(
            "serve_cache_disk_total",
            "Persistent executable-tier lookups by outcome.", ("event",))
        self._m_warm = m.counter(
            "serve_warmup_executables_total",
            "Executables pre-built by warmup(), by cache source.",
            ("source",))
        if getattr(self.executor, "obs", None) is None:
            self.executor.obs = self.obs

    # -- request path -------------------------------------------------------
    def submit(self, matrix, op: str = "eigh",
               max_delay_s: Optional[float] = None,
               sweeps: Optional[int] = None) -> Ticket:
        """Queue one request.  ``sweeps`` overrides the config's Jacobi
        sweep count for this request only -- the admission-control degrade
        path (``serving.frontend``) trades accuracy for latency by
        submitting with fewer sweeps.  Requests with different sweep
        counts batch separately (they need different executables, keyed by
        their relaxed ``SolverKey``)."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
        matrix = np.asarray(matrix, np.float32)
        if matrix.ndim != 2:
            raise ValueError(f"expected a 2-D matrix, got shape {matrix.shape}")
        if op == "eigh" and matrix.shape[0] != matrix.shape[1]:
            raise ValueError(f"eigh needs a square matrix, got {matrix.shape}")
        sweeps = self.config.sweeps if sweeps is None else int(sweeps)
        if sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {sweeps}")
        now = self.clock()
        bucket = self.policy.bucket_shape(matrix.shape)
        rid = next(self._rid)
        ticket = Ticket(rid, op, matrix.shape, bucket, sweeps)
        ticket._server = self
        delay = self.max_delay_s if max_delay_s is None else max_delay_s
        if self.obs is not None:
            self._m_submitted.labels(op=op).inc(now=now)
        self._enqueue((op, bucket, sweeps),
                      _Pending(rid, matrix, ticket, now, now + delay), now)
        return ticket

    def _enqueue(self, key: Tuple, entry: "_Pending", now: float) -> None:
        """Queue one request and flush its bucket when it reaches the cap
        (shared by ``submit`` and ``apply_plan``'s re-queue)."""
        queue = self._queues.setdefault(key, [])
        queue.append(entry)
        self.stats.record_queue_depth(len(queue), now)
        if self.obs is not None:
            self._m_queued.set(self.pending(), now=now)
        if len(queue) >= self.max_batch:
            self._dispatch_key(key)

    def poll(self, now: Optional[float] = None) -> int:
        """Retire every completed in-flight flush, then dispatch every
        queue whose oldest deadline has passed; returns the number of
        requests *retired* (with ``max_inflight=1`` a dispatched queue
        retires synchronously, so this is also the number flushed).

        Queues are visited in sorted (op, bucket) order, so dispatch --
        and therefore retirement and telemetry -- order is reproducible
        under the injected clock no matter the submission interleaving.

        When a ``serving.controller.ServingController`` is attached, poll
        also ticks it (before dispatch, so a plan swap this tick decides
        on lands ahead of the flushes it re-buckets); the controller's
        own cadence guard makes the tick a no-op between re-profiles.
        """
        now = self.clock() if now is None else now
        if self.controller is not None:
            self.controller.maybe_tick(now)
        done = self._inflight.retire_ready()
        for key in sorted(k for k, q in self._queues.items()
                          if q and min(e.flush_by for e in q) <= now):
            done += self._dispatch_key(key)
        return done

    def drain(self) -> int:
        """Dispatch everything regardless of deadlines, then retire every
        in-flight flush; returns the number of requests retired."""
        done = 0
        for key in sorted(self._queues):
            done += self._dispatch_key(key)
        return done + self._inflight.retire_to_depth(0)

    def pending(self) -> int:
        """Requests queued but not yet dispatched."""
        return sum(len(q) for q in self._queues.values())

    def inflight(self) -> int:
        """Flushes dispatched but not yet retired."""
        return self._inflight.depth

    def inflight_requests(self) -> int:
        """Requests riding the currently in-flight flushes."""
        return self._inflight.requests()

    def solve_many(self, matrices, op: str = "eigh") -> List:
        """Convenience: submit a burst, drain, return results in order."""
        tickets = [self.submit(m, op=op) for m in matrices]
        self.drain()
        return [t.result() for t in tickets]

    # -- plan hot-swap ------------------------------------------------------
    def describe_plan(self) -> Dict:
        """The serving plan currently in force, as plain JSON-able facts."""
        return {
            "mode": self.policy.mode,
            "T": self.policy.T,
            "pow2_cap": self.policy.pow2_cap,
            "max_batch": self.max_batch,
            "max_inflight": self.max_inflight,
            "executor": self.executor.describe(),
        }

    def apply_plan(self, plan, warm_profile=None) -> Dict:
        """Atomically switch this server onto a new serving plan.

        ``plan`` is any object with the ``serving.autotune.ServingPlan``
        surface: ``policy()``, ``build_executor()``, ``max_batch``,
        ``max_inflight``.  The swap happens *between* flushes:

          1. every in-flight flush is retired first (its tickets are
             fulfilled under the old plan -- they already rode old-plan
             slabs, so retiring them is the only exact choice);
          2. still-queued requests are re-bucketed under the new policy in
             submission order -- their tickets survive the swap untouched
             (same rid, same deadline), only their bucket assignment moves;
          3. policy / batch cap / pipeline depth / executor switch, and
             re-bucketed queues dispatch whenever they reach the new batch
             cap, mirroring ``submit``'s flush-on-full (so a merged queue
             that now holds several caps' worth flushes in cap-sized
             microbatches, not one oversized slab).

        ``config.T``/``config.S`` are realigned to the plan's tile and
        flush size (exactly what ``autotune.server_for_plan`` builds for a
        cold start), so a hot-swapped server and a cold server on the same
        plan compile identical executables -- including the matmul block
        size when ``config.backend`` routes through the MM-Engine -- and
        serve bit-identical results.  The executable cache is keyed on
        (op, bucket, batch, solver numerics, executor), none of which
        mention the policy or the scheduling facts T/S, so buckets both
        plans agree on keep their compiled executables across *any* swap
        that preserves bucketing and flush size.  Executables the new plan
        *does* need fresh are pre-warmed before the swap (from the queued
        requests' shapes, plus ``warm_profile`` when given), so the first
        post-swap flush dispatches warm instead of stalling on XLA.
        Returns the switch record also appended to
        ``stats.plan_switches``.
        """
        if plan.max_inflight < 1:
            raise ValueError(
                f"plan.max_inflight must be >= 1, got {plan.max_inflight}")
        if plan.max_batch < 1:
            raise ValueError(
                f"plan.max_batch must be >= 1, got {plan.max_batch}")
        # materialize the plan's policy and executor *before* touching any
        # server state: a plan that fails here (bad pow2_cap, bogus mesh
        # spec) must leave the server -- and every queued ticket -- intact
        t_swap = self.clock()
        new_policy = plan.policy()
        new_executor = plan.build_executor()
        old_plan = self.describe_plan()
        # pre-warm the incoming plan's executables while the old plan is
        # still serving: every shape we know about (queued requests, plus
        # the traffic profile when given) compiles -- or loads from the
        # disk tier -- under the new plan's facts, before any ticket is
        # re-bucketed onto them
        new_config = dataclasses.replace(self.config, T=new_policy.T,
                                         S=plan.max_batch)
        plan_backend = getattr(plan, "backend", "keep")
        if plan_backend != "keep":
            new_config = dataclasses.replace(new_config,
                                             backend=plan_backend)
        warm_shapes = sorted({(e.ticket.op, e.matrix.shape)
                              for q in self._queues.values() for e in q})
        if warm_profile is not None:
            warm_shapes += self._profile_shapes(warm_profile)
        prewarmed = {"memory": 0, "disk": 0, "compile": 0}
        for op, bucket, batch, backend in self._enumerate_keys(
                warm_shapes, new_policy, new_executor, new_config,
                plan.max_batch):
            _, source = self._executable_for(op, bucket, batch, backend,
                                             new_config, new_executor)
            prewarmed[source] += 1
        self._inflight.retire_to_depth(0)
        queued = sorted((e for q in self._queues.values() for e in q),
                        key=lambda e: e.rid)
        self._queues = {}
        self.policy = new_policy
        self.max_batch = plan.max_batch
        self.max_inflight = plan.max_inflight
        self.executor = new_executor
        self.config = new_config
        self._exec_label = self.executor.describe()
        switch = {"from": old_plan, "to": self.describe_plan(),
                  "requeued": len(queued), "prewarmed": prewarmed}
        now = self.clock()
        self.stats.record_plan_switch(switch, now=now)
        if self.obs is not None:
            if getattr(self.executor, "obs", None) is None:
                self.executor.obs = self.obs
            self._m_swaps.inc(now=now)
            self.obs.tracer.complete(
                "plan_swap", ts=t_swap, end=now, cat="control",
                track="control", requeued=len(queued),
                executor=self._exec_label, max_batch=self.max_batch,
                max_inflight=self.max_inflight, T=self.policy.T)
        for e in queued:
            bucket = self.policy.bucket_shape(e.matrix.shape)
            e.ticket.bucket = bucket
            self._enqueue((e.ticket.op, bucket, e.ticket.sweeps), e, now)
        return switch

    # -- dispatch stage -----------------------------------------------------
    def _dispatch_key(self, key: Tuple) -> int:
        """Stack, pad, compile, launch one bucket queue -- non-blocking.

        The flush joins the in-flight queue; back-pressure then retires
        whatever already completed (free) and, if the pipeline is over
        ``max_inflight``, blocks on the oldest flush until the cap holds.
        With ``max_inflight=1`` the just-dispatched flush itself retires
        here -- exactly the old synchronous flush.  Returns the number of
        requests retired while enforcing the cap.
        """
        op, bucket, sweeps = key
        queue = self._queues.pop(key, [])
        if not queue:
            return 0
        t_dispatch = self.clock()
        batch, n_active = stack_requests([e.matrix for e in queue], bucket)
        b = len(queue)
        bp = max(self.max_batch if self.pad_batches else b, b)
        # the executor may demand a larger batch (a mesh pads up to the
        # next data-axis multiple so every shard gets an identical slab)
        bp = self.executor.round_batch(bp)
        if bp > b:  # inert filler: zero matrices with zero live coordinates
            batch = np.concatenate(
                [batch, np.zeros((bp - b, *bucket), batch.dtype)])
            n_active = np.concatenate(
                [n_active, np.zeros((n_active.shape[0], bp - b), np.int32)],
                axis=1)
        backend = self.backend_for(op, bucket)
        obs = self.obs
        if obs is not None:
            # reserve the flush span's id now so the compile/launch spans
            # recorded below can name it as their parent; the span itself
            # is recorded at retire time, when its end is known
            flush_span = obs.tracer.new_id()
            t0 = self.clock()
            fn, source = self._executable(op, bucket, bp, backend, sweeps)
            if source != "memory":
                # the executable *build*: a jit-wrapper construction on the
                # memory-only path (XLA itself compiles lazily inside the
                # first launch, landing in the dispatch span), a full AOT
                # compile when the disk tier is armed, or a deserialize on
                # a disk hit ("aot_load")
                obs.tracer.complete(
                    "compile" if source == "compile" else "aot_load",
                    ts=t0, end=self.clock(), cat="compile",
                    track="flushes", parent=flush_span, op=op,
                    bucket=list(bucket), batch=bp, backend=str(backend))
        else:
            fn, source = self._executable(op, bucket, bp, backend, sweeps)
        hit = source != "compile"
        flush = self.executor.submit(fn, batch, n_active)
        flush.seq = next(self._seq)
        flush.key = key
        flush.entries = tuple(queue)
        flush.t_dispatch = t_dispatch
        flush.t_launched = self.clock()
        flush.backend = backend
        flush.batch_size = b
        flush.padded_batch = bp
        flush.cache_hit = hit
        flush._retire_cb = self._retire
        self._inflight.push(flush)
        flush.inflight_depth = self._inflight.depth
        for e in queue:
            e.ticket._flush = flush
        self.stats.record_dispatch(self._inflight.depth, t_dispatch)
        if obs is not None:
            flush.span_id = flush_span
            self._m_flushes.labels(
                op, bucket, backend, self._exec_label,
                "hit" if hit else "miss").inc(now=t_dispatch)
            self._m_batch.labels(op, bucket).observe(b, now=t_dispatch)
            self._m_depth.set(self._inflight.depth, now=t_dispatch)
            self._m_queued.set(self.pending(), now=t_dispatch)
        # back-pressure: block on the oldest flush until the cap holds.
        # Deliberately *not* an opportunistic ready-sweep -- retirement
        # points stay deterministic (cap, poll, drain, ticket) no matter
        # how fast the device happens to be, which is what keeps the
        # injected-clock test story exact.
        return self._inflight.retire_to_depth(self.max_inflight - 1)

    # -- retire stage -------------------------------------------------------
    def _retire(self, flush: InFlightFlush) -> int:
        """Force one flush's device batch home and fulfil its tickets.

        Idempotent (a ticket may race poll/drain to the same flush).  The
        gap between ``t_dispatch`` and the moment we block here is host
        work that overlapped device execution -- the quantity the pipeline
        exists to maximize; ``stats`` accounts it per flush.
        """
        if flush.retired:
            return 0
        op, bucket, sweeps = flush.key
        t_wait = self.clock()
        out = flush.result()
        t_retire = self.clock()
        flush.retired = True
        self._inflight.remove(flush)
        self.stats.record_flush(
            flush.cache_hit, t_dispatch=flush.t_dispatch,
            t_launched=flush.t_launched, t_wait=t_wait, t_retire=t_retire,
            batch_size=flush.batch_size,
            inflight_depth=flush.inflight_depth,
            op=op, bucket=bucket, padded_batch=flush.padded_batch)
        records = []
        for i, e in enumerate(flush.entries):
            rec = RequestRecord(
                rid=e.rid, op=op, shape=e.matrix.shape, bucket=bucket,
                batch_size=flush.batch_size, cache_hit=flush.cache_hit,
                t_submit=e.t_submit, t_done=t_retire,
                queue_s=flush.t_dispatch - e.t_submit,
                padding_waste=padding_waste(e.matrix.shape, bucket),
                backend=flush.backend, n_shards=flush.n_shards,
                t_dispatch=flush.t_dispatch,
                inflight_depth=flush.inflight_depth,
                deadline=e.flush_by, sweeps=sweeps)
            e.ticket._fulfil(self._unpack(op, out, i, e.matrix.shape), rec)
            self.stats.record_request(rec)
            records.append(rec)
        if self.obs is not None:
            self._record_obs(flush, records, t_wait, t_retire)
        return len(flush.entries)

    def _record_obs(self, flush: InFlightFlush, records: List[RequestRecord],
                    t_wait: float, t_retire: float) -> None:
        """Emit the retired flush's spans and metrics (obs attached only).

        One flush span (dispatch -> retire-complete) with dispatch /
        inflight / wait / retire children, then one request span per
        fulfilled ticket, parented to the flush span -- the link that ties
        a request's latency to the microbatch that actually served it.
        """
        obs = self.obs
        tr = obs.tracer
        op, bucket, _sweeps = flush.key
        backend, exec_label = flush.backend, self._exec_label
        t_end = self.clock()
        fid = flush.span_id if flush.span_id is not None else tr.new_id()
        bucket_l = list(bucket)
        tr.complete(
            f"flush:{op}", ts=flush.t_dispatch, end=t_end, cat="flush",
            track="flushes", id=fid, op=op, bucket=bucket_l,
            batch=flush.batch_size, padded_batch=flush.padded_batch,
            backend=str(backend), executor=exec_label,
            cache_hit=flush.cache_hit, n_shards=flush.n_shards,
            inflight_depth=flush.inflight_depth, seq=flush.seq)
        tr.complete("dispatch", ts=flush.t_dispatch, end=flush.t_launched,
                    cat="flush", track="flushes", parent=fid,
                    cache_hit=flush.cache_hit)
        tr.complete("inflight", ts=flush.t_launched, end=t_wait,
                    cat="flush", track="flushes", parent=fid)
        tr.complete("wait", ts=t_wait, end=t_retire, cat="flush",
                    track="flushes", parent=fid)
        tr.complete("retire", ts=t_retire, end=t_end, cat="flush",
                    track="flushes", parent=fid,
                    requests=len(records))
        labels = (op, bucket, backend, exec_label)
        self._m_wait.labels(*labels).observe(t_retire - t_wait, now=t_retire)
        lat = self._m_latency.labels(*labels)
        qwait = self._m_queue.labels(*labels)
        slo = obs.slo
        for rec in records:
            tr.complete(
                f"request:{op}", ts=rec.t_submit, end=t_end, cat="request",
                track="requests", parent=fid, rid=rec.rid, op=op,
                bucket=bucket_l, shape=list(rec.shape),
                backend=str(backend))
            lat.observe(t_end - rec.t_submit, now=t_end)
            qwait.observe(rec.queue_s, now=t_end)
            if slo is not None:
                slo.observe(op=op, latency_s=t_end - rec.t_submit,
                            t_done=t_end, t_submit=rec.t_submit,
                            deadline=rec.deadline)

    def _queue_depth(self, op: str, bucket: Tuple[int, ...],
                     sweeps: int) -> int:
        return len(self._queues.get((op, bucket, sweeps), ()))

    def backend_for(self, op: str, bucket: Tuple[int, ...]) -> Optional[str]:
        """The kernel backend this (op, bucket) routes to."""
        if self.backend_router is not None:
            return self.backend_router(op, bucket)
        return self.config.backend

    def _executable(self, op: str, bucket: Tuple[int, ...], batch: int,
                    backend: Optional[str],
                    sweeps: Optional[int] = None) -> Tuple[Callable, str]:
        return self._executable_for(op, bucket, batch, backend,
                                    self.config, self.executor,
                                    sweeps=sweeps)

    def _executable_for(self, op: str, bucket: Tuple[int, ...], batch: int,
                        backend: Optional[str], config: PCAConfig,
                        executor: LocalExecutor,
                        sweeps: Optional[int] = None) -> Tuple[Callable, str]:
        """Two-tier executable lookup under explicit plan facts.

        Returns (fn, source) with source one of ``"memory"`` (steady
        state), ``"disk"`` (AOT deserialize, promoted into memory) or
        ``"compile"``.  The key is ``SolverKey``-based -- the numerics
        subset the compiled solver actually depends on -- so configs that
        differ only in scheduling facts (T, S) share one executable.  With
        a disk tier armed, misses compile ahead-of-time (the result is
        serializable); without one, the executor's shared jit wrapper.
        The explicit (config, executor) arguments let ``apply_plan``
        pre-warm an *incoming* plan's executables before the swap.
        """
        cfg = dataclasses.replace(
            config, backend=backend,
            sweeps=config.sweeps if sweeps is None else sweeps)
        key = (op, bucket, batch, SolverKey.from_config(cfg),
               executor.cache_token())
        fn, source = self._cache.lookup(key)
        if fn is None:
            source = "compile"
            if self._cache.disk is not None:
                fn = executor.aot_compile(op, cfg, bucket, batch)
                self._cache.store(key, fn, persist=True)
            else:
                fn = executor.compile(op, cfg, bucket, batch)
                self._cache.store(key, fn)
        if self.obs is not None:
            if self._cache.disk is not None and source != "memory":
                self._m_disk.labels(
                    "hit" if source == "disk" else "miss").inc()
            self._m_exec_cached.set(len(self._cache))
        return fn, source

    # -- warmup / persistent tier -------------------------------------------
    @staticmethod
    def _profile_shapes(profile) -> List[Tuple[str, Tuple[int, ...], int]]:
        """(op, shape, count) rows of a ``TrafficProfile`` (anything with
        ``shape_counts``) or of a bare iterable of (op, shape[, n]);
        rows without a count carry weight 1."""
        rows = getattr(profile, "shape_counts", profile)
        return [(row[0], tuple(row[1]),
                 int(row[2]) if len(row) > 2 else 1) for row in rows]

    def _enumerate_keys(self, shapes, policy, executor, config,
                        max_batch) -> List[Tuple]:
        """Distinct (op, bucket, batch, backend) executables the given
        (op, shape[, count]) rows imply under the given plan facts.  The
        batch is the plan's padded flush size -- the one executable
        steady-state ``pad_batches`` traffic dispatches.

        Keys come back in descending traffic weight (sum of the counts of
        the shapes that bucket onto them), ties broken by first
        appearance: warmup compiles the executables the profile says will
        be hit most *first*, so an interrupted or still-running warmup has
        already armed the highest-traffic (i.e. SLO-critical) paths."""
        weight, order = {}, {}
        batch = executor.round_batch(max_batch)
        for row in shapes:
            op, shape = row[0], row[1]
            n = int(row[2]) if len(row) > 2 else 1
            bucket = policy.bucket_shape(tuple(shape))
            backend = (self.backend_router(op, bucket)
                       if self.backend_router is not None
                       else config.backend)
            k = (op, bucket, batch, backend)
            if k not in weight:
                weight[k] = 0
                order[k] = len(order)
            weight[k] += n
        return sorted(weight, key=lambda k: (-weight[k], order[k]))

    def warmup_keys(self, profile) -> List[Tuple]:
        """The distinct (op, bucket, batch, backend) executables
        ``profile`` implies under the plan currently in force, in
        descending traffic weight (see ``_enumerate_keys``)."""
        return self._enumerate_keys(self._profile_shapes(profile),
                                    self.policy, self.executor,
                                    self.config, self.max_batch)

    def warmup(self, profile) -> Dict:
        """Pre-build every executable ``profile`` implies.

        Each key resolves through the same two-tier path a live flush
        uses: memory hit (already warm), disk hit (AOT deserialize -- the
        fast path this method exists to arm), or compile (which, with a
        disk tier armed, also serializes the executable for the *next*
        replica).  Returns a summary dict; with obs attached the pass is
        traced as one ``warmup`` span with per-source counters in the
        metric registry.
        """
        t0 = self.clock()
        keys = self.warmup_keys(profile)
        counts = {"memory": 0, "disk": 0, "compile": 0}
        for op, bucket, batch, backend in keys:
            _, source = self._executable(op, bucket, batch, backend)
            counts[source] += 1
        now = self.clock()
        doc = {"executables": len(keys), "seconds": now - t0, **counts}
        if self.obs is not None:
            for source, n in counts.items():
                if n:
                    self._m_warm.labels(source).inc(n, now=now)
            self.obs.tracer.complete(
                "warmup", ts=t0, end=now, cat="control", track="control",
                executables=len(keys), **counts)
        return doc

    def cache_summary(self) -> Dict:
        """Both cache tiers' counters, JSON-able (see
        ``serving.cache.ExecutableCache.summary``)."""
        return self._cache.summary()

    @staticmethod
    def _unpack(op: str, out, i: int, shape: Tuple[int, ...]):
        if op == "eigh":
            n = shape[0]
            return ServedEigh(
                eigenvalues=np.asarray(out.eigenvalues[i, :n]),
                eigenvectors=np.asarray(out.eigenvectors[i, :n, :n]),
                off_norm=float(out.off_norm[i]))
        if op == "svd":
            m, n = shape
            return ServedSVD(
                U=np.asarray(out.U[i, :m, :n]),
                S=np.asarray(out.S[i, :n]),
                Vt=np.asarray(out.Vt[i, :n, :n]))
        d = shape[1]
        return ServedPCA(
            components=np.asarray(out.components[i, :d, :d]),
            eigenvalues=np.asarray(out.eigenvalues[i, :d]),
            mean=np.asarray(out.mean[i, :d]),
            scale=np.asarray(out.scale[i, :d]),
            evcr=np.asarray(out.evcr[i, :d]),
            cvcr=np.asarray(out.cvcr[i, :d]),
            off_norm=float(out.off_norm[i]))
