"""In-flight flushes: the pipeline stage between dispatch and retire.

MANOJAVAM's throughput hinges on keeping the S systolic arrays busy while
the memory hierarchy streams the next block in -- the paper's
block-streaming MM path exists to hide data movement behind compute.  The
serving engine mirrors that with a three-stage software pipeline:

  dispatch   stack / pad / compile / launch.  Non-blocking: JAX async
             dispatch returns device futures the moment the computation is
             enqueued, so the host immediately goes back to batching.
  in-flight  a bounded, dispatch-ordered queue of ``InFlightFlush``
             handles.  ``ready()`` is the completion detector (no host
             block); the bound (``PCAServer(max_inflight=...)``) is the
             back-pressure valve that keeps memory and queueing honest.
  retire     force one flush's results to host (a single gather), unpack
             them into tickets, record telemetry.

``InFlightFlush`` is created by an executor (``sharded.LocalExecutor
.submit`` / ``MeshExecutor.submit``) around the raw device output tree;
the engine then annotates it with its bookkeeping (which requests rode the
flush, dispatch timestamp, cache/backend/shard facts) and links
``retire()`` back to its own retire stage, so a ``Ticket`` can force
exactly its own flush home without draining the whole server.

Retirement is *ordered*: the queue always offers flushes oldest-first
(dispatch order), so blocking back-pressure drains deterministically, while
``retire_ready`` lets later flushes that finished early retire out of
dispatch order -- each flush only fulfils its own tickets, so out-of-order
completion is safe by construction.

With ``max_inflight=1`` the pipeline degrades exactly to the synchronous
flush the engine had before this stage existed: every dispatch is
immediately followed by the blocking retirement of the flush it launched.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np
import jax


def _leaf_ready(leaf) -> bool:
    """Non-blocking per-leaf completion probe (True when unknowable)."""
    probe = getattr(leaf, "is_ready", None)
    return bool(probe()) if probe is not None else True


class InFlightFlush:
    """Handle for one dispatched microbatch awaiting retirement.

    Executors construct it around the just-launched device output tree;
    the engine attaches its bookkeeping at dispatch time.  The device
    buffers are gathered to host exactly once (``result``), then released.
    """

    __slots__ = ("seq", "key", "entries", "t_dispatch", "t_launched",
                 "backend", "batch_size", "padded_batch", "cache_hit",
                 "inflight_depth", "n_shards", "retired", "span_id",
                 "_out", "_host", "_retire_cb")

    def __init__(self, out, n_shards: int = 1):
        self._out = out            # device result tree (async futures)
        self._host = None          # host copy, gathered once on demand
        self.n_shards = n_shards
        self.retired = False
        # engine bookkeeping, attached by PCAServer at dispatch time
        self.seq = -1
        self.key: Optional[Tuple] = None
        self.entries: Tuple = ()
        self.t_dispatch = 0.0      # dispatch stage began (pre-stack)
        self.t_launched = 0.0      # executor.submit returned (host free)
        self.backend: Optional[str] = None
        self.batch_size = 0
        self.padded_batch = 0      # device batch after padding/rounding
        self.cache_hit = False
        self.inflight_depth = 1
        self.span_id: Optional[int] = None  # reserved flush-span id (obs)
        self._retire_cb: Optional[Callable] = None

    def ready(self) -> bool:
        """Completion detection without blocking the host."""
        if self.retired or self._host is not None:
            return True
        return all(_leaf_ready(leaf) for leaf in jax.tree.leaves(self._out))

    def block_until_ready(self) -> "InFlightFlush":
        """Block until the device batch finished (results stay on device)."""
        if not self.retired and self._host is None:
            jax.block_until_ready(self._out)
        return self

    def result(self):
        """The flush's results as one host tree (blocks until complete).

        The whole tree is gathered in a single transfer -- per-request
        slicing happens on the host copy (slicing a device array per
        ticket is O(batch) dispatches, and on a sharded array each one is
        a cross-device gather; see ``sharded.LocalExecutor``).
        """
        if self._host is None:
            self._host = jax.tree.map(np.asarray, self._out)
            self._out = None       # release the device buffers
        return self._host

    def retire(self) -> int:
        """Force this flush through its engine's retire stage.

        Idempotent; returns the number of requests it fulfilled (0 when
        already retired).  Raises if the flush was never attached to an
        engine (executor-level use: call ``result()`` instead).
        """
        if self._retire_cb is None:
            raise RuntimeError(
                "flush is not attached to an engine; use result() for the "
                "raw device batch")
        return self._retire_cb(self)


class InFlightQueue:
    """Dispatch-ordered set of in-flight flushes (the retire stage inbox).

    The engine owns the bound (``max_inflight``); the queue owns ordering
    and the two retirement sweeps: ``retire_ready`` (free -- whatever the
    device already finished, oldest-first) and ``retire_to_depth``
    (blocking back-pressure -- oldest-first until the cap holds).
    """

    def __init__(self):
        self._flushes: List[InFlightFlush] = []

    def __len__(self) -> int:
        return len(self._flushes)

    def __iter__(self):
        return iter(list(self._flushes))

    @property
    def depth(self) -> int:
        return len(self._flushes)

    def requests(self) -> int:
        """Requests riding the currently in-flight flushes."""
        return sum(len(f.entries) for f in self._flushes)

    def push(self, flush: InFlightFlush) -> None:
        self._flushes.append(flush)

    def remove(self, flush: InFlightFlush) -> None:
        self._flushes.remove(flush)

    def oldest(self) -> Optional[InFlightFlush]:
        return self._flushes[0] if self._flushes else None

    def retire_ready(self) -> int:
        """Retire every already-completed flush (non-blocking sweep).

        Oldest-first, but a young finished flush does not wait for an old
        unfinished one -- that is the out-of-order half of the pipeline.
        Returns the number of requests fulfilled.
        """
        done = 0
        for flush in list(self._flushes):
            if flush.ready():
                done += flush.retire()
        return done

    def retire_to_depth(self, depth: int) -> int:
        """Blocking back-pressure: retire oldest-first until at most
        ``depth`` flushes remain in flight.  ``depth=0`` drains the stage.
        Returns the number of requests fulfilled."""
        done = 0
        while len(self._flushes) > depth:
            done += self._flushes[0].retire()
        return done
