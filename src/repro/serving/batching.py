"""Shape bucketing and padding: the software Matrix Padding Unit.

The hardware MPU (paper Sec. VI) zero-pads any input up to the next multiple
of the tile size T so a fixed (T, S) fabric can consume "datasets of any
input dimension".  In the serving engine the same trick makes *heterogeneous
traffic batchable*: every incoming matrix is padded up to a T-multiple
bucket, and up to S same-bucket requests stack into one device batch that a
single compiled executable consumes.  Zero padding is exact for the Jacobi
solvers -- see ``core.jacobi._null_pivot_guard`` -- so the bucket never
perturbs the embedded problem.

Two bucket policies:

  * ``"tile"`` -- round each dim up to the next multiple of T.  Minimal
    padding waste, but heterogeneous traffic spreads across many buckets
    (fewer batching opportunities, more executables).
  * ``"pow2"`` -- round the *tile count* up to the next power of two
    (bucket edges T, 2T, 4T, 8T, ...).  Geometric bucketing: more padding
    waste per request, but O(log) distinct buckets, so mixed traffic
    coalesces into full batches and the executable cache stays tiny.

``pow2_cap`` bounds the geometric growth: bucket edges run T, 2T, 4T, ...
up to the cap, and any dimension whose power-of-two bucket would overshoot
it falls back to linear tile rounding.  Geometric padding waste compounds
with the bucket edge (a dim just past cap/2 pays ~2x area), so capping the
doubling where traffic is sparse is one of the knobs the serving-plan
autotuner (``serving.autotune``) searches over.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

POLICIES = ("tile", "pow2")


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    T: int = 16            # tile edge (paper T); bucket dims are multiples
    mode: str = "tile"     # "tile" | "pow2"
    pow2_cap: Optional[int] = None  # pow2 mode: largest geometric bucket
                                    # edge; beyond it, linear tile rounding

    def __post_init__(self):
        if self.mode not in POLICIES:
            raise ValueError(f"unknown bucket mode {self.mode!r}")
        if self.T < 1:
            raise ValueError("bucket tile size must be >= 1")
        if self.pow2_cap is not None:
            if self.mode != "pow2":
                raise ValueError("pow2_cap only applies to the pow2 mode")
            if self.pow2_cap < self.T or self.pow2_cap % self.T:
                raise ValueError(
                    f"pow2_cap must be a multiple of T={self.T} "
                    f"(got {self.pow2_cap})")

    def bucket_dim(self, n: int) -> int:
        """Smallest bucket edge that holds a dimension of size n."""
        if n < 1:
            raise ValueError("matrix dimensions must be >= 1")
        tiles = math.ceil(n / self.T)
        if self.mode == "pow2":
            p2 = 1 << (tiles - 1).bit_length()
            if self.pow2_cap is None or p2 * self.T <= self.pow2_cap:
                tiles = p2
        return tiles * self.T

    def bucket_shape(self, shape: Sequence[int]) -> Tuple[int, ...]:
        return tuple(self.bucket_dim(int(d)) for d in shape)


def pad_to_bucket(a: np.ndarray, bucket: Sequence[int]) -> np.ndarray:
    """Zero-pad a matrix into its bucket (the MPU's zero fill)."""
    a = np.asarray(a)
    if len(bucket) != a.ndim:
        raise ValueError(f"bucket rank {len(bucket)} != matrix rank {a.ndim}")
    pads = []
    for d, b in zip(a.shape, bucket):
        if d > b:
            raise ValueError(f"matrix dim {d} exceeds bucket dim {b}")
        pads.append((0, b - d))
    if any(p for _, p in pads):
        a = np.pad(a, pads)
    return a


def stack_requests(mats: Sequence[np.ndarray], bucket: Sequence[int]):
    """Stack same-bucket matrices into one device batch.

    Returns ``(batch, n_active)`` where ``batch`` is (B, *bucket) and
    ``n_active`` is a (rank, B) int32 array of true sizes per axis --
    the masks the batched solvers use to keep padded coordinates inert.
    """
    batch = np.stack([pad_to_bucket(m, bucket) for m in mats])
    n_active = np.asarray([[m.shape[ax] for m in mats]
                           for ax in range(len(bucket))], dtype=np.int32)
    return batch, n_active


def padding_waste(shape: Sequence[int], bucket: Sequence[int]) -> float:
    """Fraction of the bucket area occupied by padding (0 = exact fit)."""
    true = float(np.prod([int(d) for d in shape]))
    padded = float(np.prod([int(b) for b in bucket]))
    return 1.0 - true / padded
