"""Traffic-driven serving-plan autotuning: pick (policy, T, pow2 cap,
max_batch, max_inflight, executor) from observed traffic.

MANOJAVAM's two-tier cache and mode-aware memory policies adapt the fabric
to the access patterns of covariance vs rotation work; the software MPU
adapts the same way, but to *traffic*: the right bucket policy, tile size,
flush size and pipeline depth depend on the shape mix and arrival pattern
the server actually sees, not on a hand-picked tuple.  This module closes
the seam PR 4 left open (``ServingStats.flush_records`` +
``inflight_depths``) with the classic autotuned-search loop (TVM/Ansor
style, applied to the Jacobi/matmul serving fabric):

  profile    ``TrafficProfile.from_stats`` condenses live telemetry into a
             JSON-round-trippable artifact: per-(op, shape) histograms,
             arrival rate, padding-waste and host/device-overlap
             aggregates, and the calibration signals (dispatch cost split
             by cache hit/miss, device seconds per unit bucket-work).
             Capture once in production, replay forever in CI.
  search     ``autotune`` scores every ``ServingPlan`` in a small discrete
             grid with an analytical ``CostModel`` (bucket area x flush
             count, recompile amortization charged per executable the plan
             needs, pipeline occupancy derived from the plan's depth and
             the profile's measured ``overlap_frac``), optionally
             refining the analytic top-K by *measuring*: ``replay``
             regenerates the profile's traffic deterministically and
             times it against a live ``PCAServer`` built from the plan.
  apply      ``PCAServer.apply_plan`` hot-swaps the winner between
             flushes: in-flight work retires first, queued tickets are
             re-bucketed in place, and the switch lands in
             ``stats.plan_switches``.

The cost model is deliberately simple -- every term is a quantity the
telemetry already measures -- because its job is *ranking* a few dozen
plans, not predicting wall time: the measured refinement exists precisely
so close calls are settled by the hardware.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pca import PCAConfig
from .batching import BucketPolicy, POLICIES
from .sharded import LocalExecutor, mesh_executor
from .stats import ServingStats

TRACE_KINDS = ("uniform", "bimodal", "heavy")


def solve_work(op: str, bucket: Sequence[int]) -> float:
    """Bucket-work units of one problem: the O(.) the Jacobi datapath does.

    eigh on an (n, n) bucket is n^3-ish (sweeps x rotations x row/col
    updates); svd/pca on (m, n) add the m n^2 Gram/standardize streaming
    pass in front of the n^3 eigensolve.  Constant factors cancel in
    ranking; the calibrated ``CostModel.device_work_per_s`` absorbs them
    when real flush telemetry is available.
    """
    if len(bucket) == 1 or op == "eigh":
        n = float(bucket[-1])
        return n * n * n
    m, n = float(bucket[0]), float(bucket[-1])
    return m * n * n + n * n * n


def _parse_bucket(label: str) -> Optional[Tuple[int, ...]]:
    """Invert ``repro.obs.metrics.fmt_label`` for bucket labels:
    ``"24x16" -> (24, 16)``.  Non-shape labels return None."""
    try:
        dims = tuple(int(d) for d in str(label).split("x"))
    except ValueError:
        return None
    return dims if dims and all(d > 0 for d in dims) else None


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """One point of the serving-policy space ``PCAServer`` can run under.

    ``mesh`` is the executor choice in ``sharded.mesh_executor`` spelling:
    ``"none"`` (single device), ``"auto"`` (every visible device) or an
    integer-string N.  ``backend`` is the kernel-backend axis: the
    sentinel ``"keep"`` (default) leaves the server's ``config.backend``
    untouched -- every pre-existing plan JSON round-trips to it -- while
    any other value (a registry backend name, or ``None`` for plain XLA)
    overrides the config when the plan is applied or a server is built
    for it.  The default instance is exactly the ``launch.serve_pca``
    CLI's defaults -- the hand-picked tuple the autotuner exists to beat.
    """
    mode: str = "tile"
    T: int = 16
    pow2_cap: Optional[int] = None
    max_batch: int = 4
    max_inflight: int = 1
    mesh: str = "none"
    backend: Optional[str] = "keep"

    def policy(self) -> BucketPolicy:
        return BucketPolicy(T=self.T, mode=self.mode,
                            pow2_cap=self.pow2_cap)

    def build_executor(self) -> LocalExecutor:
        return mesh_executor(self.mesh)

    def n_shards(self) -> int:
        """Data-axis shards the plan's executor would spread a flush over
        (without instantiating a mesh -- cost scoring must stay cheap)."""
        if self.mesh in (None, "none", "local"):
            return 1
        import jax
        n = (jax.device_count() if self.mesh == "auto"
             else min(int(self.mesh), jax.device_count()))
        return max(n, 1)

    def describe(self) -> str:
        cap = f"<=cap{self.pow2_cap}" if self.pow2_cap else ""
        be = "" if self.backend == "keep" else f" backend={self.backend}"
        return (f"{self.mode}{cap}(T={self.T}) S={self.max_batch} "
                f"inflight={self.max_inflight} mesh={self.mesh}{be}")

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, doc: Dict) -> "ServingPlan":
        return cls(**{f.name: doc[f.name]
                      for f in dataclasses.fields(cls) if f.name in doc})


def plan_grid(modes: Sequence[str] = POLICIES,
              tiles: Sequence[int] = (8, 16, 32),
              pow2_caps: Sequence[Optional[int]] = (None,),
              batches: Sequence[int] = (4, 8, 16, 32),
              inflights: Sequence[int] = (1, 2, 4),
              meshes: Sequence[str] = ("none",),
              backends: Sequence[Optional[str]] = ("keep",)
              ) -> List[ServingPlan]:
    """The small discrete search grid (exhaustive scoring is cheap).

    pow2 caps that are not a multiple of a tile size are skipped for that
    tile rather than raising, so one cap list can serve mixed tile lists.
    ``meshes`` and ``backends`` default to single-element axes (the
    grid stays scheduling-only unless a caller -- the serving controller
    -- grows them); the analytic cost model cannot separate backends, so
    a widened backend axis only pays off under measured bandit rungs.
    """
    plans = []
    for mode in modes:
        caps = pow2_caps if mode == "pow2" else (None,)
        for T in tiles:
            for cap in caps:
                if cap is not None and (cap < T or cap % T):
                    continue
                for S in batches:
                    for depth in inflights:
                        for mesh in meshes:
                            for backend in backends:
                                plans.append(ServingPlan(
                                    mode=mode, T=T, pow2_cap=cap,
                                    max_batch=S, max_inflight=depth,
                                    mesh=mesh, backend=backend))
    return plans


# ---------------------------------------------------------------------------
# the profile
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrafficProfile:
    """What the server observed, condensed for scoring and replay.

    ``shape_counts`` is the per-op shape histogram -- the replayable part.
    The aggregates are the cost-model calibration signals; all of them are
    exact zeros (never NaN) when the capture window saw no traffic, so a
    profile of an idle server is well-defined (see
    ``ServingStats.summary``'s same contract).
    """
    shape_counts: Tuple[Tuple[str, Tuple[int, ...], int], ...]
    requests: int = 0
    duration_s: float = 0.0
    arrival_rate: float = 0.0        # requests/s over the capture span
    mean_padding_waste: float = 0.0  # under the *captured* plan's buckets
    flushes: int = 0
    mean_flush_batch: float = 0.0    # live requests per flush
    mean_dispatch_hit_s: float = 0.0   # host cost/flush, executable cached
    mean_dispatch_miss_s: float = 0.0  # host cost/flush incl. compilation
    host_s: float = 0.0              # total dispatch-stage host seconds
    device_s: float = 0.0            # total launch-to-retire seconds
    work_dispatched: float = 0.0     # padded problems x solve_work, summed
    overlap_frac: float = 0.0        # measured host/device overlap
    captured: Tuple[Tuple[str, object], ...] = ()  # plan it ran under

    @classmethod
    def from_stats(cls, stats: ServingStats,
                   captured: Optional[Dict] = None) -> "TrafficProfile":
        recs = list(stats.records)
        counts = collections.Counter(
            (r.op, tuple(int(d) for d in r.shape)) for r in recs)
        shape_counts = tuple(sorted(
            (op, shape, n) for (op, shape), n in counts.items()))
        span = (max(r.t_done for r in recs) - min(r.t_submit for r in recs)
                if recs else 0.0)
        fr = list(stats.flush_records)
        hit = [f.dispatch_s for f in fr if f.cache_hit]
        miss = [f.dispatch_s for f in fr if not f.cache_hit]
        overlap_s = float(sum(f.overlap_s for f in fr))
        inflight_s = overlap_s + float(sum(f.wait_s for f in fr))
        return cls(
            shape_counts=shape_counts,
            requests=len(recs),
            duration_s=float(span),
            arrival_rate=len(recs) / span if span > 0 else 0.0,
            mean_padding_waste=(float(np.mean(
                [r.padding_waste for r in recs])) if recs else 0.0),
            flushes=len(fr),
            mean_flush_batch=(float(np.mean([f.batch_size for f in fr]))
                              if fr else 0.0),
            mean_dispatch_hit_s=float(np.mean(hit)) if hit else 0.0,
            mean_dispatch_miss_s=float(np.mean(miss)) if miss else 0.0,
            host_s=float(sum(f.dispatch_s for f in fr)),
            device_s=inflight_s,
            work_dispatched=float(sum(
                f.padded_batch * solve_work(f.op, f.bucket)
                for f in fr if f.bucket)),
            overlap_frac=(overlap_s / inflight_s if inflight_s > 0 else 0.0),
            captured=tuple(sorted((captured or {}).items())),
        )

    @classmethod
    def from_registry(cls, registry, window_s: float,
                      now: Optional[float] = None,
                      carry: Optional["TrafficProfile"] = None,
                      decay: float = 0.5,
                      captured: Optional[Dict] = None) -> "TrafficProfile":
        """A sliding-window profile from live ``repro.obs.MetricRegistry``
        telemetry (the controller's re-profiling substrate).

        Reads the per-request ``serve_request_latency_seconds`` events of
        the trailing ``window_s`` via ``registry.series_events`` -- one
        event per fulfilled request, labeled (op, bucket) -- so the shape
        histogram is bucket-granular (the registry does not retain
        pre-bucketing shapes; ``from_stats`` does, and the controller
        prefers it when the server's ``ServingStats`` is reachable).

        Carry-forward: a windowed snapshot drops every op that saw zero
        events in the window, and a profile that went empty would make a
        controller swap to a degenerate plan tuned for nothing.  When
        ``carry`` (the previous window's profile) is given, ops absent
        from this window inherit their last non-empty histogram at
        ``decay`` weight; because the controller hands each emitted
        profile back as the next tick's ``carry``, a quiet op fades out
        geometrically (counts round to zero after ~log2(n) quiet windows)
        instead of vanishing the instant its traffic pauses.
        """
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        now = registry.clock() if now is None else now
        counts: Dict[Tuple[str, Tuple[int, ...]], int] = \
            collections.Counter()
        for labels, events in registry.series_events(
                "serve_request_latency_seconds", window_s, now):
            if not events:
                continue
            bucket = _parse_bucket(labels.get("bucket", ""))
            if bucket is None:
                continue
            counts[(labels.get("op", "eigh"), bucket)] += len(events)
        fresh_ops = {op for op, _ in counts}
        if carry is not None and decay > 0:
            for op, shape, n in carry.shape_counts:
                if op in fresh_ops:
                    continue
                kept = int(round(n * decay))
                if kept > 0:
                    counts[(op, tuple(int(d) for d in shape))] += kept
        shape_counts = tuple(sorted(
            (op, shape, n) for (op, shape), n in counts.items()))
        requests = sum(n for _, _, n in shape_counts)
        batch_events = [v for labels, events in registry.series_events(
            "serve_flush_batch_size", window_s, now) for _, v in events]
        return cls(
            shape_counts=shape_counts,
            requests=requests,
            duration_s=float(window_s),
            arrival_rate=requests / window_s,
            flushes=len(batch_events),
            mean_flush_batch=(float(np.mean(batch_events))
                              if batch_events else 0.0),
            captured=tuple(sorted((captured or {}).items())),
        )

    @classmethod
    def from_shapes(cls, shape_counts, **aggregates) -> "TrafficProfile":
        """A profile straight from an (op, shape, count) histogram -- for
        banners, tests and hand-written what-if scenarios."""
        norm = tuple(sorted((op, tuple(int(d) for d in shape), int(n))
                            for op, shape, n in shape_counts))
        return cls(shape_counts=norm,
                   requests=sum(n for _, _, n in norm), **aggregates)

    @property
    def captured_plan(self) -> Dict:
        return dict(self.captured)

    def warmup_shapes(self) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
        """The distinct (op, shape) pairs this profile implies -- what
        ``PCAServer.warmup``/``warmup_keys`` expands into concrete
        (op, bucket, batch, backend) executables under a live plan, and
        what ``serve_pca --warmup profile.json`` pre-builds before the
        first request lands."""
        seen, out = set(), []
        for op, shape, _n in self.shape_counts:
            if (op, shape) not in seen:
                seen.add((op, shape))
                out.append((op, shape))
        return tuple(out)

    # -- JSON round trip ----------------------------------------------------
    def to_json(self) -> str:
        doc = dataclasses.asdict(self)
        doc["shape_counts"] = [[op, list(shape), n]
                               for op, shape, n in self.shape_counts]
        doc["captured"] = self.captured_plan
        return json.dumps({"traffic_profile": 1, **doc}, indent=2,
                          sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "TrafficProfile":
        doc = json.loads(text)
        doc.pop("traffic_profile", None)
        doc["shape_counts"] = tuple(
            (op, tuple(int(d) for d in shape), int(n))
            for op, shape, n in doc["shape_counts"])
        doc["captured"] = tuple(sorted(doc.get("captured", {}).items()))
        return cls(**{f.name: doc[f.name]
                      for f in dataclasses.fields(cls) if f.name in doc})

    def save(self, path) -> None:
        pathlib.Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "TrafficProfile":
        return cls.from_json(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# synthetic traffic (deterministic generators for tests, CI and replay)
# ---------------------------------------------------------------------------

def trace_dims(kind: str, n: int, lo: int = 6, hi: int = 48,
               seed: int = 0) -> List[int]:
    """Deterministic dimension stream for a named traffic shape.

    uniform: flat over [lo, hi]; bimodal: a small-matrix mode near ``lo``
    and a large mode near ``hi`` (the heterogeneous mix where bucket
    policies differ most); heavy: Pareto-tailed around ``lo`` (most
    requests tiny, rare huge ones -- the regime where pow2 caps pay).
    """
    if kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {kind!r}; one of {TRACE_KINDS}")
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        dims = rng.integers(lo, hi + 1, size=n)
    elif kind == "bimodal":
        small = rng.normal(lo + 2, 1.5, size=n)
        large = rng.normal(hi - 4, 3.0, size=n)
        pick = rng.random(n) < 0.65      # small mode dominates
        dims = np.where(pick, small, large)
    else:  # heavy
        dims = lo + rng.pareto(1.5, size=n) * 3.0
    return [int(d) for d in np.clip(np.round(dims), lo, hi)]


def synthesize(op: str, shape: Sequence[int], rng) -> np.ndarray:
    """One request matrix for (op, shape): symmetric for eigh, tall data
    for svd/pca -- matching ``launch.serve_pca.mixed_traffic``."""
    if op == "eigh":
        n = int(shape[-1])
        a = rng.standard_normal((n, n)).astype(np.float32)
        return (a + a.T) / 2
    m, n = int(shape[0]), int(shape[1])
    return rng.standard_normal((m, n)).astype(np.float32)


def synthetic_trace(kind: str, n: int, op: str = "eigh", lo: int = 6,
                    hi: int = 48, seed: int = 0) -> List[np.ndarray]:
    """A deterministic heterogeneous request burst of a named shape."""
    rng = np.random.default_rng(seed + 1)
    mats = []
    for d in trace_dims(kind, n, lo=lo, hi=hi, seed=seed):
        shape = (d, d) if op == "eigh" else (4 * d, d)
        mats.append(synthesize(op, shape, rng))
    return mats


def request_sequence(profile: TrafficProfile,
                     seed: int = 0) -> List[Tuple[str, Tuple[int, ...]]]:
    """The profile's histogram expanded into a deterministic arrival order
    (a seeded shuffle -- histograms forget ordering, and a sorted replay
    would batch unrealistically well)."""
    reqs = [(op, shape) for op, shape, n in profile.shape_counts
            for _ in range(n)]
    order = np.random.default_rng(seed).permutation(len(reqs))
    return [reqs[i] for i in order]


# ---------------------------------------------------------------------------
# the cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostModel:
    """Analytical score of (plan, profile) -> estimated seconds to serve.

    Three terms, each a telemetry-calibratable quantity:

      device   bucket area (to the solve-work power) x padded batch x
               flush count / device rate -- the padding-waste and
               batching term: bigger buckets and emptier flushes cost.
      host     per-flush dispatch cost (stack/pad/launch/unpack), minus
               the fraction the plan's pipeline depth hides behind device
               execution.  Occupancy is ``1 - 1/max_inflight`` scaled by
               the efficiency the profile actually measured
               (``overlap_frac``) when it was captured under a pipelined
               plan -- a host that never reached its theoretical overlap
               will not magically reach it under the candidate either.
      compile  one charge per distinct executable the plan needs
               (op x bucket x padded-batch), amortized against the
               executable cache: steady-state traffic compiles once, so
               plans that shatter traffic across many buckets pay here.
    """
    device_work_per_s: float = 2.0e9
    host_s_per_flush: float = 1.0e-3
    host_s_per_request: float = 3.0e-5
    compile_s_per_executable: float = 0.25

    def request_service_s(self, op: str, bucket: Sequence[int],
                          batch: int = 1,
                          sweeps_frac: float = 1.0) -> float:
        """Predicted seconds to serve one request of (op, bucket).

        The admission-control primitive: device work for the padded
        problem (scaled by ``sweeps_frac`` -- the degrade path trades
        Jacobi sweeps for time) plus the per-request share of one flush's
        host cost.  ``batch`` amortizes the flush overhead the way the
        serving engine actually does.
        """
        batch = max(int(batch), 1)
        dev = solve_work(op, bucket) * max(sweeps_frac, 0.0) \
            / self.device_work_per_s
        host = self.host_s_per_flush / batch + self.host_s_per_request
        return dev + host

    @classmethod
    def calibrated(cls, profile: TrafficProfile) -> "CostModel":
        """Constants from the profile's own telemetry where available."""
        m = cls()
        if profile.mean_dispatch_hit_s > 0:
            m.host_s_per_flush = max(
                profile.mean_dispatch_hit_s
                - m.host_s_per_request * profile.mean_flush_batch, 1e-6)
        if profile.mean_dispatch_miss_s > profile.mean_dispatch_hit_s > 0:
            m.compile_s_per_executable = (profile.mean_dispatch_miss_s
                                          - profile.mean_dispatch_hit_s)
        if profile.work_dispatched > 0 and profile.device_s > 0:
            m.device_work_per_s = profile.work_dispatched / profile.device_s
        return m

    def occupancy(self, plan: ServingPlan,
                  profile: TrafficProfile) -> float:
        """Fraction of per-flush host cost the plan's pipeline hides."""
        if plan.max_inflight <= 1:
            return 0.0
        ceiling = 1.0 - 1.0 / plan.max_inflight
        captured = profile.captured_plan
        cap_depth = int(captured.get("max_inflight", 1) or 1)
        if cap_depth > 1 and profile.overlap_frac > 0:
            # the profile measured real overlap under a pipelined plan:
            # trust its efficiency relative to that plan's own ceiling
            eff = profile.overlap_frac / (1.0 - 1.0 / cap_depth)
            return ceiling * float(np.clip(eff, 0.1, 1.0))
        return ceiling

    def plan_cost(self, plan: ServingPlan,
                  profile: TrafficProfile) -> Dict[str, float]:
        """Score one plan against one profile (lower total_s is better)."""
        policy = plan.policy()
        shards = plan.n_shards()
        per_bucket: Dict[Tuple, int] = collections.Counter()
        waste_num = 0.0
        for op, shape, n in profile.shape_counts:
            bucket = policy.bucket_shape(shape)
            per_bucket[(op, bucket)] += n
            true = float(np.prod([int(d) for d in shape]))
            padded = float(np.prod(bucket))
            waste_num += n * (1.0 - true / padded)
        occupancy = self.occupancy(plan, profile)
        device_s = host_s = hidden_s = 0.0
        n_exec = 0
        padded_batch = int(math.ceil(plan.max_batch / shards)) * shards
        for (op, bucket), n in sorted(per_bucket.items()):
            flushes = math.ceil(n / plan.max_batch)
            dev_flush = (padded_batch / shards) * solve_work(op, bucket) \
                / self.device_work_per_s
            host_flush = (self.host_s_per_flush
                          + self.host_s_per_request * plan.max_batch)
            n_exec += 1
            device_s += flushes * dev_flush
            host_s += flushes * host_flush
            hidden_s += flushes * occupancy * min(host_flush, dev_flush)
        compile_s = n_exec * self.compile_s_per_executable
        # deadline term: when the profile measured an arrival rate, a plan
        # slower than the offered load queues unboundedly -- every second
        # of predicted service beyond the offered span is a second of
        # backlog at the end of the window, charged at face value so
        # plans that keep up dominate plans that almost keep up.
        overload_s = 0.0
        if profile.arrival_rate > 0 and profile.requests > 0:
            offered_span = profile.requests / profile.arrival_rate
            serve_s = device_s + host_s - hidden_s
            overload_s = max(0.0, serve_s - offered_span)
        total_s = max(device_s + host_s - hidden_s + compile_s
                      + overload_s, 1e-12)
        requests = max(profile.requests, 1)
        return {
            "total_s": total_s,
            "device_s": device_s,
            "host_s": host_s,
            "hidden_s": hidden_s,
            "compile_s": compile_s,
            "overload_s": overload_s,
            "n_buckets": float(len(per_bucket)),
            "n_executables": float(n_exec),
            "est_padding_waste": waste_num / requests,
            "est_requests_per_s": requests / total_s,
        }


# ---------------------------------------------------------------------------
# the search driver
# ---------------------------------------------------------------------------

def server_for_plan(plan: ServingPlan, config: Optional[PCAConfig] = None,
                    **kw) -> "PCAServer":
    """A fresh ``PCAServer`` configured exactly as the plan prescribes."""
    from . import engine
    cfg = dataclasses.replace(config or PCAConfig(),
                              T=plan.T, S=plan.max_batch)
    if getattr(plan, "backend", "keep") != "keep":
        cfg = dataclasses.replace(cfg, backend=plan.backend)
    kw.setdefault("max_delay_s", 10.0)
    with engine.spec_construction():
        return engine.PCAServer(
            cfg, policy=plan.policy(), max_batch=plan.max_batch,
            max_inflight=plan.max_inflight,
            executor=plan.build_executor(), **kw)


def replay(profile: TrafficProfile, plan: ServingPlan,
           config: Optional[PCAConfig] = None, seed: int = 0,
           passes: int = 2) -> Dict[str, float]:
    """Measure one plan on the profile's regenerated traffic.

    Deterministic end to end: the request sequence and matrix contents
    depend only on (profile, seed), so every candidate plan sees the
    byte-identical burst.  One warmup pass compiles the plan's buckets
    (steady-state serving runs on the executable cache; the cost model
    charges compilation separately), then best-of-``passes`` timing.
    """
    import time as _time

    reqs = request_sequence(profile, seed)
    rng = np.random.default_rng(seed)
    mats = [(op, synthesize(op, shape, rng)) for op, shape in reqs]
    srv = server_for_plan(plan, config)

    def one_pass():
        tickets = [srv.submit(m, op=op) for op, m in mats]
        srv.drain()
        return tickets

    one_pass()                       # warmup: compile every bucket
    wall, s = float("inf"), None
    for _ in range(max(passes, 1)):
        srv.stats.reset()
        t0 = _time.perf_counter()
        one_pass()
        elapsed = _time.perf_counter() - t0
        if elapsed < wall:
            # keep the telemetry of the pass whose wall time wins, so a
            # row's throughput and latency numbers come from the same run
            wall, s = elapsed, srv.stats.summary()
    return {
        "wall_s": wall,
        "requests_per_s": len(mats) / wall if wall > 0 else 0.0,
        "latency_p99_ms": s["latency_p99_ms"],
        "mean_padding_waste": s["mean_padding_waste"],
        "mean_batch": s["mean_batch"],
        "cache_hit_rate": s["cache_hit_rate"],
        "overlap_frac": s["overlap_frac"],
    }


@dataclasses.dataclass
class AutotuneResult:
    best: ServingPlan
    mode: str            # "analytic" | "measured" | "bandit[-analytic]"
    scored: List[Tuple[ServingPlan, Dict]]      # every plan, best first
    measured: List[Dict] = dataclasses.field(default_factory=list)
    model: Optional[CostModel] = None
    measured_evals: int = 0                     # replay calls spent
    grid_size: int = 0

    def to_json(self) -> Dict:
        return {
            "mode": self.mode,
            "best": self.best.to_json(),
            "best_describe": self.best.describe(),
            "grid_size": self.grid_size,
            "measured_evals": self.measured_evals,
            "analytic_top": [
                {"plan": p.to_json(), "total_s": c["total_s"],
                 "est_requests_per_s": c["est_requests_per_s"],
                 "est_padding_waste": c["est_padding_waste"]}
                for p, c in self.scored[:5]],
            "measured": self.measured,
        }


def autotune(profile: TrafficProfile,
             grid: Optional[Sequence[ServingPlan]] = None,
             model: Optional[CostModel] = None,
             measure_top_k: int = 0,
             config: Optional[PCAConfig] = None,
             seed: int = 0,
             passes: int = 2,
             obs=None) -> AutotuneResult:
    """Search the plan grid against a profile.

    Exhaustive analytic scoring (the grid is small by design), then an
    optional measured refinement: the analytic top-``measure_top_k`` plans
    replay the profile's traffic on live servers and the measured best
    wins.  ``measure_top_k=0`` is the pure-analytic mode (CI-cheap).

    ``obs``: optional ``repro.obs.Observability`` -- the search lands as
    one span on the control track plus an ``autotune_searches_total{mode}``
    counter, so plan churn shows up next to the plan-swap spans it causes.
    """
    grid = list(grid) if grid is not None else plan_grid()
    if not grid:
        raise ValueError("empty plan grid")
    t0 = obs.clock() if obs is not None else 0.0
    model = model or CostModel.calibrated(profile)
    scored = sorted(((plan, model.plan_cost(plan, profile))
                     for plan in grid), key=lambda pc: pc[1]["total_s"])
    best, mode, measured = scored[0][0], "analytic", []
    if measure_top_k > 0:
        for plan, cost in scored[:measure_top_k]:
            row = replay(profile, plan, config=config, seed=seed,
                         passes=passes)
            row.update(plan=plan.to_json(), describe=plan.describe(),
                       est_total_s=cost["total_s"])
            measured.append(row)
        measured.sort(key=lambda r: -r["requests_per_s"])
        best, mode = ServingPlan.from_json(measured[0]["plan"]), "measured"
    if obs is not None:
        obs.tracer.complete(
            "autotune", ts=t0, end=obs.clock(), cat="control",
            track="control", mode=mode, plans=len(grid),
            measured=len(measured), best=best.describe())
        obs.metrics.counter(
            "autotune_searches_total", "Serving-plan autotune searches.",
            ("mode",)).labels(mode=mode).inc()
    return AutotuneResult(best=best, mode=mode, scored=scored,
                          measured=measured, model=model,
                          measured_evals=len(measured), grid_size=len(grid))


# ---------------------------------------------------------------------------
# successive-halving bandit search
# ---------------------------------------------------------------------------

def subsample(profile: TrafficProfile, frac: float,
              seed: int = 0) -> TrafficProfile:
    """The profile at reduced fidelity: every histogram count scaled by
    ``frac`` (at least 1, so no op disappears -- a rung must still see
    every traffic mode it is ranking plans for).  Low rungs of the bandit
    replay these cheap approximations; only the final rung pays for the
    full profile."""
    if frac >= 1.0:
        return profile
    if frac <= 0:
        raise ValueError(f"frac must be in (0, 1], got {frac}")
    rows = tuple(sorted((op, shape, max(1, int(round(n * frac))))
                        for op, shape, n in profile.shape_counts))
    requests = sum(n for _, _, n in rows)
    return dataclasses.replace(
        profile, shape_counts=rows, requests=requests,
        duration_s=profile.duration_s * frac)


def _rung_sizes(budget: int, n_plans: int, eta: int) -> List[int]:
    """Survivor counts per rung: geometric decay by ``eta`` down to a
    final rung of 1, sized so the total replay calls fit ``budget``."""
    n0 = min(n_plans, max(2, (budget * (eta - 1)) // eta))
    while n0 > 1:
        sizes = []
        n = n0
        while n > 1:
            sizes.append(n)
            n = max(1, math.ceil(n / eta))
        sizes.append(1)
        if sum(sizes) <= budget:
            return sizes
        n0 -= 1
    return [1] if budget >= 1 else []


def bandit_search(profile: TrafficProfile,
                  grid: Optional[Sequence[ServingPlan]] = None,
                  model: Optional[CostModel] = None,
                  budget_frac: float = 0.25,
                  eta: int = 3,
                  config: Optional[PCAConfig] = None,
                  seed: int = 0,
                  passes: int = 1,
                  measure: bool = True,
                  obs=None) -> AutotuneResult:
    """Successive-halving plan search: analytic seeding, measured rungs.

    The exhaustive ``autotune(measure_top_k=len(grid))`` spends one
    ``replay`` per plan; this spends at most ``budget_frac`` of that
    (default 25% -- i.e. >= 75% of the measured evaluations are pruned),
    which is what lets the grid grow the mesh x backend axes without the
    measured refinement exploding:

      rung 0   the analytic ``CostModel`` scores the *whole* grid for
               free and seeds the first measured rung with its top
               ``n0`` arms (``n0`` sized so the geometric rung series
               fits the replay budget).
      rung i   every surviving arm replays a ``subsample`` of the
               profile whose fidelity grows by ``eta`` per rung (classic
               successive halving on fidelity); the top ``1/eta`` of
               arms by measured throughput survive.  Ties break toward
               the better analytic rank, so fidelity noise can only
               reorder plans the model already called close.
      final    the last survivor pair replays the full profile; the
               measured winner is the plan.

    ``measure=False`` (or a budget below 2 replays) degrades to pure
    analytic ranking over the grid -- deterministic under an injected
    clock, which is how the serving controller runs in tests and under
    ``VirtualClock`` traffic.
    """
    grid = list(grid) if grid is not None else plan_grid()
    if not grid:
        raise ValueError("empty plan grid")
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")
    t0 = obs.clock() if obs is not None else 0.0
    model = model or CostModel.calibrated(profile)
    scored = sorted(((plan, model.plan_cost(plan, profile))
                     for plan in grid), key=lambda pc: pc[1]["total_s"])
    budget = int(budget_frac * len(grid))
    measured: List[Dict] = []
    evals = 0
    if not measure or budget < 2:
        best, mode = scored[0][0], "bandit-analytic"
    else:
        sizes = _rung_sizes(budget, len(grid), eta)
        analytic_rank = {plan: i for i, (plan, _) in enumerate(scored)}
        survivors = [plan for plan, _ in scored[:sizes[0]]]
        n_rungs = len(sizes)
        for i, size in enumerate(sizes):
            survivors = survivors[:size]
            frac = float(eta) ** (i - (n_rungs - 1))
            rung_profile = subsample(profile, frac, seed=seed)
            rows = []
            for plan in survivors:
                row = replay(rung_profile, plan, config=config, seed=seed,
                             passes=passes)
                evals += 1
                row.update(plan=plan.to_json(), describe=plan.describe(),
                           rung=i, fidelity=frac,
                           est_total_s=model.plan_cost(
                               plan, profile)["total_s"])
                rows.append((plan, row))
            rows.sort(key=lambda pr: (-pr[1]["requests_per_s"],
                                      analytic_rank[pr[0]]))
            measured.extend(r for _, r in rows)
            survivors = [plan for plan, _ in rows]
        best, mode = survivors[0], "bandit"
    if obs is not None:
        obs.tracer.complete(
            "autotune", ts=t0, end=obs.clock(), cat="control",
            track="control", mode=mode, plans=len(grid),
            measured=evals, best=best.describe())
        obs.metrics.counter(
            "autotune_searches_total", "Serving-plan autotune searches.",
            ("mode",)).labels(mode=mode).inc()
    return AutotuneResult(best=best, mode=mode, scored=scored,
                          measured=measured, model=model,
                          measured_evals=evals, grid_size=len(grid))
