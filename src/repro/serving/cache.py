"""Executable cache: the in-memory LRU tier and the persistent AOT tier.

MANOJAVAM answers MM+SVD traffic at fixed latency from cycle one because
the fabric is *pre-built*; a software replica that JIT-compiles on first
request serves its first minutes at compile speed instead -- fatal for
elastic scale-out, where a fresh replica is spawned precisely because
traffic already exceeds capacity.  This module closes that gap with two
cooperating tiers under ``PCAServer._cache``:

  memory  ``LRUCache`` -- the compiled-callable map the engine always had,
          now bounded: a long-lived server under the autotuner used to
          leak every executable of every plan it ever ran (each
          ``apply_plan`` re-aligned the config and minted fresh keys);
          the cap evicts least-recently-dispatched entries instead.
  disk    ``DiskCache`` -- content-hash-keyed AOT executables serialized
          via ``jit(...).lower().compile()`` + ``jax.experimental
          .serialize_executable`` (the pickled-PJRT-binary path; loading
          skips XLA entirely, ~100-1000x faster than a cold compile).
          Writes are atomic (tmpfile in the same directory, then
          ``os.replace``) so two replicas warming one ``--cache-dir``
          concurrently never see a torn file; loads are
          corruption-tolerant (any deserialize failure quarantines the
          entry and falls back to JIT, which then repairs it); the
          directory is size-capped with oldest-access-first eviction.

Keying is the part the old in-memory tier got wrong and that a persistent
tier would have serialized forever: the engine keyed on the *whole*
``PCAConfig``, but the compiled solver only depends on the numerics subset
(sweeps / pivot / rotation / angle / tol / standardize / backend, plus the
matmul block size when a kernel backend is routed).  ``SolverKey`` is that
subset -- two configs that differ only in scheduling facts (T, S) now share
one executable, which is exactly why a plan hot-swap that preserves
bucketing keeps its whole cache.  The disk tier hashes ``SolverKey``
together with (op, bucket, batch, executor token, jax version, device
backend), so an entry is invalidated -- cleanly, by never being looked up
-- the moment any of those change.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import pathlib
import pickle
import tempfile
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax

# bump when the on-disk record layout changes; part of the content hash so
# old-format entries are simply never looked up again
# (2: SolverKey grew precision + fused -- pre-mixed-precision executables
# must never serve a precision-keyed request)
CACHE_FORMAT = 2

# default in-memory cap: generous for steady traffic (a few ops x a few
# buckets x a few batches), small enough that a plan-churning server stays
# bounded
DEFAULT_MAX_ENTRIES = 256

DEFAULT_MAX_DISK_BYTES = 1 << 30    # 1 GiB of serialized executables


def aot_supported() -> bool:
    """Can this jax serialize compiled executables?

    The pickled-PJRT path (``jax.experimental.serialize_executable``) is
    the only one that skips XLA at load time (``jax.export`` round-trips
    StableHLO, which still compiles on load -- no cold-start win).  Absent
    support degrades to memory-tier-only serving, never an error.
    """
    try:
        from jax.experimental import serialize_executable  # noqa: F401
        return True
    except ImportError:         # pragma: no cover - depends on jax build
        return False


def environment_fingerprint() -> Tuple[str, str]:
    """(jax version, device backend) -- the facts that invalidate every
    serialized executable at once when they drift (an XLA binary compiled
    by one jax for one backend must never load into another)."""
    return (jax.__version__, jax.default_backend())


@dataclasses.dataclass(frozen=True)
class SolverKey:
    """The PCAConfig subset a compiled solver actually depends on.

    ``build_solver_fn`` reads sweeps/pivot/rotation/angle/tol/standardize
    and routes matmuls through ``backend`` (whose Pallas block size is
    ``block`` = config.T -- only relevant when a kernel backend is set, so
    it is normalized to None on the plain-XLA datapath).  T and S are
    deliberately absent: they are scheduling facts (bucket tile, flush
    size) that reach the executable through (bucket, batch) in the engine
    key, and keying on them fragmented the cache across every
    ``apply_plan`` re-alignment.
    """
    sweeps: int
    tol: Optional[float]
    pivot: str
    rotation: str
    angle: str
    standardize: bool
    backend: Optional[str]
    block: Optional[int]
    # mixed-precision policy and fused-kernel routing both change the
    # compiled executable (operand dtypes / kernel launch structure), so
    # they are key material like the numerics above
    precision: str = "fp32"
    fused: bool = False

    @classmethod
    def from_config(cls, config) -> "SolverKey":
        return cls(
            sweeps=config.sweeps, tol=config.tol, pivot=config.pivot,
            rotation=config.rotation, angle=config.angle,
            standardize=config.standardize, backend=config.backend,
            block=(config.T if config.backend is not None else None),
            precision=getattr(config, "precision", "fp32"),
            fused=getattr(config, "fused", False))


def content_hash(op: str, bucket: Tuple[int, ...], batch: int,
                 solver: SolverKey, exec_token) -> str:
    """Stable content address of one executable.

    Everything that changes the compiled binary is in the digest: the op,
    the concrete shapes (bucket, batch), the solver numerics, the
    executor placement token (mesh axes + device ids for a mesh), the
    jax version, the device backend, and the record format.  A mismatch
    in any of them lands on a different file -- stale entries are never
    loaded, only eventually evicted by the size cap.
    """
    material = repr((CACHE_FORMAT, op, tuple(bucket), int(batch),
                     dataclasses.astuple(solver), exec_token,
                     environment_fingerprint()))
    return hashlib.sha256(material.encode()).hexdigest()


class LRUCache:
    """Bounded mapping with least-recently-used eviction.

    The engine's in-memory executable tier.  Reads refresh recency (a
    steadily-hit executable never ages out); writes beyond ``max_entries``
    evict the coldest entry.  ``max_entries=None`` is unbounded (the old
    behavior, kept for tests that count entries exactly).
    """

    def __init__(self, max_entries: Optional[int] = DEFAULT_MAX_ENTRIES,
                 on_evict: Optional[Callable] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self.evictions = 0
        self._on_evict = on_evict
        self._data: "collections.OrderedDict" = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def __iter__(self) -> Iterator:
        return iter(list(self._data))

    def __contains__(self, key) -> bool:
        return key in self._data

    def __getitem__(self, key):
        value = self._data[key]
        self._data.move_to_end(key)
        return value

    def get(self, key, default=None):
        if key not in self._data:
            return default
        return self[key]

    def __setitem__(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while (self.max_entries is not None
               and len(self._data) > self.max_entries):
            old_key, old_value = self._data.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_key, old_value)

    def clear(self) -> None:
        self._data.clear()


class DiskCache:
    """Content-addressed directory of serialized AOT executables.

    One file per executable: ``<sha256>.jexec`` holding a pickled record
    ``{"format", "jax", "backend", "payload", "in_tree", "out_tree"}``
    (the ``serialize_executable.serialize`` triple plus the header that
    lets a loader reject an entry copied across environments even when the
    file name happens to match).  All failure modes degrade to a miss:

      * write: serialized to a ``tempfile`` in the cache directory, then
        ``os.replace``d into place -- readers see the old bytes or the new
        bytes, never a prefix, so concurrent warmers are safe.
      * read: any exception (truncated pickle, header mismatch, PJRT
        deserialize failure) quarantines the file (best-effort unlink) and
        returns None; the caller JIT-compiles and re-``put``s, repairing
        the entry.
      * size: after each write the directory is evicted down to
        ``max_bytes``, oldest access first (POSIX atime is unreliable, so
        eviction uses mtime and ``get`` re-touches on hit).
    """

    SUFFIX = ".jexec"

    def __init__(self, cache_dir,
                 max_bytes: int = DEFAULT_MAX_DISK_BYTES):
        self.dir = pathlib.Path(cache_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.max_bytes = max_bytes
        self.hits = 0
        self.misses = 0
        self.stores = 0
        self.errors = 0        # corrupt/mismatched entries quarantined

    def _path(self, key_hash: str) -> pathlib.Path:
        return self.dir / f"{key_hash}{self.SUFFIX}"

    def get(self, key_hash: str) -> Optional[Callable]:
        """The deserialized executable, or None (miss / corrupt entry)."""
        path = self._path(key_hash)
        try:
            blob = path.read_bytes()
        except OSError:
            self.misses += 1
            return None
        try:
            record = pickle.loads(blob)
            if (record["format"] != CACHE_FORMAT
                    or (record["jax"], record["backend"])
                    != environment_fingerprint()):
                raise ValueError(
                    f"cache entry from jax {record.get('jax')}/"
                    f"{record.get('backend')}, this process is "
                    f"{environment_fingerprint()}")
            from jax.experimental import serialize_executable
            fn = serialize_executable.deserialize_and_load(
                record["payload"], record["in_tree"], record["out_tree"])
        except Exception:
            # corrupt, truncated, version-drifted or undeserializable:
            # quarantine and fall back to JIT (the caller re-puts, which
            # repairs the entry)
            self.errors += 1
            try:
                path.unlink()
            except OSError:
                pass
            return None
        self.hits += 1
        try:                   # refresh recency for mtime-ordered eviction
            os.utime(path)
        except OSError:
            pass
        return fn

    def put(self, key_hash: str, compiled) -> bool:
        """Serialize one AOT executable; atomic, best-effort (a full disk
        or an unserializable executable is a skipped store, not a serving
        failure).  Returns True when the entry landed."""
        try:
            from jax.experimental import serialize_executable
            payload, in_tree, out_tree = serialize_executable.serialize(
                compiled)
            jax_version, backend = environment_fingerprint()
            blob = pickle.dumps({
                "format": CACHE_FORMAT, "jax": jax_version,
                "backend": backend, "payload": payload,
                "in_tree": in_tree, "out_tree": out_tree,
            })
        except Exception:
            self.errors += 1
            return False
        try:
            fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                os.replace(tmp, self._path(key_hash))
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except OSError:
            self.errors += 1
            return False
        self.stores += 1
        self._evict_to_cap()
        return True

    def entries(self):
        return sorted(self.dir.glob(f"*{self.SUFFIX}"))

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.entries())

    def _evict_to_cap(self) -> None:
        """Drop oldest-touched entries until the directory fits the cap."""
        try:
            paths = [(p.stat().st_mtime, p.stat().st_size, p)
                     for p in self.entries()]
        except OSError:        # raced a concurrent eviction
            return
        total = sum(size for _, size, _ in paths)
        for _, size, path in sorted(paths, key=lambda t: t[0]):
            if total <= self.max_bytes:
                break
            try:
                path.unlink()
                total -= size
            except OSError:    # another process got there first
                pass

    def summary(self) -> Dict:
        return {
            "dir": str(self.dir),
            "entries": len(self.entries()),
            "bytes": self.total_bytes(),
            "max_bytes": self.max_bytes,
            "hits": self.hits, "misses": self.misses,
            "stores": self.stores, "errors": self.errors,
        }


class ExecutableCache:
    """The engine's two-tier executable cache (what ``PCAServer._cache``
    is now).

    Mapping surface (``len``/``in``/iteration/indexing) is the in-memory
    LRU tier, so everything that introspected the old dict still works;
    ``lookup``/``store`` add the disk tier underneath:

      lookup   memory hit -> (fn, "memory").  Disk hit -> deserialize,
               promote into memory, ("disk").  Otherwise (None, "miss").
      store    memory insert; when the entry is an AOT ``Compiled`` (the
               engine compiles AOT exactly when a disk tier is armed) it
               is also serialized to disk.

    The same LRU instance backs both the engine's steady-state path and
    the disk tier's promotions, so the size cap is shared: warming 500
    executables from disk cannot balloon host memory past the cap either.
    """

    def __init__(self, max_entries: Optional[int] = DEFAULT_MAX_ENTRIES,
                 cache_dir=None,
                 max_disk_bytes: int = DEFAULT_MAX_DISK_BYTES):
        self.mem = LRUCache(max_entries=max_entries)
        self.disk: Optional[DiskCache] = None
        if cache_dir is not None and aot_supported():
            self.disk = DiskCache(cache_dir, max_bytes=max_disk_bytes)

    # -- mapping surface (the old dict's contract) --------------------------
    def __len__(self) -> int:
        return len(self.mem)

    def __iter__(self) -> Iterator:
        return iter(self.mem)

    def __contains__(self, key) -> bool:
        return key in self.mem

    def __getitem__(self, key):
        return self.mem[key]

    def get(self, key, default=None):
        return self.mem.get(key, default)

    @property
    def evictions(self) -> int:
        return self.mem.evictions

    # -- two-tier path ------------------------------------------------------
    def hash_key(self, key) -> str:
        op, bucket, batch, solver, exec_token = key
        return content_hash(op, bucket, batch, solver, exec_token)

    def lookup(self, key) -> Tuple[Optional[Callable], str]:
        """(executable, source) where source is 'memory'|'disk'|'miss'."""
        fn = self.mem.get(key)
        if fn is not None:
            return fn, "memory"
        if self.disk is not None:
            fn = self.disk.get(self.hash_key(key))
            if fn is not None:
                self.mem[key] = fn
                return fn, "disk"
        return None, "miss"

    def store(self, key, fn, persist: bool = False) -> None:
        self.mem[key] = fn
        if persist and self.disk is not None:
            self.disk.put(self.hash_key(key), fn)

    def clear_memory(self) -> None:
        """Drop the in-memory tier only (a fresh replica's view of a warm
        disk cache -- used by cold-start benchmarks and tests)."""
        self.mem.clear()

    def summary(self) -> Dict:
        doc = {
            "entries": len(self.mem),
            "max_entries": self.mem.max_entries,
            "evictions": self.mem.evictions,
            "disk": self.disk.summary() if self.disk is not None else None,
        }
        return doc
