"""Autonomous serving controller: sliding-window re-profiling + bandit
plan search + hysteresis-guarded hot-swaps (ROADMAP item 3).

MANOJAVAM's mode-aware memory policies re-adapt the fabric as the access
pattern shifts between PCA stages; the serving-layer analogue re-adapts
the *plan* as the traffic regime shifts under the open-loop frontend.
``PCAServer.apply_plan`` (PR 5) made swaps possible but manual; this
closes the loop:

  re-profile   every ``reprofile_every_s`` on the engine's injected clock
               (``PCAServer.poll`` ticks the controller, so the loop is
               single-threaded and fully deterministic under a
               ``VirtualClock``), condense the trailing ``window_s`` of
               live telemetry into a ``TrafficProfile`` --
               ``ServingStats`` records when reachable (true pre-bucket
               shapes), else ``MetricRegistry`` series
               (``TrafficProfile.from_registry``).  Quiet ops carry
               forward at exponential decay, so a traffic pause never
               yields the empty profile that would tune for nothing.
  search       ``autotune.bandit_search`` over the plan grid grown by the
               mesh x backend axes: the analytic ``CostModel``
               (calibrated from lifetime telemetry) seeds the rungs for
               free; with ``measure=True`` surviving arms replay at
               rising fidelity, spending <= ``budget_frac`` of the
               exhaustive grid's measured evaluations.
  swap         only when the predicted gain clears ``hysteresis`` AND
               ``min_dwell_s`` has passed since the last swap -- the
               anti-thrash pair.  The swap goes through
               ``apply_plan(warm_profile=...)`` so the incoming plan's
               executables pre-build before any ticket re-buckets.
  feed back    the post-swap calibrated ``CostModel`` is pushed into the
               frontend's ``AdmissionController``
               (``TrafficFrontend.set_cost_model``), so admission
               feasibility tracks the plan actually in force.

Every tick emits ``controller_*`` telemetry through ``repro.obs``: a
``controller_tick`` span on the control track, swap/skip counters (skips
labeled by reason: same-plan / below-hysteresis / dwell / empty-window)
and a predicted-gain gauge.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.pca import PCAConfig
from .autotune import (CostModel, ServingPlan, TrafficProfile,
                       bandit_search, plan_grid)

__all__ = ["ServingController"]


class ServingController:
    """The re-profile / search / swap loop around one ``PCAServer``.

    Args:
      server: the engine to steer; its clock and telemetry drive the loop.
      window_s: sliding re-profile window (seconds of trailing traffic).
      reprofile_every_s: tick cadence; ``maybe_tick`` between cadences is
        a cheap no-op, so the engine can call it every ``poll``.
      hysteresis: minimum predicted fractional gain
        (``1 - best_cost / current_cost``) before a swap is worth the
        re-bucketing churn.
      min_dwell_s: minimum time between swaps (anti-thrash).
      grid: explicit plan grid; default ``plan_grid`` grown by ``meshes``
        x ``backends``.
      meshes / backends: the executor and kernel-backend axes (default
        off, matching the engine's own defaults).
      budget_frac / measure / passes: bandit search budget --
        ``measure=False`` (default) is the pure-analytic bandit,
        deterministic under an injected clock.
      frontend: optional ``TrafficFrontend``; after a swap its admission
        controller receives the new calibrated cost model.
      min_window_requests: windows with fewer fresh+carried requests are
        skipped (not enough signal to out-predict the current plan).
      decay: carry-forward weight for ops quiet in the current window.
    """

    def __init__(self, server, window_s: float = 5.0,
                 reprofile_every_s: float = 1.0, hysteresis: float = 0.15,
                 min_dwell_s: float = 2.0,
                 grid: Optional[Sequence[ServingPlan]] = None,
                 meshes: Sequence[str] = ("none",),
                 backends: Sequence[Optional[str]] = ("keep",),
                 budget_frac: float = 0.25, measure: bool = False,
                 passes: int = 1, seed: int = 0, frontend=None,
                 min_window_requests: int = 4, decay: float = 0.5,
                 model: Optional[CostModel] = None):
        if window_s <= 0 or reprofile_every_s <= 0:
            raise ValueError("window_s and reprofile_every_s must be > 0")
        if not 0 <= hysteresis < 1:
            raise ValueError(f"hysteresis must be in [0, 1), "
                             f"got {hysteresis}")
        self.server = server
        self.window_s = float(window_s)
        self.reprofile_every_s = float(reprofile_every_s)
        self.hysteresis = float(hysteresis)
        self.min_dwell_s = float(min_dwell_s)
        self.grid = (list(grid) if grid is not None
                     else plan_grid(meshes=tuple(meshes),
                                    backends=tuple(backends)))
        self.budget_frac = float(budget_frac)
        self.measure = bool(measure)
        self.passes = int(passes)
        self.seed = int(seed)
        self.frontend = frontend
        self.min_window_requests = int(min_window_requests)
        self.decay = float(decay)
        # a pinned model skips per-tick calibration -- benchmarks pin it
        # so regret is well-defined under one scoring function; live
        # serving leaves it None and recalibrates from each window
        self.model = model
        self.swaps: List[Dict] = []       # one record per applied swap
        self.plan_log: List[tuple] = []   # (t, ServingPlan) per swap
        self.ticks = 0
        self.last_result = None           # AutotuneResult of the last tick
        self._last_tick: Optional[float] = None
        self._last_swap: Optional[float] = None
        self._last_profile: Optional[TrafficProfile] = None
        self._in_tick = False
        self._wire_obs()

    @classmethod
    def from_spec(cls, server, cspec, frontend=None,
                  seed: int = 0) -> "ServingController":
        """Build from a ``serving.spec.ControllerSpec``."""
        return cls(server, window_s=cspec.window_s,
                   reprofile_every_s=cspec.reprofile_every_s,
                   hysteresis=cspec.hysteresis,
                   min_dwell_s=cspec.min_dwell_s,
                   meshes=cspec.meshes, backends=cspec.backends,
                   budget_frac=cspec.budget_frac, measure=cspec.measure,
                   seed=seed, frontend=frontend)

    # -- telemetry ----------------------------------------------------------
    def _wire_obs(self) -> None:
        obs = self.server.obs
        if obs is None:
            self._m_ticks = None
            return
        m = obs.metrics
        self._m_ticks = m.counter(
            "controller_ticks_total",
            "Controller re-profile ticks.").labels()
        self._m_swaps = m.counter(
            "controller_swaps_total",
            "Plan swaps the controller applied.").labels()
        self._m_skips = m.counter(
            "controller_skips_total",
            "Ticks that decided against swapping, by reason.", ("reason",))
        self._m_gain = m.gauge(
            "controller_predicted_gain",
            "Predicted fractional gain of the last tick's best plan "
            "over the current plan.").labels()

    def _skip(self, reason: str, now: float) -> None:
        if self._m_ticks is not None:
            self._m_skips.labels(reason=reason).inc(now=now)

    # -- profiling ----------------------------------------------------------
    def current_plan(self) -> ServingPlan:
        """The server's in-force facts as a ``ServingPlan`` (the
        hysteresis baseline the candidate must beat)."""
        srv = self.server
        n = int(getattr(srv.executor, "n_shards", 1))
        return ServingPlan(mode=srv.policy.mode, T=srv.policy.T,
                           pow2_cap=srv.policy.pow2_cap,
                           max_batch=srv.max_batch,
                           max_inflight=srv.max_inflight,
                           mesh="none" if n <= 1 else str(n))

    def window_profile(self, now: float) -> TrafficProfile:
        """The trailing window's traffic, with quiet-op carry-forward.

        Prefers ``ServingStats`` records (true pre-bucketing shapes; the
        registry only retains bucket labels); falls back to
        ``TrafficProfile.from_registry`` when stats are unreachable.
        Either way, ops with zero events this window inherit the previous
        profile's histogram at ``decay`` weight -- see ``from_registry``.
        """
        captured = self.server.describe_plan()
        stats = getattr(self.server, "stats", None)
        if stats is not None:
            profile = self._from_stats_window(stats, now, captured)
        else:
            profile = TrafficProfile.from_registry(
                self.server.obs.metrics, self.window_s, now=now,
                carry=self._last_profile, decay=self.decay,
                captured=captured)
        self._last_profile = profile
        return profile

    def _from_stats_window(self, stats, now: float,
                           captured: Dict) -> TrafficProfile:
        """Windowed ``from_stats`` with the same carry-forward contract
        as ``from_registry``."""
        import collections
        cut = now - self.window_s
        recs = [r for r in stats.records if r.t_done >= cut]
        counts = collections.Counter(
            (r.op, tuple(int(d) for d in r.shape)) for r in recs)
        fresh_ops = {op for op, _ in counts}
        carry = self._last_profile
        if carry is not None and self.decay > 0:
            for op, shape, n in carry.shape_counts:
                if op in fresh_ops:
                    continue
                kept = int(round(n * self.decay))
                if kept > 0:
                    counts[(op, tuple(int(d) for d in shape))] += kept
        shape_counts = tuple(sorted(
            (op, shape, n) for (op, shape), n in counts.items()))
        requests = sum(n for _, _, n in shape_counts)
        # calibration aggregates come from lifetime telemetry (more
        # samples -> steadier cost-model constants than one window's)
        life = TrafficProfile.from_stats(stats, captured=captured)
        return dataclasses.replace(
            life, shape_counts=shape_counts, requests=requests,
            duration_s=self.window_s,
            arrival_rate=requests / self.window_s)

    # -- the loop -----------------------------------------------------------
    def maybe_tick(self, now: Optional[float] = None) -> Optional[Dict]:
        """Run one controller decision if the cadence is due.

        Returns the swap record when a swap was applied, else None.
        Reentrancy-guarded: a swap's own pre-warm/poll activity cannot
        recurse into another tick.
        """
        if self._in_tick:
            return None
        now = self.server.clock() if now is None else now
        if (self._last_tick is not None
                and now - self._last_tick < self.reprofile_every_s):
            return None
        self._in_tick = True
        try:
            return self._tick(now)
        finally:
            self._in_tick = False

    def _tick(self, now: float) -> Optional[Dict]:
        self._last_tick = now
        self.ticks += 1
        obs = self.server.obs
        if self._m_ticks is not None:
            self._m_ticks.inc(now=now)
        profile = self.window_profile(now)
        if profile.requests < self.min_window_requests:
            self._skip("empty-window", now)
            return None
        model = self.model or CostModel.calibrated(profile)
        result = bandit_search(
            profile, grid=self.grid, model=model,
            budget_frac=self.budget_frac,
            config=dataclasses.replace(self.server.config),
            seed=self.seed, passes=self.passes,
            measure=self.measure, obs=obs)
        self.last_result = result
        current = self.current_plan()
        cur_cost = model.plan_cost(current, profile)["total_s"]
        best_cost = model.plan_cost(result.best, profile)["total_s"]
        gain = 1.0 - best_cost / cur_cost if cur_cost > 0 else 0.0
        if self._m_ticks is not None:
            self._m_gain.set(gain, now=now)
        swap = None
        reason = None
        if result.best == current:
            reason = "same-plan"
        elif gain < self.hysteresis:
            reason = "below-hysteresis"
        elif (self._last_swap is not None
              and now - self._last_swap < self.min_dwell_s):
            reason = "dwell"
        else:
            swap = self.server.apply_plan(result.best,
                                          warm_profile=profile)
            swap.update(t=now, predicted_gain=gain,
                        plan=result.best.describe(),
                        search_mode=result.mode,
                        measured_evals=result.measured_evals)
            self.swaps.append(swap)
            self.plan_log.append((now, result.best))
            self._last_swap = now
            if self._m_ticks is not None:
                self._m_swaps.inc(now=now)
            if self.frontend is not None:
                self.frontend.set_cost_model(model)
        if reason is not None:
            self._skip(reason, now)
        if obs is not None:
            obs.tracer.complete(
                "controller_tick", ts=now, end=obs.clock(), cat="control",
                track="control", requests=profile.requests,
                gain=round(gain, 4), swapped=swap is not None,
                **({"skip": reason} if reason else {}))
        return swap

    def summary(self) -> Dict:
        """Plain JSON-able controller state for reports and banners."""
        return {
            "ticks": self.ticks,
            "swaps": len(self.swaps),
            "grid_size": len(self.grid),
            "window_s": self.window_s,
            "reprofile_every_s": self.reprofile_every_s,
            "hysteresis": self.hysteresis,
            "min_dwell_s": self.min_dwell_s,
            "current_plan": self.current_plan().describe(),
            "swap_log": [{k: s[k] for k in
                          ("t", "predicted_gain", "plan", "requeued")}
                         for s in self.swaps],
        }
