"""Declarative server construction: one frozen ``ServerSpec`` replaces
the 13-kwarg ``PCAServer.__init__`` and the ``serve_pca`` flag soup.

The spec is the single source of truth for *what to build*; live objects
(executors, obs bundles, routers) are built from it, never stored in it,
so a spec round-trips through JSON losslessly and two servers built from
equal specs are built from identical parts:

  SchedulingSpec   bucketing + microbatching + pipeline depth -- the
                   facts a ``ServingPlan`` hot-swaps.
  ExecutionSpec    where and how flushes run: mesh, kernel backend (and
                   the threshold router's cut-over), solver numerics.
  CacheSpec        the persistent executable tier + warmup profile.
  ObsSpec          tracing/metrics/SLO outputs (obs is armed iff any
                   output is requested).
  ControllerSpec   the autonomous serving controller's cadence,
                   hysteresis and search budget.

Construction paths:

  ``ServerSpec.from_args(ns)``    every ``serve_pca`` flag resolves here
                                  (and ``validate_args`` rejects flag
                                  combinations that would silently
                                  last-write-win).
  ``ServerSpec.from_json``/``to_json``  the ``--spec server.json`` file.
  ``build_server(spec)`` / ``PCAServer.from_spec(spec)``  the live
                                  server, with obs bundle and controller
                                  attached when the spec asks.

Parity contract (tests/test_spec.py): a spec-built server serves the
selftest burst bitwise-identical to the kwarg-built server, because the
spec layer passes the same values to the same constructor -- there is no
second code path to drift.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, Optional, Tuple

from repro.core.pca import PCAConfig
from .batching import BucketPolicy, POLICIES

SPEC_FORMAT = 1


class SpecConflictError(ValueError):
    """Two flags (or a flag and a spec file) claim the same fact."""


def _freeze(v):
    return tuple(v) if isinstance(v, list) else v


@dataclasses.dataclass(frozen=True)
class SchedulingSpec:
    """Bucketing and microbatching: the hot-swappable plan facts."""
    mode: str = "tile"               # bucket policy (POLICIES)
    T: int = 16                      # bucket tile (paper T)
    pow2_cap: Optional[int] = None
    max_batch: int = 4               # requests per flush (paper S)
    max_delay_s: float = 0.01        # flush deadline per queued request
    pad_batches: bool = True
    max_inflight: int = 1            # dispatch pipeline depth

    def policy(self) -> BucketPolicy:
        return BucketPolicy(T=self.T, mode=self.mode,
                            pow2_cap=self.pow2_cap)


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """Where and how flushes execute."""
    mesh: str = "none"               # sharded.mesh_executor spelling
    backend: Optional[str] = None    # PCAConfig.backend (None = plain XLA)
    router_min_dim: Optional[int] = None  # threshold_router cut-over
    sweeps: int = 12
    precision: str = "fp32"
    fused: bool = False


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """The persistent executable tier and pre-traffic warmup."""
    cache_dir: Optional[str] = None
    max_cached_executables: Optional[int] = None  # None = engine default
    warmup_profile: Optional[str] = None          # TrafficProfile JSON path


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability outputs; the bundle is armed iff any is set."""
    slo_ms: Optional[float] = None
    trace_out: Optional[str] = None
    metrics_out: Optional[str] = None
    jax_profile: Optional[str] = None

    @property
    def armed(self) -> bool:
        return any((self.slo_ms is not None, self.trace_out,
                    self.metrics_out, self.jax_profile))


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """The autonomous controller's cadence, guards and search budget."""
    enabled: bool = False
    window_s: float = 5.0            # sliding re-profile window
    reprofile_every_s: float = 1.0   # tick cadence on the engine clock
    hysteresis: float = 0.15         # min predicted gain before a swap
    min_dwell_s: float = 2.0         # anti-thrash: min time between swaps
    budget_frac: float = 0.25        # measured-replay budget vs grid size
    measure: bool = False            # False = analytic bandit (CI-cheap)
    meshes: Tuple[str, ...] = ("none",)        # executor axis of the grid
    backends: Tuple[Optional[str], ...] = ("keep",)  # backend axis

    def __post_init__(self):
        object.__setattr__(self, "meshes", _freeze(self.meshes))
        object.__setattr__(self, "backends", _freeze(self.backends))


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """Everything needed to build one ``PCAServer`` (and its controller).

    Frozen and JSON-round-trippable; see the module docstring for the
    sub-spec split.  ``build_server(spec)`` is the constructor.
    """
    scheduling: SchedulingSpec = SchedulingSpec()
    execution: ExecutionSpec = ExecutionSpec()
    cache: CacheSpec = CacheSpec()
    obs: ObsSpec = ObsSpec()
    controller: ControllerSpec = ControllerSpec()

    # -- derived parts ------------------------------------------------------
    def config(self) -> PCAConfig:
        return PCAConfig(T=self.scheduling.T,
                         S=self.scheduling.max_batch,
                         sweeps=self.execution.sweeps,
                         backend=self.execution.backend,
                         precision=self.execution.precision,
                         fused=self.execution.fused)

    def validate(self) -> "ServerSpec":
        s = self.scheduling
        if s.mode not in POLICIES:
            raise ValueError(f"unknown bucket mode {s.mode!r}; "
                             f"one of {POLICIES}")
        if s.T < 1 or s.max_batch < 1 or s.max_inflight < 1:
            raise ValueError(f"T/max_batch/max_inflight must be >= 1: {s}")
        c = self.controller
        if c.enabled:
            if c.window_s <= 0 or c.reprofile_every_s <= 0:
                raise ValueError(
                    f"controller window/cadence must be > 0: {c}")
            if not 0 <= c.hysteresis < 1:
                raise ValueError(
                    f"hysteresis must be in [0, 1), got {c.hysteresis}")
            if c.min_dwell_s < 0:
                raise ValueError(
                    f"min_dwell_s must be >= 0, got {c.min_dwell_s}")
        return self

    # -- JSON round trip ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({"server_spec": SPEC_FORMAT,
                           **dataclasses.asdict(self)},
                          indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "ServerSpec":
        doc = json.loads(text)
        doc.pop("server_spec", None)
        parts = {}
        for f in dataclasses.fields(cls):
            sub = doc.get(f.name)
            if sub is None:
                continue
            sub_cls = {"scheduling": SchedulingSpec,
                       "execution": ExecutionSpec, "cache": CacheSpec,
                       "obs": ObsSpec, "controller": ControllerSpec}[f.name]
            parts[f.name] = sub_cls(**{
                sf.name: _freeze(sub[sf.name])
                for sf in dataclasses.fields(sub_cls) if sf.name in sub})
        return cls(**parts).validate()

    def save(self, path) -> None:
        pathlib.Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "ServerSpec":
        return cls.from_json(pathlib.Path(path).read_text())

    # -- CLI resolution -----------------------------------------------------
    @classmethod
    def from_args(cls, ns) -> "ServerSpec":
        """Resolve an argparse namespace (the ``serve_pca`` flag set) into
        a spec.  Every construction-relevant flag flows through here --
        the CLI has no second path to the constructor.  Missing attributes
        fall back to the spec defaults, so partially-populated namespaces
        (tests, other CLIs) resolve too."""
        g = lambda name, default: getattr(ns, name, default)
        timeout_ms = g("timeout_ms", 10.0)
        spec = cls(
            scheduling=SchedulingSpec(
                mode=g("bucket_policy", "tile"),
                T=g("tile", 16),
                max_batch=g("max_batch", 4),
                max_delay_s=float(timeout_ms) / 1e3,
                max_inflight=g("inflight", 1)),
            execution=ExecutionSpec(
                mesh=g("mesh", "none"),
                sweeps=g("sweeps", 12)),
            cache=CacheSpec(
                cache_dir=g("cache_dir", None),
                warmup_profile=g("warmup", None)),
            obs=ObsSpec(
                slo_ms=g("slo_ms", None),
                trace_out=g("trace_out", None),
                metrics_out=g("metrics_out", None),
                jax_profile=g("jax_profile", None)),
            controller=ControllerSpec(
                enabled=g("controller", "off") == "on",
                window_s=g("profile_window", 5.0),
                reprofile_every_s=g("reprofile_every", 1.0),
                hysteresis=g("hysteresis", 0.15),
                min_dwell_s=g("min_dwell", 2.0),
                meshes=("none",) if g("mesh", "none") in ("none", "local")
                else ("none", g("mesh", "none"))),
        )
        return spec.validate()


# flag dest -> "which fact it sets" for the conflict messages; these are
# exactly the serve_pca flags a --spec file owns
SPEC_COVERED_FLAGS = {
    "tile": "scheduling.T",
    "bucket_policy": "scheduling.mode",
    "max_batch": "scheduling.max_batch",
    "timeout_ms": "scheduling.max_delay_s",
    "inflight": "scheduling.max_inflight",
    "mesh": "execution.mesh",
    "sweeps": "execution.sweeps",
    "cache_dir": "cache.cache_dir",
    "warmup": "cache.warmup_profile",
    "slo_ms": "obs.slo_ms",
    "trace_out": "obs.trace_out",
    "metrics_out": "obs.metrics_out",
    "jax_profile": "obs.jax_profile",
    "controller": "controller.enabled",
    "profile_window": "controller.window_s",
    "reprofile_every": "controller.reprofile_every_s",
    "hysteresis": "controller.hysteresis",
    "min_dwell": "controller.min_dwell_s",
}


def _explicit(ns, defaults: Dict, dest: str) -> bool:
    """Did the CLI user set this flag away from its parser default?"""
    return (dest in defaults
            and getattr(ns, dest, defaults[dest]) != defaults[dest])


def validate_args(ns, defaults: Dict) -> None:
    """Reject mutually-exclusive / silently-ignored flag combinations
    with a named conflict, instead of last-write-wins.  ``defaults`` is
    the parser's own default mapping (``vars(parser.parse_args([]))``),
    so "explicitly set" means "differs from the parser default"."""
    def conflict(msg):
        raise SpecConflictError(f"flag conflict: {msg}")

    spec_file = getattr(ns, "spec", None)
    if spec_file:
        clash = sorted(dest for dest in SPEC_COVERED_FLAGS
                       if _explicit(ns, defaults, dest))
        if clash:
            flags = ", ".join("--" + d.replace("_", "-") for d in clash)
            facts = ", ".join(SPEC_COVERED_FLAGS[d] for d in clash)
            conflict(f"{flags} conflicts with --spec {spec_file}: the "
                     f"spec file owns {facts}; edit the spec instead")
    controller_on = getattr(ns, "controller", "off") == "on"
    if controller_on and getattr(ns, "autotune", "off") != "off":
        conflict(f"--autotune {ns.autotune} conflicts with --controller "
                 "on: the controller owns plan search (it re-tunes every "
                 "re-profile window); drop one of the two")
    if not controller_on and not spec_file:
        for dest in ("reprofile_every", "hysteresis", "min_dwell",
                     "profile_window"):
            if _explicit(ns, defaults, dest):
                conflict(f"--{dest.replace('_', '-')} is ignored without "
                         "--controller on")
    if getattr(ns, "arrivals", None):
        for dest, why in (("autotune", "open-loop runs tune via the "
                           "controller (--controller on), not --autotune"),
                          ("profile_in", "open-loop runs profile their "
                           "own arrival stream"),
                          ("warmup", "open-loop runs warm every bucket "
                           "of the arrival stream themselves")):
            if _explicit(ns, defaults, dest):
                conflict(f"--{dest.replace('_', '-')} is ignored under "
                         f"--arrivals: {why}")
    if (_explicit(ns, defaults, "degrade_frac")
            and getattr(ns, "admission", "shed") != "degrade"):
        conflict("--degrade-frac only applies with --admission degrade")
    if (_explicit(ns, defaults, "measure_top_k")
            and getattr(ns, "autotune", "off") != "measured"):
        conflict("--measure-top-k only applies with --autotune measured")


def resolve_spec(ns, defaults: Optional[Dict] = None) -> "ServerSpec":
    """The CLI entry point: validate the flag set, then resolve it into a
    spec -- from the ``--spec`` file when given, else from the flags."""
    validate_args(ns, defaults or {})
    spec_file = getattr(ns, "spec", None)
    if spec_file:
        return ServerSpec.load(spec_file)
    return ServerSpec.from_args(ns)


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def build_server(spec: ServerSpec, clock=None, frontend=None):
    """The live ``PCAServer`` a spec describes (obs bundle and controller
    included).  ``clock=None`` uses wall time; tests inject a
    ``VirtualClock``.  ``frontend`` (a ``TrafficFrontend``) wires the
    controller's admission feedback."""
    from . import engine
    from .sharded import mesh_executor
    spec.validate()
    clock = clock or time.monotonic
    obs = None
    if spec.obs.armed:
        from repro.obs import Observability
        obs = Observability.enabled(slo_ms=spec.obs.slo_ms, clock=clock)
    router = None
    if spec.execution.router_min_dim is not None:
        router = engine.threshold_router(spec.execution.router_min_dim)
    kw = {}
    if spec.cache.max_cached_executables is not None:
        kw["max_cached_executables"] = spec.cache.max_cached_executables
    with engine.spec_construction():
        srv = engine.PCAServer(
            spec.config(),
            policy=spec.scheduling.policy(),
            max_batch=spec.scheduling.max_batch,
            max_delay_s=spec.scheduling.max_delay_s,
            pad_batches=spec.scheduling.pad_batches,
            backend_router=router,
            executor=mesh_executor(spec.execution.mesh),
            max_inflight=spec.scheduling.max_inflight,
            obs=obs,
            cache_dir=spec.cache.cache_dir,
            clock=clock,
            **kw)
    srv.spec = spec
    if spec.controller.enabled:
        from .controller import ServingController
        srv.controller = ServingController.from_spec(
            srv, spec.controller, frontend=frontend)
    return srv
