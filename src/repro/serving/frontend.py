"""Open-loop traffic frontend: arrivals, tenant fairness, admission.

The benchmarks before this module replayed closed-loop bursts -- submit
everything, drain, divide.  Millions of users are an *open-loop* arrival
process: requests land on their own schedule whether or not the server
kept up, and the headline metric shifts from raw throughput to **goodput
under an SLO** (p99-latency-compliant requests/s).  This module is the
layer between that traffic and ``PCAServer.submit``:

  arrivals    seeded generators for Poisson / diurnal (sinusoid-modulated
              rate, thinning-sampled) / bursty (Markov-modulated on-off)
              processes, producing timestamped per-tenant ``Arrival``
              streams whose shape mix reuses ``autotune.trace_dims`` --
              so ``profile_of(arrivals)`` hands the autotuner a
              ``TrafficProfile`` describing exactly the traffic the
              frontend will emit, arrival rate included.
  fairness    per-tenant ``TokenBucket`` quotas and a ``FairQueue``
              scheduling across tenant queues by virtual finish time
              (start-time fair queueing: tag = max(vtime, tenant finish),
              finish += work/weight; pop min tag) with a priority lane
              that bypasses WFQ for latency-critical tenants.
  admission   deadline feasibility at ingress: ``CostModel``-predicted
              service time plus the current backlog vs the request's SLO.
              Infeasible requests are *shed* (typed outcome, no queueing)
              or *degraded* (resubmitted with fewer Jacobi sweeps -- a
              relaxed ``SolverKey`` executable -- when the cheaper
              variant fits the deadline).  The backlog estimate is
              scheduler-aware: under WFQ a tenant waits on its *own*
              queue scaled by its weight share, so admission does not
              shed a light tenant for a whale's backlog.

``TrafficFrontend.run`` drives a live server in two modes.  ``pace=True``
replays arrivals in real time through a feeder thread + submitter worker
(the threaded slot/queue shape of the MaxText offline-inference harness):
the feeder never blocks on the server -- that is what makes the loop
open -- while the worker absorbs backpressure from the engine's in-flight
cap, so the scheduler queue grows exactly when the server saturates and
fairness starts to matter.  ``pace=False`` runs the same admission and
scheduling math single-threaded under a ``VirtualClock`` with a modeled
service horizon (``CostModel`` seconds accumulate into ``busy_until``),
which makes queueing, shedding and WFQ ordering bit-reproducible: same
seed, same admitted/shed split, same results.  CI asserts exactly that.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import heapq
import itertools
import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .autotune import CostModel, TrafficProfile, synthesize, trace_dims

ARRIVALS = ("poisson", "diurnal", "bursty")
SCHEDULERS = ("wfq", "fifo")
ADMISSION_MODES = ("none", "shed", "degrade")


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

class VirtualClock:
    """A settable monotonic clock -- inject into ``PCAServer``,
    ``Observability`` and the frontend so a whole open-loop run advances
    in simulated time, deterministically."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a clock by {dt}")
        self._t += dt
        return self._t

    def set(self, t: float) -> float:
        """Move to ``t`` (monotone: never backwards)."""
        self._t = max(self._t, float(t))
        return self._t


# ---------------------------------------------------------------------------
# tenants and arrivals
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic contract.

    ``share`` is its fraction of the offered load (normalized across the
    tenant set); ``weight`` its WFQ weight; ``rate_limit`` a token-bucket
    quota in requests/s (0 = unlimited) with ``burst`` tokens of depth
    (default: one second's worth); ``priority`` routes it around WFQ
    through the priority lane; ``slo_ms`` overrides the frontend SLO.
    """
    name: str
    share: float = 1.0
    weight: float = 1.0
    rate_limit: float = 0.0
    burst: float = 0.0
    priority: bool = False
    slo_ms: Optional[float] = None


def parse_tenants(spec: str) -> Tuple[TenantSpec, ...]:
    """CLI spelling: ``name[:share[:weight]][:p]`` comma-separated --
    ``"whale:0.9,mouse:0.1"``, ``"rt:0.2:1:p,batch:0.8:1"``."""
    tenants = []
    for tok in spec.split(","):
        parts = [p.strip() for p in tok.strip().split(":") if p.strip()]
        if not parts:
            continue
        priority = parts[-1].lower() == "p"
        if priority:
            parts = parts[:-1]
        name = parts[0]
        share = float(parts[1]) if len(parts) > 1 else 1.0
        weight = float(parts[2]) if len(parts) > 2 else 1.0
        tenants.append(TenantSpec(name=name, share=share, weight=weight,
                                  priority=priority))
    if not tenants:
        raise ValueError(f"no tenants in spec {spec!r}")
    return tuple(tenants)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One timestamped request in an open-loop stream."""
    t: float
    tenant: str
    op: str
    shape: Tuple[int, ...]
    rid: int


def arrival_times(kind: str, rate: float, n: int, seed: int = 0,
                  period_s: float = 60.0, depth: float = 0.8,
                  on_s: float = 1.0, off_s: float = 3.0,
                  burst_factor: float = 4.0) -> List[float]:
    """``n`` arrival timestamps of a named process at mean ``rate`` req/s.

    poisson  homogeneous: exponential inter-arrivals.
    diurnal  non-homogeneous, lam(t) = rate * (1 + depth sin(2 pi t /
             period_s)), sampled by thinning against lam_max.
    bursty   Markov-modulated on-off: exponential dwell in on/off states
             (mean ``on_s``/``off_s``), on-rate = burst_factor * rate,
             off-rate chosen so the long-run mean stays ``rate`` (clamped
             at 0 when the on state alone exceeds it -- the defaults,
             4x bursts for a quarter of the cycle, balance exactly).

    Deterministic in (kind, rate, n, seed, shape params) -- the generator
    never reads a wall clock.
    """
    if kind not in ARRIVALS:
        raise ValueError(f"unknown arrival kind {kind!r}; one of {ARRIVALS}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if n <= 0:
        return []
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = 0.0
    if kind == "poisson":
        for dt in rng.exponential(1.0 / rate, size=n):
            t += dt
            times.append(t)
    elif kind == "diurnal":
        lam_max = rate * (1.0 + abs(depth))
        while len(times) < n:
            t += rng.exponential(1.0 / lam_max)
            lam = rate * (1.0 + depth * math.sin(2 * math.pi * t / period_s))
            if rng.random() * lam_max <= max(lam, 0.0):
                times.append(t)
    else:  # bursty
        rate_on = burst_factor * rate
        cycle = on_s + off_s
        rate_off = max((rate * cycle - rate_on * on_s) / off_s, 0.0)
        on = True
        t_flip = t + rng.exponential(on_s)
        while len(times) < n:
            r = rate_on if on else rate_off
            if r <= 0:
                t = t_flip
                on = not on
                t_flip = t + rng.exponential(on_s if on else off_s)
                continue
            dt = rng.exponential(1.0 / r)
            if t + dt >= t_flip:
                t = t_flip
                on = not on
                t_flip = t + rng.exponential(on_s if on else off_s)
                continue
            t += dt
            times.append(t)
    return times


def generate(kind: str, rate: float, n: int,
             tenants: Sequence[TenantSpec] = (TenantSpec("t0"),),
             seed: int = 0, trace: str = "bimodal", op: str = "eigh",
             lo: int = 6, hi: int = 48, **arrival_kw) -> List[Arrival]:
    """A timestamped per-tenant request stream: arrival times from the
    named process, dims from ``autotune.trace_dims`` (the same named
    shape mixes the autotuner replays), tenants drawn by ``share``."""
    times = arrival_times(kind, rate, n, seed=seed, **arrival_kw)
    dims = trace_dims(trace, n, lo=lo, hi=hi, seed=seed)
    shares = np.asarray([max(t.share, 0.0) for t in tenants], float)
    if shares.sum() <= 0:
        raise ValueError("tenant shares must sum > 0")
    picks = np.random.default_rng(seed + 7).choice(
        len(tenants), size=n, p=shares / shares.sum())
    out = []
    for i, (t, d) in enumerate(zip(times, dims)):
        shape = (d, d) if op == "eigh" else (4 * d, d)
        out.append(Arrival(t=t, tenant=tenants[int(picks[i])].name,
                           op=op, shape=shape, rid=i))
    return out


def merge(*streams: Sequence[Arrival]) -> List[Arrival]:
    """Interleave independently-generated per-tenant streams into one
    timeline (rids reassigned in arrival order) -- the skewed-mix story:
    a whale of large refits and a mouse of small interactive requests
    get *different* shape distributions, not just different shares."""
    merged = sorted((a for s in streams for a in s),
                    key=lambda a: (a.t, a.tenant, a.rid))
    return [dataclasses.replace(a, rid=i) for i, a in enumerate(merged)]


def materialize(arrival: Arrival, seed: int = 0) -> np.ndarray:
    """The request matrix for one arrival -- deterministic per (seed,
    rid), so admission order cannot change any request's contents."""
    rng = np.random.default_rng((seed, arrival.rid))
    return synthesize(arrival.op, arrival.shape, rng)


def profile_of(arrivals: Sequence[Arrival]) -> TrafficProfile:
    """The ``TrafficProfile`` describing this exact stream -- histogram,
    span and measured arrival rate -- ready for ``autotune``/``warmup``.
    This is the ROADMAP seam: plans are scored against offered load."""
    counts = collections.Counter((a.op, a.shape) for a in arrivals)
    span = (max(a.t for a in arrivals) - min(a.t for a in arrivals)
            if len(arrivals) > 1 else 0.0)
    return TrafficProfile.from_shapes(
        sorted((op, shape, c) for (op, shape), c in counts.items()),
        duration_s=float(span),
        arrival_rate=len(arrivals) / span if span > 0 else 0.0)


# ---------------------------------------------------------------------------
# fairness: token buckets and weighted fair queueing
# ---------------------------------------------------------------------------

class TokenBucket:
    """Per-tenant rate quota: ``rate`` tokens/s refill into a bucket of
    ``burst`` depth; a request takes one token or is throttled.
    ``rate <= 0`` means unlimited.  Time is injected per call, so the
    bucket is exact under a virtual clock."""

    def __init__(self, rate: float, burst: Optional[float] = None):
        self.rate = float(rate)
        self.burst = float(burst) if burst else max(self.rate, 1.0)
        self.tokens = self.burst
        self._t: Optional[float] = None

    def try_take(self, now: float, n: float = 1.0) -> bool:
        if self.rate <= 0:
            return True
        if self._t is None:
            self._t = now
        self.tokens = min(self.burst,
                          self.tokens + (now - self._t) * self.rate)
        self._t = now
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False


class FairQueue:
    """Tenant-fair scheduler ahead of ``PCAServer.submit``.

    ``wfq`` mode is start-time fair queueing over virtual time: each item
    gets tag = max(vtime, tenant's last finish), the tenant's finish
    advances by work/weight, and pop takes the minimum tag (ties by
    push order).  Popping advances vtime to the popped tag, so an idle
    tenant re-enters at *current* virtual time instead of burning its
    saved-up past -- the classic SFQ rule.  ``fifo`` mode is the
    baseline the benchmarks compare against.  A separate priority lane
    (``push(..., priority=True)``) always pops first, in FIFO order --
    the latency-critical bypass.

    Per-tenant queued work (in the same units as ``work``; the frontend
    uses predicted service seconds) is tracked for admission control.
    """

    def __init__(self, weights: Optional[Dict[str, float]] = None,
                 mode: str = "wfq"):
        if mode not in SCHEDULERS:
            raise ValueError(f"unknown mode {mode!r}; one of {SCHEDULERS}")
        self.mode = mode
        self.weights = dict(weights or {})
        self.vtime = 0.0
        self._finish: Dict[str, float] = {}
        self._heap: List[Tuple[float, int, str, float, object]] = []
        self._fifo: collections.deque = collections.deque()
        self._prio: collections.deque = collections.deque()
        self._seq = itertools.count()
        self._work: Dict[str, float] = collections.defaultdict(float)
        self._n: Dict[str, int] = collections.defaultdict(int)
        self._prio_work = 0.0

    def weight(self, tenant: str) -> float:
        return max(float(self.weights.get(tenant, 1.0)), 1e-9)

    def weight_share(self, tenant: str) -> float:
        """This tenant's share of total scheduler weight (all known
        tenants -- a stable, conservative denominator)."""
        names = set(self.weights) | {tenant}
        total = sum(self.weight(n) for n in names)
        return self.weight(tenant) / total if total > 0 else 1.0

    def push(self, tenant: str, item, work: float = 1.0,
             priority: bool = False) -> None:
        self._work[tenant] += work
        self._n[tenant] += 1
        if priority:
            self._prio_work += work
            self._prio.append((tenant, work, item))
        elif self.mode == "fifo":
            self._fifo.append((tenant, work, item))
        else:
            tag = max(self.vtime, self._finish.get(tenant, 0.0))
            self._finish[tenant] = tag + work / self.weight(tenant)
            heapq.heappush(self._heap,
                           (tag, next(self._seq), tenant, work, item))

    def pop(self) -> Tuple[str, float, object]:
        """(tenant, work, item) of the next request in fair order."""
        if self._prio:
            tenant, work, item = self._prio.popleft()
            self._prio_work -= work
        elif self.mode == "fifo":
            if not self._fifo:
                raise IndexError("pop from an empty FairQueue")
            tenant, work, item = self._fifo.popleft()
        else:
            if not self._heap:
                raise IndexError("pop from an empty FairQueue")
            tag, _, tenant, work, item = heapq.heappop(self._heap)
            self.vtime = max(self.vtime, tag)
        self._work[tenant] -= work
        self._n[tenant] -= 1
        return tenant, work, item

    def __len__(self) -> int:
        return len(self._prio) + len(self._fifo) + len(self._heap)

    def depth(self, tenant: str) -> int:
        return self._n[tenant]

    def queued_work(self, tenant: Optional[str] = None) -> float:
        if tenant is not None:
            return self._work[tenant]
        return sum(self._work.values())

    def priority_work(self) -> float:
        return self._prio_work


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    outcome: str          # "admit" | "degrade" | "shed"
    predicted_s: float    # service estimate for the variant chosen
    backlog_s: float      # backlog the decision saw


class AdmissionController:
    """Deadline feasibility at ingress.

    A request is feasible when predicted backlog + predicted service fits
    inside its SLO.  ``mode="none"`` admits everything (the unbounded-
    queueing baseline the benchmark beats); ``"shed"`` rejects infeasible
    requests outright; ``"degrade"`` first retries the feasibility check
    with a ``degrade_frac``-sweeps service estimate and admits the
    relaxed variant when *that* fits -- trading eigenvector accuracy for
    a kept deadline -- shedding only when even the cheap variant cannot
    make it.
    """

    def __init__(self, model: CostModel, policy, slo_s: Optional[float],
                 mode: str = "shed", degrade_frac: float = 0.5,
                 batch: int = 1):
        if mode not in ADMISSION_MODES:
            raise ValueError(
                f"unknown admission mode {mode!r}; one of {ADMISSION_MODES}")
        self.model = model
        self.policy = policy
        self.slo_s = slo_s
        self.mode = mode
        self.degrade_frac = float(degrade_frac)
        self.batch = int(batch)

    def service_s(self, op: str, shape, sweeps_frac: float = 1.0) -> float:
        return self.model.request_service_s(
            op, self.policy.bucket_shape(shape), batch=self.batch,
            sweeps_frac=sweeps_frac)

    def decide(self, op: str, shape, backlog_s: float,
               slo_s: Optional[float] = None) -> AdmissionDecision:
        slo = self.slo_s if slo_s is None else slo_s
        full = self.service_s(op, shape)
        if self.mode == "none" or slo is None or backlog_s + full <= slo:
            return AdmissionDecision("admit", full, backlog_s)
        if self.mode == "degrade":
            deg = self.service_s(op, shape, self.degrade_frac)
            if backlog_s + deg <= slo:
                return AdmissionDecision("degrade", deg, backlog_s)
        return AdmissionDecision("shed", full, backlog_s)


# ---------------------------------------------------------------------------
# the frontend
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FrontendReport:
    """One open-loop run's accounting (plain JSON via ``to_json``)."""
    requests: int
    served: int
    degraded: int
    shed: int
    throttled: int
    duration_s: float
    offered_rps: float
    goodput_rps: float        # SLO-compliant completions / duration
    served_rps: float         # all completions / duration
    shed_frac: float          # (shed + throttled) / requests
    per_tenant: Dict[str, Dict]
    outcomes: Dict[int, str]  # rid -> served|degraded|shed|throttled
    digest: str               # sha256 over (rid, outcome, result bytes)

    @property
    def worst_tenant_goodput_rps(self) -> float:
        rows = [r.get("goodput_rps", 0.0)
                for r in self.per_tenant.values()]
        return min(rows) if rows else 0.0

    def to_json(self) -> Dict:
        doc = dataclasses.asdict(self)
        doc.pop("outcomes")
        doc["worst_tenant_goodput_rps"] = self.worst_tenant_goodput_rps
        return doc


class TrafficFrontend:
    """Open-loop traffic in front of one ``PCAServer``.

    Args:
      server: the engine to drive; its clock is shared (pass the same
        ``VirtualClock`` for deterministic runs).
      tenants: the tenant set (weights, quotas, priority, SLO overrides).
      slo_ms: default deadline; per-tenant ``slo_ms`` overrides it.
      scheduler: "wfq" | "fifo".
      admission: "none" | "shed" | "degrade".
      model: ``CostModel`` for service prediction; calibrate it from a
        profile of the same stream for honest admission estimates.
      degrade_frac: sweeps fraction of the degrade variant (the actual
        sweep count is ``max(1, round(config.sweeps * degrade_frac))``).
      accounting: optional ``repro.obs.TenantAccounting`` to mirror
        tenant-labeled counters/latency/goodput into a metric registry.
      seed: matrix-content seed (see ``materialize``).
    """

    def __init__(self, server, tenants: Sequence[TenantSpec],
                 slo_ms: Optional[float] = None, scheduler: str = "wfq",
                 admission: str = "shed",
                 model: Optional[CostModel] = None,
                 degrade_frac: float = 0.5, accounting=None, seed: int = 0):
        self.server = server
        self.tenants = {t.name: t for t in tenants}
        self.slo_s = slo_ms / 1e3 if slo_ms is not None else None
        self.model = model or CostModel()
        self.queue = FairQueue({t.name: t.weight for t in tenants},
                               mode=scheduler)
        self.buckets = {t.name: TokenBucket(t.rate_limit, t.burst or None)
                        for t in tenants}
        self.admission = AdmissionController(
            self.model, server.policy, self.slo_s, mode=admission,
            degrade_frac=degrade_frac, batch=server.max_batch)
        self.accounting = accounting
        self.seed = seed
        self.degrade_sweeps = max(
            1, int(round(server.config.sweeps * degrade_frac)))

    def set_cost_model(self, model: CostModel) -> None:
        """Swap the admission cost model in place -- the controller's
        feedback path.  After a plan hot-swap the server's policy and
        batch cap may have moved too, so admission re-reads both: a
        feasibility verdict should price the plan actually in force, not
        the one the frontend was built against."""
        self.model = model
        self.admission.model = model
        self.admission.policy = self.server.policy
        self.admission.batch = self.server.max_batch

    # -- shared admission math ----------------------------------------------
    def _slo_for(self, tenant: str) -> Optional[float]:
        spec = self.tenants[tenant]
        return spec.slo_ms / 1e3 if spec.slo_ms is not None else self.slo_s

    def _backlog_s(self, tenant: str, residual_s: float) -> float:
        """Scheduler-aware backlog: what *this* tenant's next request
        would wait.  Work already on the server (``residual_s``) delays
        everyone; scheduler queue wait depends on the discipline -- under
        WFQ a tenant's queue drains at its weight share of capacity, so
        a light tenant is not charged for a whale's backlog."""
        spec = self.tenants[tenant]
        if spec.priority:
            return residual_s + self.queue.priority_work()
        if self.queue.mode == "fifo":
            return residual_s + self.queue.queued_work()
        share = self.queue.weight_share(tenant)
        return (residual_s + self.queue.priority_work()
                + self.queue.queued_work(tenant) / share)

    def _ingest(self, a: Arrival, now: float,
                residual_s: float) -> Optional[Tuple]:
        """Token bucket + admission for one arrival; returns the queue
        entry (arrival, matrix, sweeps, t_ingress) or None when the
        request was throttled/shed.  Outcome accounting for the rejected
        paths happens here; served/degraded land at completion."""
        controller = getattr(self.server, "controller", None)
        if controller is not None:
            # the virtual-time run never calls server.poll(), so the
            # arrival stream is the controller's clock source there; the
            # paced run double-ticks harmlessly (cadence-guarded no-op)
            controller.maybe_tick(now)
        spec = self.tenants[a.tenant]
        if not self.buckets[a.tenant].try_take(now):
            self._outcome(a, "throttled", now)
            return None
        decision = self.admission.decide(
            a.op, a.shape, self._backlog_s(a.tenant, residual_s),
            self._slo_for(a.tenant))
        if decision.outcome == "shed":
            self._outcome(a, "shed", now)
            return None
        sweeps = (self.degrade_sweeps if decision.outcome == "degrade"
                  else None)
        entry = (a, materialize(a, self.seed), sweeps, now)
        self.queue.push(a.tenant, entry, work=decision.predicted_s,
                        priority=spec.priority)
        if self.accounting is not None:
            self.accounting.queue_depth(a.tenant,
                                        self.queue.depth(a.tenant), now=now)
        return entry

    def _outcome(self, a: Arrival, outcome: str, now: float) -> None:
        self._outcomes[a.rid] = outcome
        if self.accounting is not None:
            self.accounting.outcome(a.tenant, outcome, now=now)

    # -- run ----------------------------------------------------------------
    def run(self, arrivals: Sequence[Arrival],
            pace: bool = False) -> FrontendReport:
        """Drive the server through one arrival stream.

        ``pace=False`` (default): single-threaded virtual-time run -- the
        server's clock must be a ``VirtualClock``; completions are modeled
        off ``CostModel`` service seconds (``busy_until`` horizon), which
        makes the whole run -- admission split, WFQ order, results --
        bit-deterministic in (arrivals, seed).  ``pace=True``: wall-clock
        replay through feeder/worker threads; latencies are measured on
        the real server (the benchmark path).
        """
        self._outcomes: Dict[int, str] = {}
        arrivals = sorted(arrivals, key=lambda a: (a.t, a.rid))
        if not arrivals:
            raise ValueError("empty arrival stream")
        if pace:
            completions, span = self._run_paced(arrivals)
        else:
            completions, span = self._run_virtual(arrivals)
        return self._report(arrivals, completions, span)

    def _run_virtual(self, arrivals):
        clock = self.server.clock
        if not isinstance(clock, VirtualClock):
            raise TypeError(
                "pace=False needs the server built on a VirtualClock "
                "(PCAServer(..., clock=VirtualClock()))")
        busy = clock()                     # modeled service horizon
        completions = []                   # (arrival, ticket, t_done)

        def drain_until(t_limit):
            nonlocal busy
            while len(self.queue) and busy < t_limit:
                tenant, work, (a, mat, sweeps, _) = self.queue.pop()
                clock.set(busy)
                ticket = self.server.submit(mat, op=a.op, sweeps=sweeps)
                busy += work
                completions.append((a, ticket, busy))

        for a in arrivals:
            drain_until(a.t)
            now = clock.set(a.t)
            self._ingest(a, now, residual_s=max(0.0, busy - now))
        drain_until(float("inf"))
        clock.set(busy)
        self.server.drain()
        t0 = arrivals[0].t
        t_end = max([busy] + [t for _, _, t in completions])
        return ([(a, tk, t_done - a.t) for a, tk, t_done in completions],
                max(t_end - t0, 1e-9))

    def _run_paced(self, arrivals):
        clock = self.server.clock
        lock = threading.Lock()
        cond = threading.Condition(lock)
        busy = [clock()]                   # modeled horizon, shared
        completions = []                   # (arrival, ticket, t_ingress)
        feeding = [True]

        def worker():
            while True:
                with cond:
                    if not len(self.queue) and feeding[0]:
                        cond.wait(0.005)
                    if not len(self.queue):
                        if not feeding[0]:
                            return
                        popped = None
                    else:
                        popped = self.queue.pop()
                        _, work, _ = popped
                        busy[0] = max(busy[0], clock()) + work
                if popped is None:
                    # idle tick: flush partial batches whose deadline
                    # passed, retire completed in-flight work
                    self.server.poll()
                    continue
                _, _, (a, mat, sweeps, t_in) = popped
                # submit outside the lock: this is where engine
                # backpressure (flush-on-full + in-flight cap) bites, and
                # the feeder must keep pacing meanwhile
                ticket = self.server.submit(mat, op=a.op, sweeps=sweeps)
                self.server.poll()
                with lock:
                    completions.append((a, ticket, t_in))

        th = threading.Thread(target=worker, daemon=True)
        th.start()
        t0 = clock()
        first_t = arrivals[0].t
        for a in arrivals:
            target = t0 + (a.t - first_t)
            now = clock()
            if now < target:
                time.sleep(target - now)
                now = clock()
            with cond:
                residual = max(0.0, busy[0] - now)
                if self._ingest(a, now, residual) is not None:
                    cond.notify()
        with cond:
            feeding[0] = False
            cond.notify_all()
        th.join()
        self.server.drain()
        t_end = clock()
        out = []
        for a, ticket, t_in in completions:
            rec = ticket.record
            t_done = rec.t_done if rec is not None else clock()
            out.append((a, ticket, t_done - t_in))
        return out, max(t_end - t0, 1e-9)

    # -- accounting ---------------------------------------------------------
    def _report(self, arrivals, completions, span) -> FrontendReport:
        per_tenant: Dict[str, Dict] = {
            name: {"served": 0, "degraded": 0, "shed": 0, "throttled": 0,
                   "slo_ok": 0, "latencies_ms": []}
            for name in self.tenants}
        h = hashlib.sha256()
        ok_total = 0
        for a, ticket, latency in sorted(completions,
                                         key=lambda c: c[0].rid):
            outcome = ("degraded" if ticket.sweeps < self.server.config.sweeps
                       else "served")
            self._outcomes[a.rid] = outcome
            slo = self._slo_for(a.tenant)
            ok = slo is None or latency <= slo
            ok_total += int(ok)
            row = per_tenant[a.tenant]
            row[outcome] += 1
            row["slo_ok"] += int(ok)
            row["latencies_ms"].append(latency * 1e3)
            h.update(f"{a.rid}:{outcome}".encode())
            for part in _result_arrays(ticket.result()):
                h.update(np.ascontiguousarray(part).tobytes())
            if self.accounting is not None:
                self.accounting.outcome(a.tenant, outcome)
                self.accounting.served(a.tenant, latency, ok)
        tenant_of = {a.rid: a.tenant for a in arrivals}
        for a in arrivals:
            if a.rid not in self._outcomes:   # defensive: lost entries
                self._outcomes[a.rid] = "shed"
        for rid in sorted(self._outcomes):
            if self._outcomes[rid] in ("shed", "throttled"):
                h.update(f"{rid}:{self._outcomes[rid]}".encode())
                per_tenant[tenant_of[rid]][self._outcomes[rid]] += 1
        counts = collections.Counter(self._outcomes.values())
        for name, row in per_tenant.items():
            lats = row.pop("latencies_ms")
            row["latency_p50_ms"] = (float(np.percentile(lats, 50))
                                     if lats else 0.0)
            row["latency_p99_ms"] = (float(np.percentile(lats, 99))
                                     if lats else 0.0)
            row["goodput_rps"] = row["slo_ok"] / span
            if self.accounting is not None:
                self.accounting.goodput(name, row["goodput_rps"])
        n = len(arrivals)
        return FrontendReport(
            requests=n,
            served=counts["served"],
            degraded=counts["degraded"],
            shed=counts["shed"],
            throttled=counts["throttled"],
            duration_s=span,
            offered_rps=n / span,
            goodput_rps=ok_total / span,
            served_rps=len(completions) / span,
            shed_frac=(counts["shed"] + counts["throttled"]) / n,
            per_tenant=per_tenant,
            outcomes=dict(self._outcomes),
            digest=h.hexdigest())


def _result_arrays(result) -> List[np.ndarray]:
    """Every array inside a served result (ServedEigh/SVD/PCA dataclass,
    tuple, or bare array), in field order, for digesting."""
    if dataclasses.is_dataclass(result):
        out = []
        for f in dataclasses.fields(result):
            out.extend(_result_arrays(getattr(result, f.name)))
        return out
    if isinstance(result, (tuple, list)):
        out = []
        for part in result:
            out.extend(_result_arrays(part))
        return out
    return [np.asarray(result)]
