"""Batched PCA/SVD solvers: the paper's S-array axis realized with vmap.

MANOJAVAM(T, S) instantiates S independent TxT systolic arrays; here the S
axis becomes a leading batch dimension over ``vmap``-ed Jacobi solves, so one
compiled executable retires S independent problems per dispatch.  All three
pivot strategies ("parallel" / "cyclic" / "paper") and both rotation modes
("rowcol" / "matmul") vmap cleanly: the sweep machinery is pure lax
control flow and the DLE argmax batches element-wise.

Backend dispatch: every matmul in these solvers flows through the injected
``matmul_fn`` (or the ``config.backend`` name on ``pca_fit_batched``), which
``PCAServer`` resolves per bucket via its ``backend_router`` -- so one server
can retire a large bucket on the Pallas MM-Engine while a small bucket stays
on plain XLA, each under its own backend-qualified cached executable.

Bucket-padding contract: inputs arrive zero-padded into a shared bucket
(``serving.batching``) with per-problem true sizes ``n_active``.  The
zero-pivot guard in ``core.jacobi`` makes every rotation that touches a
padded coordinate the *exact* identity, so the padded block of C stays
exactly zero and eigenvector columns of padded coordinates remain exact
basis vectors e_j at their original positions.  That invariant is what lets
``_masked_sort`` recover the embedded problem's descending eigenpairs with a
pure O(n log n) reorder -- no per-problem dynamic shapes anywhere.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jacobi import DEFAULT_SWEEPS, jacobi_eigh
from repro.core.pca import PCAConfig, evcr_cvcr


class BatchedEighResult(NamedTuple):
    eigenvalues: jnp.ndarray   # (B, nb) descending per problem, padded tail 0
    eigenvectors: jnp.ndarray  # (B, nb, nb) columns pair with eigenvalues
    off_norm: jnp.ndarray      # (B,) final relative off-diagonal norms
    n_active: jnp.ndarray      # (B,) true problem sizes


class BatchedSVDResult(NamedTuple):
    U: jnp.ndarray             # (B, mb, nb)
    S: jnp.ndarray             # (B, nb) descending, padded tail 0
    Vt: jnp.ndarray            # (B, nb, nb)
    n_rows: jnp.ndarray        # (B,)
    n_cols: jnp.ndarray        # (B,)


class BatchedPCAResult(NamedTuple):
    components: jnp.ndarray    # (B, nb, nb) eigenvector columns, descending
    eigenvalues: jnp.ndarray   # (B, nb)
    mean: jnp.ndarray          # (B, nb)
    scale: jnp.ndarray         # (B, nb)
    evcr: jnp.ndarray          # (B, nb)
    cvcr: jnp.ndarray          # (B, nb)
    off_norm: jnp.ndarray      # (B,)
    n_rows: jnp.ndarray        # (B,)
    n_cols: jnp.ndarray        # (B,)


def _as_n_active(n_active, batch: int, full: int):
    if n_active is None:
        return jnp.full((batch,), full, jnp.int32)
    return jnp.asarray(n_active, jnp.int32)


def _masked_sort(w, V, n_active):
    """Descending sort of the *live* eigenpairs; padded pairs go last.

    Padded coordinates hold exact zero eigenvalues, which would interleave
    with a mixed-sign live spectrum under a plain sort.  Scoring padded
    slots at -inf pushes them behind every live eigenvalue, so slots
    [0, n_active) are exactly the embedded problem's descending eigenpairs.
    """
    nb = w.shape[-1]
    ids = jnp.arange(nb)
    live = ids < n_active
    score = jnp.where(live, w, -jnp.inf)
    order = jnp.argsort(-score)
    w = jnp.where(live, w[order], jnp.zeros_like(w))
    V = V[:, order]
    return w, V


def jacobi_eigh_batched(
    C,
    n_active=None,
    sweeps: int = DEFAULT_SWEEPS,
    pivot: str = "parallel",
    rotation: str = "rowcol",
    angle: str = "rutishauser",
    matmul_fn: Optional[Callable] = None,
    tol: Optional[float] = None,
    sort: bool = True,
    fused: bool = False,
    fused_backend: Optional[str] = None,
) -> BatchedEighResult:
    """Batched symmetric eigendecomposition over a shape bucket.

    Args:
      C: (B, nb, nb) zero-padded symmetric matrices sharing one bucket.
      n_active: (B,) true sizes (None = all full).  Rows/cols >= n_active[i]
        must be zero; they provably never mix (null-pivot guard).
      remaining args: as ``core.jacobi.jacobi_eigh`` (``fused`` vmaps the
        one-launch-per-round ``jacobi_sweep`` kernel across the batch).
    """
    C = jnp.asarray(C)
    if C.ndim != 3:
        raise ValueError(f"expected (B, n, n) batch, got shape {C.shape}")
    n_active = _as_n_active(n_active, C.shape[0], C.shape[-1])

    def solve(c):
        return jacobi_eigh(c, sweeps=sweeps, pivot=pivot, rotation=rotation,
                           angle=angle, matmul_fn=matmul_fn, tol=tol,
                           sort=False, fused=fused,
                           fused_backend=fused_backend)

    res = jax.vmap(solve)(C)
    w, V = res.eigenvalues, res.eigenvectors
    if sort:
        w, V = jax.vmap(_masked_sort)(w, V, n_active)
    return BatchedEighResult(w, V, res.off_norm, n_active)


def jacobi_svd_batched(
    A,
    n_rows=None,
    n_cols=None,
    matmul_fn: Optional[Callable] = None,
    rcond: Optional[float] = None,
    fused: bool = False,
    fused_backend: Optional[str] = None,
    precision: str = "fp32",
    **eigh_kwargs,
) -> BatchedSVDResult:
    """Batched thin SVD via the Gram-matrix path (paper PCA datapath).

    A: (B, mb, nb) zero-padded.  All three matmuls (Gram, rotations, the
    U = A V back-projection) share the injected ``matmul_fn`` datapath.

    Rank deficiency: the back-projection U = A V / s divides by singular
    values the Gram path cannot resolve below ~sqrt(eps) * s_max -- for a
    rank-deficient *live* input (s ~ 0 inside n_cols) that division
    amplifies rounding noise in A V into garbage U columns.  Columns whose
    singular value falls below ``rcond * s_max`` are therefore zeroed
    exactly (their live counterparts keep bit-identical values: the mask
    only ever turns noise into zeros).  ``rcond`` defaults to
    sqrt(nb * eps_f32), a few times the Gram path's own noise floor.
    """
    A = jnp.asarray(A)
    if A.ndim != 3:
        raise ValueError(f"expected (B, m, n) batch, got shape {A.shape}")
    B, mb, nb = A.shape
    n_rows = _as_n_active(n_rows, B, mb)
    n_cols = _as_n_active(n_cols, B, nb)
    mm = matmul_fn or jnp.matmul
    if fused:
        from repro.kernels import ops as kops
        gram = jax.vmap(lambda a: kops.covariance(
            a, precision=precision, backend=fused_backend))(A)
    else:
        gram = jax.vmap(lambda a: mm(a.T, a))(A)
    res = jacobi_eigh_batched(gram, n_active=n_cols, matmul_fn=matmul_fn,
                              fused=fused, fused_backend=fused_backend,
                              **eigh_kwargs)
    s = jnp.sqrt(jnp.maximum(res.eigenvalues, 0.0))
    safe = jnp.maximum(s, 1e-30)
    if rcond is None:
        rcond = float(np.sqrt(nb * np.finfo(np.float32).eps))
    # relative cutoff per problem; an all-zero problem (s_max == 0) has no
    # live column at all and U comes out exactly zero
    cutoff = rcond * jnp.max(s, axis=-1, keepdims=True)
    live = s > cutoff
    U = jnp.where(live[:, None, :],
                  jax.vmap(mm)(A, res.eigenvectors) / safe[:, None, :],
                  0.0)
    Vt = jnp.swapaxes(res.eigenvectors, -1, -2)
    return BatchedSVDResult(U, s, Vt, n_rows, n_cols)


def _masked_standardize(X, m, d, eps: float = 1e-8):
    """Per-feature zero-mean / unit-variance over the live (m, d) block.

    Padded rows must not bias the moments and padded entries must stay
    exactly zero afterwards (X - mean is nonzero on padded rows), so both
    masks are applied explicitly.  Matches ``core.covariance.standardize``
    (ddof=0) on an exact-fit matrix.
    """
    mb, db = X.shape
    rmask = (jnp.arange(mb) < m)[:, None].astype(X.dtype)
    cmask = (jnp.arange(db) < d).astype(X.dtype)
    cnt = jnp.maximum(m, 1).astype(X.dtype)
    mean = jnp.sum(X * rmask, axis=0) / cnt
    diff = (X - mean[None, :]) * rmask
    var = jnp.sum(diff * diff, axis=0) / cnt
    std = jnp.sqrt(var)
    std = jnp.where(std < eps, jnp.ones_like(std), std)
    return (diff / std[None, :]) * cmask[None, :], mean * cmask, std


def pca_fit_batched(
    X,
    n_rows=None,
    n_cols=None,
    config: PCAConfig = PCAConfig(),
) -> BatchedPCAResult:
    """Batched PCA fit (paper Alg. 1 across the S axis).

    X: (B, mb, db) zero-padded data matrices sharing one bucket; per-problem
    true shapes in (n_rows, n_cols).  EVCR/CVCR are computed over the live
    spectrum only (padded eigenvalues are exactly zero, so they contribute
    nothing to the totals).
    """
    X = jnp.asarray(X)
    if X.ndim != 3:
        raise ValueError(f"expected (B, m, d) batch, got shape {X.shape}")
    B, mb, db = X.shape
    n_rows = _as_n_active(n_rows, B, mb)
    n_cols = _as_n_active(n_cols, B, db)
    mm = config.matmul_fn() or jnp.matmul

    if config.standardize:
        Xs, mean, scale = jax.vmap(_masked_standardize)(X, n_rows, n_cols)
    else:
        Xs = X
        mean = jnp.zeros((B, db), X.dtype)
        scale = jnp.ones((B, db), X.dtype)
    if config.fused:
        from repro.kernels import ops as kops
        C = jax.vmap(lambda x: kops.covariance(
            x, precision=config.precision, backend=config.backend))(Xs)
    else:
        C = jax.vmap(lambda x: mm(x.T, x))(Xs)
    res = jacobi_eigh_batched(
        C, n_active=n_cols, sweeps=config.sweeps, pivot=config.pivot,
        rotation=config.rotation, angle=config.angle,
        matmul_fn=config.matmul_fn(), tol=config.tol,
        fused=config.fused, fused_backend=config.backend)
    evcr, cvcr = jax.vmap(evcr_cvcr)(res.eigenvalues)
    return BatchedPCAResult(res.eigenvectors, res.eigenvalues, mean, scale,
                            evcr, cvcr, res.off_norm, n_rows, n_cols)


def build_solver_fn(op: str, config: PCAConfig) -> Callable:
    """The un-jitted batched solver for one op under one config.

    Uniform signature ``(batch, n_rows, n_cols) -> result`` across all three
    ops (eigh ignores the redundant column counts: the two n_active axes of a
    square bucket coincide), so the serving executors can jit it with
    whatever device placement they own -- plain ``jax.jit`` on the default
    executor, batch-axis ``NamedSharding``s on the mesh executor.
    """
    kw = dict(sweeps=config.sweeps, pivot=config.pivot,
              rotation=config.rotation, angle=config.angle, tol=config.tol,
              matmul_fn=config.matmul_fn(),
              fused=config.fused, fused_backend=config.backend)
    if op == "eigh":
        return lambda C, nr, nc: jacobi_eigh_batched(C, nr, **kw)
    if op == "svd":
        return lambda A, nr, nc: jacobi_svd_batched(
            A, nr, nc, precision=config.precision, **kw)
    if op == "pca":
        return lambda X, nr, nc: pca_fit_batched(X, nr, nc, config=config)
    raise ValueError(f"unknown op {op!r}")


def pca_transform_batched(X, result: BatchedPCAResult, k: int,
                          matmul_fn: Optional[Callable] = None):
    """Batched top-k projection O = X_std V_k (paper eq. 5)."""
    mm = matmul_fn or jnp.matmul
    X = jnp.asarray(X)
    scale = jnp.where(result.scale == 0.0, 1.0, result.scale)
    rmask = (jnp.arange(X.shape[1])[None, :]
             < result.n_rows[:, None]).astype(X.dtype)
    Xs = (X - result.mean[:, None, :]) / scale[:, None, :] * rmask[:, :, None]
    return jax.vmap(lambda x, v: mm(x, v[:, :k]))(Xs, result.components)
