"""Serving telemetry: per-request latency, queue depth, padding waste,
throughput -- plus predicted-vs-measured hooks into the analytical fabric
model (``core.memory_model``), so measured service latency can be compared
against what a MANOJAVAM(T, S) fabric would promise for the same request
stream (the paper's Sec. VII-A simulator, now fed by live traffic).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import memory_model


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    rid: int
    op: str                    # "eigh" | "svd" | "pca"
    shape: Tuple[int, ...]     # true shape
    bucket: Tuple[int, ...]    # padded shape
    batch_size: int            # device batch it rode in
    cache_hit: bool            # executable cache hit at flush time
    t_submit: float
    t_done: float              # retirement time (results on host)
    queue_s: float             # time spent waiting before the dispatch
    padding_waste: float       # 1 - true_area / bucket_area
    backend: Optional[str] = None  # kernel backend the bucket routed to
                                   # (None = plain XLA matmul datapath);
                                   # always a concrete name, never "auto"
    n_shards: int = 1          # data-axis shards the flush spread over
                               # (1 = single-device LocalExecutor)
    t_dispatch: float = 0.0    # when the flush launched (non-blocking)
    inflight_depth: int = 1    # outstanding flushes right after dispatch
                               # (1 = synchronous engine)
    deadline: float = float("inf")  # flush-by time (submit + max_delay);
                                    # inf = no deadline was tracked
    sweeps: Optional[int] = None    # Jacobi sweeps the request ran with
                                    # (None = pre-degrade-path record)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def deadline_missed(self) -> bool:
        """Fulfilled after its flush deadline had already passed."""
        return self.t_done > self.deadline

    @property
    def inflight_s(self) -> float:
        """Dispatch-to-retire span (device execution + pipeline residency)."""
        return self.t_done - self.t_dispatch


@dataclasses.dataclass(frozen=True)
class FlushRecord:
    """Per-flush pipeline accounting (the dispatch/retire split).

    ``t_dispatch`` is when the dispatch stage began (pre-stack),
    ``t_launched`` when the non-blocking launch returned (host free again),
    ``t_wait`` when the engine finally blocked on the flush, ``t_retire``
    when its results were on host.  Of the in-flight window
    [t_launched, t_retire], the part up to ``t_wait`` is device execution
    the host *overlapped* with other work (batching or retiring
    neighbours) and the rest is the un-hidden remainder; the flush's own
    dispatch-stage host cost (``dispatch_s``) precedes the window.  A
    synchronous engine (max_inflight=1) blocks immediately after
    launching, so overlap_s ~ 0; a deep pipeline pushes overlap_frac
    toward 1 -- that is the measured host/device overlap the benchmark
    reports.
    """
    t_dispatch: float
    t_launched: float
    t_wait: float
    t_retire: float
    batch_size: int
    cache_hit: bool
    inflight_depth: int        # outstanding flushes right after dispatch
    op: str = ""               # which solver the flush ran
    bucket: Tuple[int, ...] = ()   # the flush's shape bucket
    padded_batch: int = 0      # device batch after padding/rounding (the
                               # slab the executable actually consumed;
                               # padded_batch - batch_size is inert filler)

    @property
    def dispatch_s(self) -> float:
        """Host cost of the dispatch stage (stack/pad/cache-lookup/launch)."""
        return self.t_launched - self.t_dispatch

    @property
    def overlap_s(self) -> float:
        return self.t_wait - self.t_launched

    @property
    def wait_s(self) -> float:
        return self.t_retire - self.t_wait


def percentile(xs: Sequence[float], p: float) -> float:
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), p))


class ServingStats:
    """Accumulates serving telemetry; cheap to record, summarised on demand.

    Per-request histories are bounded ring buffers (``max_records``) so a
    long-running server's telemetry stays O(1) in traffic volume; counters
    (flushes, cache hits) are lifetime totals.
    """

    def __init__(self, clock=time.monotonic, max_records: int = 65536):
        self.clock = clock
        self.records: Deque[RequestRecord] = collections.deque(
            maxlen=max_records)
        self.queue_depths: Deque[Tuple[float, int]] = collections.deque(
            maxlen=max_records)
        self.inflight_depths: Deque[Tuple[float, int]] = collections.deque(
            maxlen=max_records)
        self.flush_records: Deque[FlushRecord] = collections.deque(
            maxlen=max_records)
        self.plan_switches: Deque[Dict] = collections.deque(
            maxlen=max_records)
        self.flushes = 0
        self.cache_hits = 0
        self.cache_misses = 0

    # -- recording ----------------------------------------------------------
    def record_request(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def record_queue_depth(self, depth: int, now: Optional[float] = None) -> None:
        self.queue_depths.append((self.clock() if now is None else now, depth))

    def record_dispatch(self, depth: int,
                        now: Optional[float] = None) -> None:
        """In-flight depth right after a flush launched."""
        self.inflight_depths.append(
            (self.clock() if now is None else now, depth))

    def record_flush(self, cache_hit: bool, *,
                     t_dispatch: Optional[float] = None,
                     t_launched: Optional[float] = None,
                     t_wait: Optional[float] = None,
                     t_retire: Optional[float] = None,
                     batch_size: int = 0,
                     inflight_depth: int = 1,
                     op: str = "",
                     bucket: Tuple[int, ...] = (),
                     padded_batch: int = 0) -> None:
        self.flushes += 1
        if cache_hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        if t_dispatch is not None:
            self.flush_records.append(FlushRecord(
                t_dispatch=t_dispatch,
                t_launched=t_dispatch if t_launched is None else t_launched,
                t_wait=t_dispatch if t_wait is None else t_wait,
                t_retire=t_dispatch if t_retire is None else t_retire,
                batch_size=batch_size, cache_hit=cache_hit,
                inflight_depth=inflight_depth, op=op, bucket=tuple(bucket),
                padded_batch=padded_batch))

    def record_plan_switch(self, switch: Dict,
                           now: Optional[float] = None) -> None:
        """One ``PCAServer.apply_plan`` hot-swap (old plan, new plan,
        how many queued requests were re-bucketed)."""
        self.plan_switches.append(
            {"t": self.clock() if now is None else now, **switch})

    def reset(self) -> None:
        self.records.clear()
        self.queue_depths.clear()
        self.inflight_depths.clear()
        self.flush_records.clear()
        self.plan_switches.clear()
        self.flushes = self.cache_hits = self.cache_misses = 0

    # -- summaries ----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        # empty percentiles are 0.0, not NaN: profile capture on an idle
        # server (serving.autotune) must produce a well-defined, JSON-clean
        # summary, and NaN would poison every downstream aggregate
        def pct(xs, p):
            return percentile(xs, p) if len(xs) else 0.0

        lat = [r.latency_s for r in self.records]
        if self.records:
            span = (max(r.t_done for r in self.records)
                    - min(r.t_submit for r in self.records))
        else:
            span = 0.0
        depths = [d for _, d in self.queue_depths]
        inflight = [d for _, d in self.inflight_depths]
        # measured host/device overlap: of every flush's in-flight window
        # (launch-to-retire; the flush's own dispatch-stage host cost
        # precedes the launch and is excluded), how much did the host
        # spend doing other work (batching / retiring neighbours) rather
        # than blocked waiting
        overlap_s = float(sum(f.overlap_s for f in self.flush_records))
        span_s = overlap_s + float(sum(f.wait_s for f in self.flush_records))
        deadline_misses = sum(1 for r in self.records if r.deadline_missed)
        return {
            "requests": len(self.records),
            "wall_s": span,
            "requests_per_s": len(self.records) / span if span > 0 else 0.0,
            "latency_p50_ms": pct(lat, 50) * 1e3,
            "latency_p99_ms": pct(lat, 99) * 1e3,
            "queue_p50_ms": pct(
                [r.queue_s for r in self.records], 50) * 1e3,
            "mean_batch": (float(np.mean([r.batch_size for r in self.records]))
                           if self.records else 0.0),
            "mean_padding_waste": (
                float(np.mean([r.padding_waste for r in self.records]))
                if self.records else 0.0),
            "max_queue_depth": max(depths) if depths else 0,
            "mean_shards": (float(np.mean([r.n_shards for r in self.records]))
                            if self.records else 0.0),
            "max_shards": (max(r.n_shards for r in self.records)
                           if self.records else 0),
            "flushes": self.flushes,
            "cache_hit_rate": (self.cache_hits / self.flushes
                               if self.flushes else 0.0),
            "mean_inflight_depth": (float(np.mean(inflight))
                                    if inflight else 0.0),
            "max_inflight_depth": max(inflight) if inflight else 0,
            "overlap_frac": (overlap_s / span_s if span_s > 0 else 0.0),
            "overlap_s": overlap_s,
            "plan_switches": len(self.plan_switches),
            "deadline_miss_count": deadline_misses,
            "deadline_miss_frac": (deadline_misses / len(self.records)
                                   if self.records else 0.0),
        }

    # -- fabric-model hooks -------------------------------------------------
    @staticmethod
    def predicted_seconds(op: str, shape: Tuple[int, ...],
                          fabric: memory_model.FabricConfig =
                          memory_model.VIRTEX_US) -> float:
        """What the analytical MANOJAVAM(T, S) model promises per request."""
        f = fabric.freq_mhz * 1e6
        if op == "eigh":
            return memory_model.jacobi_cycles(shape[0], fabric) / f
        m, n = shape[0], shape[1]
        est = memory_model.pca_seconds(m, n, fabric,
                                       include_projection=(op == "pca"))
        return est["total_s"] if op == "pca" else est["covariance_s"] + est["svd_s"]

    def predicted_vs_measured(self, fabric: memory_model.FabricConfig =
                              memory_model.VIRTEX_US) -> List[Dict[str, float]]:
        """Per-request (predicted fabric latency, measured service latency).

        The measured number includes queueing + batching + dispatch; the
        predicted number is pure fabric compute -- the gap is the serving
        overhead the engine exists to amortise.
        """
        out = []
        for r in self.records:
            pred = self.predicted_seconds(r.op, r.shape, fabric)
            out.append({
                "rid": r.rid,
                "op": r.op,
                "predicted_s": pred,
                "measured_s": r.latency_s,
                "ratio": r.latency_s / pred if pred > 0 else float("inf"),
            })
        return out
