from . import checkpointer
from .checkpointer import all_steps, latest_step, restore, save
