"""Atomic, versioned checkpointing with reshard-on-load (elastic restart).

Layout:
  <dir>/step_<n>.tmp/...   (written, fsynced)
  <dir>/step_<n>/          (atomic rename = commit)
  <dir>/step_<n>/manifest.json   (paths, shapes, dtypes, user metadata)
  leaves stored as .npy keyed by their pytree path

Restore takes an optional tree of ``NamedSharding``s and device_puts each
leaf to it -- so a checkpoint written on a 16x16 mesh restores onto 8x8 or
2x16x16 unchanged (elastic scaling), and shape/dtype are validated against
the manifest before any state is touched.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
from typing import Any, Dict, Optional

import numpy as np
import jax


def _path_key(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return "__".join(out) or "root"


def save(directory, step: int, state, metadata: Optional[Dict] = None,
         keep: int = 3) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for path, leaf in flat:
        key = _path_key(path)
        arr = np.asarray(leaf)
        np.save(tmp / f"{key}.npy", arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)          # atomic commit
    _retain(d, keep)
    return final


def _retain(d: pathlib.Path, keep: int):
    steps = sorted(all_steps(d))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)


def all_steps(directory) -> list:
    d = pathlib.Path(directory)
    out = []
    for p in d.glob("step_*"):
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory, state_like, step: Optional[int] = None,
            shardings: Optional[Any] = None):
    """Load ``step`` (default: latest) into the structure of ``state_like``.

    ``shardings``: optional matching tree of jax.sharding.Sharding; each
    leaf is device_put onto it (reshard-on-load -- the saved mesh does not
    need to match the current one).
    Returns (state, metadata).
    """
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {d}")
    cdir = d / f"step_{step}"
    manifest = json.loads((cdir / "manifest.json").read_text())

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(flat))
    out = []
    for (path, like), sh in zip(flat, sh_leaves):
        key = _path_key(path)
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint {cdir} missing leaf {key}")
        arr = np.load(cdir / f"{key}.npy")
        want_shape = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {want_shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(
                arr.astype(getattr(like, "dtype", arr.dtype))))
    return treedef.unflatten(out), manifest["metadata"]
