"""Paper Fig. 8 + Sec. VII-D: relative off-diagonal Frobenius norm vs sweep
count across data modalities -- the offline study that justifies the fixed
50-sweep schedule.  Validates the paper's claims: standard datasets hit the
numerical noise floor within 10-15 sweeps; ill-conditioned (clustered
eigenvalue) data needs more, motivating the 50-sweep factor of safety.

A precision axis rides along (ISSUE 9): the measured relative Frobenius
error of the fp32 and bf16-streamed eigenvalue spectra against the fp64
subprocess oracle, reported next to the documented ``ERROR_BUDGETS``
ceiling each must stay under."""
from __future__ import annotations

import numpy as np

from repro.core.schedule import (convergence_curve, make_ill_conditioned,
                                 sweeps_to_tolerance)
from .common import emit, synthetic_dataset


def precision_axis(fast: bool = True):
    """Measured error vs the fp64 oracle per precision policy.

    One small dataset (the oracle pays a subprocess + x64 solve per op);
    the budgets are ceilings, the emitted numbers the measured truth."""
    from repro.core import precision as prec
    from repro.kernels import ops as kops
    from repro.core.jacobi import jacobi_eigh

    x = synthetic_dataset(512, 24, 9)
    sweeps = 15 if fast else 30
    oracle_c = prec.run_fp64_oracle(x, "covariance")
    oracle_e = prec.run_fp64_oracle(x, "eigh", sweeps=sweeps)
    for precision in ("fp32", "bf16_fp32acc"):
        C = kops.covariance(x, block_m=64, precision=precision,
                            backend="interpret")
        err_c = prec.rel_frobenius(np.asarray(C), oracle_c["C"])
        res = jacobi_eigh(np.asarray(C), sweeps=sweeps)
        err_e = prec.rel_frobenius(np.asarray(res.eigenvalues),
                                   oracle_e["eigenvalues"])
        emit(f"fig8/precision/{precision}", "",
             f"cov_err={err_c:.2e}"
             f";budget={prec.ERROR_BUDGETS[precision]['covariance']:.0e}"
             f";eigh_err={err_e:.2e}"
             f";eigh_budget={prec.ERROR_BUDGETS[precision]['eigh']:.0e}")


def run(fast: bool = True):
    suites = {
        # shape-matched stand-ins for the paper's modalities
        "mnist-like_1797x64": synthetic_dataset(1797, 64, 1),
        "faces-like_400x128": synthetic_dataset(400, 128, 2),
        "biomed-like_4000x7": synthetic_dataset(4000, 7, 3),
        "text-like_2000x96": synthetic_dataset(2000, 96, 4,
                                               spectrum="flat"),
        "ill-conditioned_512x64": make_ill_conditioned(512, 64,
                                                       cluster_gap=1e-5),
    }
    floors = []
    for name, x in suites.items():
        curve = convergence_curve(x, sweeps=25 if fast else 50)
        k6 = sweeps_to_tolerance(curve, 1e-6)
        floors.append((name, k6))
        emit(f"fig8/{name}", "",
             f"sweeps_to_1e-6={k6};final={curve[-1]:.2e}")
    standard = [k for n, k in floors if not n.startswith("ill")]
    emit("fig8/claim_10_to_15_sweeps", "",
         f"max_standard={max(standard)};within_15={max(standard) <= 15}")
    ill = [k for n, k in floors if n.startswith("ill")]
    emit("fig8/claim_50_sweep_safety_margin", "",
         f"ill_conditioned={ill[0]};margin_ok={ill[0] <= 50}")
    precision_axis(fast=fast)
