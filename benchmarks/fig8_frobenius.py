"""Paper Fig. 8 + Sec. VII-D: relative off-diagonal Frobenius norm vs sweep
count across data modalities -- the offline study that justifies the fixed
50-sweep schedule.  Validates the paper's claims: standard datasets hit the
numerical noise floor within 10-15 sweeps; ill-conditioned (clustered
eigenvalue) data needs more, motivating the 50-sweep factor of safety."""
from __future__ import annotations

import numpy as np

from repro.core.schedule import (convergence_curve, make_ill_conditioned,
                                 sweeps_to_tolerance)
from .common import emit, synthetic_dataset


def run(fast: bool = True):
    suites = {
        # shape-matched stand-ins for the paper's modalities
        "mnist-like_1797x64": synthetic_dataset(1797, 64, 1),
        "faces-like_400x128": synthetic_dataset(400, 128, 2),
        "biomed-like_4000x7": synthetic_dataset(4000, 7, 3),
        "text-like_2000x96": synthetic_dataset(2000, 96, 4,
                                               spectrum="flat"),
        "ill-conditioned_512x64": make_ill_conditioned(512, 64,
                                                       cluster_gap=1e-5),
    }
    floors = []
    for name, x in suites.items():
        curve = convergence_curve(x, sweeps=25 if fast else 50)
        k6 = sweeps_to_tolerance(curve, 1e-6)
        floors.append((name, k6))
        emit(f"fig8/{name}", "",
             f"sweeps_to_1e-6={k6};final={curve[-1]:.2e}")
    standard = [k for n, k in floors if not n.startswith("ill")]
    emit("fig8/claim_10_to_15_sweeps", "",
         f"max_standard={max(standard)};within_15={max(standard) <= 15}")
    ill = [k for n, k in floors if n.startswith("ill")]
    emit("fig8/claim_50_sweep_safety_margin", "",
         f"ill_conditioned={ill[0]};margin_ok={ill[0] <= 50}")
