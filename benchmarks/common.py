"""Shared benchmark utilities: timing, the paper's dataset suite
(Table IV), CSV emission, machine-readable JSON trajectory files."""
from __future__ import annotations

import datetime
import json
import pathlib
import subprocess
import time
from typing import Callable, Dict, Tuple

import numpy as np
import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Paper Table IV: (records M, features N) per benchmark dataset.  No
# network access in this container, so measured runs use synthetic
# stand-ins with the same shapes (spectra controlled where it matters --
# fig8 uses structured covariances).
DATASETS: Dict[str, Tuple[int, int]] = {
    "mnist-8x8": (1797, 64),
    "mnist-28x28": (70000, 784),
    "cifar-10": (60000, 3072),
    "olivetti": (400, 4096),
    "breast-cancer": (45312, 7),
    "20-newsgroups": (18846, 1024),
}

# paper headline GPU comparison numbers (A6000; Sec. VII-B/C) for reference
PAPER_CLAIMS = {
    "cifar10_total_speedup_vs_a6000": 3.87,
    "svd_speedup_vs_a6000": 22.75,
    "cifar10_energy_reduction_vs_a6000": 42.14,
}


def synthetic_dataset(m: int, n: int, seed: int = 0,
                      spectrum: str = "decay") -> np.ndarray:
    rng = np.random.default_rng(seed)
    k = min(n, 32)
    if spectrum == "decay":
        base = rng.standard_normal((m, k)) * np.geomspace(1, 0.05, k)
        mix = rng.standard_normal((k, n)) / np.sqrt(k)
        x = base @ mix + 0.05 * rng.standard_normal((m, n))
    else:
        x = rng.standard_normal((m, n))
    return x.astype(np.float32)


def time_call(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall-time of a jitted call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call, derived=""):
    print(f"{name},{us_per_call},{derived}", flush=True)


def provenance() -> Dict:
    """Where/when/what produced a benchmark number: git SHA, timestamp,
    jax version, device backend and count.  Best-effort (a checkout-less
    run stamps ``git_sha: null``) -- the numbers must still emit."""
    try:
        r = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                           capture_output=True, text=True, timeout=10)
        sha = r.stdout.strip() if r.returncode == 0 else None
    except OSError:
        sha = None
    return {
        "git_sha": sha,
        "emitted_at": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def emit_json(name: str, payload: Dict) -> pathlib.Path:
    """Write a machine-readable result file ``BENCH_<name>.json`` at the
    repo root so the perf trajectory accumulates across PRs.  ``payload``
    should be a dict of plain scalars/lists (rows keyed like the CSV).

    Every file carries a ``provenance`` block (git SHA, emission time, jax
    version, device fleet); ``scripts/check_bench.py`` ignores it when
    diffing rows, so provenance churn never reads as a perf change."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    doc = {"benchmark": name, "timestamp_s": time.time(),
           "provenance": provenance(), **payload}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path
