"""Paper Fig. 1: PCA execution-time split (covariance vs SVD) across the
two scaling regimes -- (a) constant rows / growing features: SVD's O(d^3)
dominates; (b) constant features / growing rows: covariance's O(n*d^2)
dominates.  Measured with jitted JAX on CPU (small sizes) and the paper's
trend validated on the measured ratios."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import PCAConfig, covariance, jacobi_eigh, standardize
from .common import emit, synthetic_dataset, time_call


def _stage_times(m: int, d: int, sweeps: int = 8):
    x = jnp.asarray(synthetic_dataset(m, d, seed=d + m))
    xs, _, _ = standardize(x)
    cov_fn = jax.jit(covariance)
    c = cov_fn(xs)
    svd_fn = jax.jit(lambda c: jacobi_eigh(c, sweeps=sweeps).eigenvalues)
    t_cov = time_call(cov_fn, xs)
    t_svd = time_call(svd_fn, c)
    return t_cov, t_svd


def run(fast: bool = True):
    # (a) constant rows m=512, features grow -> SVD share grows
    shares = []
    for d in (16, 32, 64, 128) if fast else (16, 32, 64, 128, 256):
        t_cov, t_svd = _stage_times(512, d)
        shares.append(t_svd / (t_cov + t_svd))
        emit(f"fig1a/constant_rows_d{d}", round(t_cov + t_svd, 1),
             f"svd_share={shares[-1]:.3f}")
    emit("fig1a/svd_share_grows_with_d", "",
         f"monotone={all(b > a for a, b in zip(shares, shares[1:]))}")

    # (b) constant features d=64, rows grow -> covariance share grows
    shares = []
    for m in (256, 1024, 4096) if fast else (256, 1024, 4096, 16384):
        t_cov, t_svd = _stage_times(m, 64)
        shares.append(t_cov / (t_cov + t_svd))
        emit(f"fig1b/constant_features_m{m}", round(t_cov + t_svd, 1),
             f"cov_share={shares[-1]:.3f}")
    emit("fig1b/cov_share_grows_with_m", "",
         f"monotone={all(b > a for a, b in zip(shares, shares[1:]))}")
