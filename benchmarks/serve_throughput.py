"""Serving throughput: batched multi-tenant engine vs one-at-a-time baseline.

Sweeps (T, S, bucket policy) over a fixed mixed-shape eigh request stream and
reports requests/s plus p50/p99 service latency.  The S=1 row is the
serve-one-at-a-time baseline (every request its own dispatch); batched rows
must clear >2x its requests/s to demonstrate the S-array axis paying off in
software.  Also emits ``BENCH_serve_throughput.json`` for the perf
trajectory.
"""
from __future__ import annotations

import time

from repro.core import PCAConfig
from repro.launch.serve_pca import mixed_traffic
from repro.serving import BucketPolicy, PCAServer, threshold_router

from .common import emit, emit_json

MIXED_DIMS = (10, 14, 18, 24, 29, 31, 37, 46)


def _measure(mats, T: int, S: int, mode: str, sweeps: int = 10,
             backend_router=None):
    srv = PCAServer(PCAConfig(T=T, S=S, sweeps=sweeps),
                    policy=BucketPolicy(T=T, mode=mode), max_delay_s=10.0,
                    backend_router=backend_router)
    srv.solve_many(mats)            # warmup: compile every bucket executable
    srv.stats.reset()
    t0 = time.perf_counter()
    srv.solve_many(mats)
    wall = time.perf_counter() - t0
    s = srv.stats.summary()
    return {
        "T": T, "S": S, "policy": mode,
        "wall_s": wall,
        "requests_per_s": len(mats) / wall,
        "us_per_request": wall / len(mats) * 1e6,
        "latency_p50_ms": s["latency_p50_ms"],
        "latency_p99_ms": s["latency_p99_ms"],
        "mean_padding_waste": s["mean_padding_waste"],
        "mean_batch": s["mean_batch"],
        "cache_hit_rate": s["cache_hit_rate"],
    }


def run(fast: bool = True) -> None:
    n_req = 32 if fast else 128
    mats = mixed_traffic(n_req, "eigh", MIXED_DIMS)
    grid = [(16, 1, "tile"),            # serve-one-at-a-time baseline
            (16, 4, "tile"), (16, 8, "tile"),
            (16, 4, "pow2"), (16, 8, "pow2")]
    if not fast:
        grid += [(32, 4, "tile"), (32, 8, "tile"), (32, 8, "pow2")]

    rows = []
    baseline_rps = None
    for T, S, mode in grid:
        row = _measure(mats, T, S, mode)
        if S == 1:
            baseline_rps = row["requests_per_s"]
        row["speedup_vs_serial"] = (row["requests_per_s"] / baseline_rps
                                    if baseline_rps else float("nan"))
        rows.append(row)
        emit(f"serve_T{T}_S{S}_{mode}", f"{row['us_per_request']:.1f}",
             f"rps={row['requests_per_s']:.1f}"
             f";p50_ms={row['latency_p50_ms']:.2f}"
             f";p99_ms={row['latency_p99_ms']:.2f}"
             f";waste={row['mean_padding_waste']:.3f}"
             f";speedup={row['speedup_vs_serial']:.2f}")

    best = max(r["speedup_vs_serial"] for r in rows if r["S"] >= 4)
    emit("serve_best_batched_speedup", f"{best:.2f}",
         "acceptance: >2x vs serve-one-at-a-time")
    emit_json("serve_throughput", {
        "n_requests": n_req,
        "mixed_dims": list(MIXED_DIMS),
        "baseline_requests_per_s": baseline_rps,
        "best_batched_speedup": best,
        "rows": rows,
    })


def selftest() -> int:
    """CI smoke: one backend-sweep point -- a routed server splits traffic
    across two kernel backends in one run; results are verified against
    numpy and both backends must actually be exercised."""
    import json

    import numpy as np

    mats = mixed_traffic(8, "eigh", (6, 20))
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=14),
                    policy=BucketPolicy(T=8), max_delay_s=10.0,
                    backend_router=threshold_router(16, large="interpret",
                                                    small=None))
    # warmup pass doubles as the correctness check (compiles both buckets)
    for m, r in zip(mats, srv.solve_many(mats)):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    routed = sorted({(r.bucket, str(r.backend))
                     for r in srv.stats.records})
    assert len({b for _, b in routed}) == 2, routed
    srv.stats.reset()
    t0 = time.perf_counter()
    srv.solve_many(mats)
    wall = time.perf_counter() - t0
    s = srv.stats.summary()
    assert s["cache_hit_rate"] == 1.0, s   # steady state: no recompiles
    print("serve_throughput selftest ok:", json.dumps({
        "routed_buckets": [f"{bkt}->{be}" for bkt, be in routed],
        "requests_per_s": round(len(mats) / wall, 1),
        "cache_hit_rate": s["cache_hit_rate"],
    }))
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true",
                    help="one backend-sweep smoke point and exit")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        sys.exit(selftest())
    print("name,us_per_call,derived")
    run(fast=not args.full)
