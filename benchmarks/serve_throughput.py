"""Serving throughput: batched multi-tenant engine vs one-at-a-time baseline.

Sweeps (T, S, bucket policy) over a fixed mixed-shape eigh request stream and
reports requests/s plus p50/p99 service latency.  The S=1 row is the
serve-one-at-a-time baseline (every request its own dispatch); batched rows
must clear >2x its requests/s to demonstrate the S-array axis paying off in
software.  Also emits ``BENCH_serve_throughput.json`` for the perf
trajectory.

The sharded sweep axis (``sharded_rows``) holds the flush size fixed and
sweeps the device-mesh size: one large bucket, ``MeshExecutor`` over
1/2/4/8 host devices.  It always runs in a subprocess that forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
``tests/test_distributed.py`` recipe), so the per-device-count rows mean
the same thing on a laptop, in either CI matrix job, or next to a real
accelerator -- the comparison ``scripts/check_bench.py`` gates on never
mixes device-visibility regimes.

The sync-vs-async sweep axis (``async_rows``) sweeps the pipeline depth
(``max_inflight`` 1/2/4) over the same large bucket in *latency mode*:
single-request flushes (``max_batch=1``), every request its own dispatch.
That is the regime where a synchronous engine loses the most to
host/device serialization -- the flush rate is highest, so the host stage
(stack / launch / gather / unpack / telemetry, plus the next request's
submission) is a measurable fraction of each flush -- and therefore the
regime that isolates what the dispatch/in-flight/retire pipeline buys: at
``max_inflight>1`` the host batches request k+1 while the device solves
request k.  A deliberately light sweep count keeps the device stage from
drowning the host stage (all rows, sync and async, share the identical
solver, so the comparison is pure pipeline).  Rows are regime-pinned like
the sharded ones: a subprocess forces a single host device, and the three
servers' timing passes are interleaved so a slow host phase cannot land on
one pipeline depth systematically.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core import PCAConfig
from repro.launch.serve_pca import mixed_traffic
from repro.serving import (BucketPolicy, LocalExecutor, MeshExecutor,
                           PCAServer, host_mesh, threshold_router)

from .common import REPO_ROOT, emit, emit_json

MIXED_DIMS = (10, 14, 18, 24, 29, 31, 37, 46)

# sharded sweep: one large bucket (dim 46 -> 48 under T=16), fixed flush
# size, device count as the only axis
SHARDED_DIM = 46
SHARDED_FLUSH = 64
SHARDED_DEVICE_COUNTS = (1, 2, 4, 8)

# sync-vs-async sweep: the same large bucket in latency mode
# (single-request flushes), pipeline depth as the only axis
ASYNC_DIM = 46
ASYNC_FLUSH = 1
ASYNC_SWEEPS = 2
ASYNC_REQUESTS = 48
ASYNC_INFLIGHT = (1, 2, 4)


def _measure(mats, T: int, S: int, mode: str, sweeps: int = 10,
             backend_router=None, executor=None, max_batch=None,
             max_inflight: int = 1, reps: int = 3):
    srv = PCAServer(PCAConfig(T=T, S=S, sweeps=sweeps),
                    policy=BucketPolicy(T=T, mode=mode), max_delay_s=10.0,
                    backend_router=backend_router, executor=executor,
                    max_batch=max_batch, max_inflight=max_inflight)
    srv.solve_many(mats)            # warmup: compile every bucket executable
    # best-of-reps: scheduler noise only ever slows a pass down, and the
    # check_bench regression gate needs run-to-run stability
    wall = float("inf")
    for _ in range(reps):
        srv.stats.reset()
        t0 = time.perf_counter()
        srv.solve_many(mats)
        wall = min(wall, time.perf_counter() - t0)
    s = srv.stats.summary()
    return {
        "T": T, "S": S, "policy": mode,
        "wall_s": wall,
        "requests_per_s": len(mats) / wall,
        "us_per_request": wall / len(mats) * 1e6,
        "latency_p50_ms": s["latency_p50_ms"],
        "latency_p99_ms": s["latency_p99_ms"],
        "mean_padding_waste": s["mean_padding_waste"],
        "mean_batch": s["mean_batch"],
        "cache_hit_rate": s["cache_hit_rate"],
    }


def sharded_sweep() -> list:
    """Per-device-count rows for one large bucket at a fixed flush size.

    Must run under ``--xla_force_host_platform_device_count=8`` (or with 8
    real devices); device counts beyond what is visible are dropped.  The
    n_devices=1 row is the single-device ``LocalExecutor`` flush of the
    same ``SHARDED_FLUSH``-request batch, so each row answers "what did
    sharding this exact flush across n devices buy?".
    """
    import jax

    mats = mixed_traffic(SHARDED_FLUSH, "eigh", (SHARDED_DIM,))
    rows = []
    base_rps = None
    for n_dev in SHARDED_DEVICE_COUNTS:
        if n_dev > jax.device_count():
            break
        ex = (MeshExecutor(mesh=host_mesh(n_dev)) if n_dev > 1
              else LocalExecutor())
        row = _measure(mats, T=16, S=SHARDED_FLUSH, mode="tile",
                       executor=ex, max_batch=SHARDED_FLUSH)
        row["n_devices"] = n_dev
        row["flush_batch"] = SHARDED_FLUSH
        if n_dev == 1:
            base_rps = row["requests_per_s"]
        row["speedup_vs_1dev"] = (row["requests_per_s"] / base_rps
                                  if base_rps else float("nan"))
        rows.append(row)
    return rows


def _sweep_subprocess(fn_name: str, xla_flags: str) -> list:
    """Run a sweep function in a child pinned to one XLA regime.

    XLA fixes the device count at backend init, so an already-started
    process cannot change its device visibility; the subprocess both makes
    a sweep runnable from anywhere (either CI matrix job, a laptop, next
    to an accelerator) and pins its rows to one regime so
    ``scripts/check_bench.py`` never compares across regimes.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = xla_flags
    env["PYTHONPATH"] = (str(REPO_ROOT / "src") + os.pathsep
                         + str(REPO_ROOT))
    prog = (f"import json; from benchmarks.serve_throughput import "
            f"{fn_name}; print(json.dumps({fn_name}()))")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=1200, cwd=REPO_ROOT)
    if r.returncode != 0:
        raise RuntimeError(f"{fn_name} subprocess failed:\n"
                           f"{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def sharded_sweep_subprocess() -> list:
    return _sweep_subprocess("sharded_sweep",
                             "--xla_force_host_platform_device_count=8")


def async_sweep() -> list:
    """Pipeline-depth rows for the large bucket in latency mode.

    One server per ``max_inflight`` depth, identical solver and traffic;
    the only difference is whether the engine blocks on every flush
    (depth 1, the synchronous baseline) or keeps flushes in flight while
    it batches the next request.  Timing passes are *interleaved* across
    the servers -- a noisy-neighbour phase hits every depth equally
    instead of skewing one row -- and each row keeps its best pass (the
    same best-of-reps policy as ``_measure``).
    """
    import jax

    mats = mixed_traffic(ASYNC_REQUESTS, "eigh", (ASYNC_DIM,))
    servers = {
        depth: PCAServer(
            PCAConfig(T=16, S=ASYNC_FLUSH, sweeps=ASYNC_SWEEPS),
            policy=BucketPolicy(T=16, mode="tile"), max_delay_s=10.0,
            max_batch=ASYNC_FLUSH, max_inflight=depth)
        for depth in ASYNC_INFLIGHT
    }
    for srv in servers.values():
        srv.solve_many(mats)        # warmup: compile the bucket executable
    best = {depth: (float("inf"), None) for depth in ASYNC_INFLIGHT}
    for _ in range(8):
        for depth, srv in servers.items():
            srv.stats.reset()
            t0 = time.perf_counter()
            srv.solve_many(mats)
            wall = time.perf_counter() - t0
            if wall < best[depth][0]:
                best[depth] = (wall, srv.stats.summary())
    rows = []
    base_rps = None
    for depth in ASYNC_INFLIGHT:
        wall, s = best[depth]
        row = {
            "T": 16, "S": ASYNC_FLUSH, "policy": "tile", "op": "eigh",
            "sweeps": ASYNC_SWEEPS, "inflight": depth,
            "device_count": jax.device_count(),
            "wall_s": wall,
            "requests_per_s": len(mats) / wall,
            "us_per_request": wall / len(mats) * 1e6,
            "latency_p50_ms": s["latency_p50_ms"],
            "latency_p99_ms": s["latency_p99_ms"],
            "overlap_frac": s["overlap_frac"],
            "mean_inflight_depth": s["mean_inflight_depth"],
        }
        if depth == 1:
            base_rps = row["requests_per_s"]
        row["speedup_vs_sync"] = (row["requests_per_s"] / base_rps
                                  if base_rps else float("nan"))
        rows.append(row)
    return rows


def async_sweep_subprocess() -> list:
    return _sweep_subprocess("async_sweep",
                             "--xla_force_host_platform_device_count=1")


def run(fast: bool = True) -> None:
    import jax

    n_req = 32 if fast else 128
    mats = mixed_traffic(n_req, "eigh", MIXED_DIMS)
    grid = [(16, 1, "tile"),            # serve-one-at-a-time baseline
            (16, 4, "tile"), (16, 8, "tile"),
            (16, 4, "pow2"), (16, 8, "pow2")]
    if not fast:
        grid += [(32, 4, "tile"), (32, 8, "tile"), (32, 8, "pow2")]

    rows = []
    baseline_rps = None
    for T, S, mode in grid:
        row = _measure(mats, T, S, mode)
        # part of the row's *identity* for scripts/check_bench.py: grid
        # timings measured under different device splits (the mesh-8 CI
        # job carves the CPU into 8 host devices) are not comparable, so
        # rows only match within one device-visibility regime.  The
        # sharded rows pin their regime by construction (subprocess with
        # forced host-device count).
        row["device_count"] = jax.device_count()
        if S == 1:
            baseline_rps = row["requests_per_s"]
        row["speedup_vs_serial"] = (row["requests_per_s"] / baseline_rps
                                    if baseline_rps else float("nan"))
        rows.append(row)
        emit(f"serve_T{T}_S{S}_{mode}", f"{row['us_per_request']:.1f}",
             f"rps={row['requests_per_s']:.1f}"
             f";p50_ms={row['latency_p50_ms']:.2f}"
             f";p99_ms={row['latency_p99_ms']:.2f}"
             f";waste={row['mean_padding_waste']:.3f}"
             f";speedup={row['speedup_vs_serial']:.2f}")

    best = max(r["speedup_vs_serial"] for r in rows if r["S"] >= 4)
    emit("serve_best_batched_speedup", f"{best:.2f}",
         "acceptance: >2x vs serve-one-at-a-time")

    sharded_rows = sharded_sweep_subprocess()
    for row in sharded_rows:
        emit(f"serve_sharded_{row['n_devices']}dev",
             f"{row['us_per_request']:.1f}",
             f"rps={row['requests_per_s']:.1f}"
             f";speedup_vs_1dev={row['speedup_vs_1dev']:.2f}")
    sharded_best = (max(r["speedup_vs_1dev"] for r in sharded_rows)
                    if sharded_rows else float("nan"))
    emit("serve_sharded_best_speedup", f"{sharded_best:.2f}",
         "acceptance: >=2x at 8 host devices vs 1 (large bucket)")

    async_rows = async_sweep_subprocess()
    for row in async_rows:
        emit(f"serve_async_inflight{row['inflight']}",
             f"{row['us_per_request']:.1f}",
             f"rps={row['requests_per_s']:.1f}"
             f";speedup_vs_sync={row['speedup_vs_sync']:.2f}"
             f";overlap={row['overlap_frac']:.2f}")
    async_best = (max(r["speedup_vs_sync"] for r in async_rows)
                  if async_rows else float("nan"))
    emit("serve_async_best_speedup", f"{async_best:.2f}",
         "acceptance: >=1.3x for max_inflight>1 vs 1 (large bucket)")

    emit_json("serve_throughput", {
        "n_requests": n_req,
        "mixed_dims": list(MIXED_DIMS),
        "baseline_requests_per_s": baseline_rps,
        "best_batched_speedup": best,
        "rows": rows,
        "sharded_dim": SHARDED_DIM,
        "sharded_flush": SHARDED_FLUSH,
        "sharded_best_speedup": sharded_best,
        "sharded_rows": sharded_rows,
        "async_dim": ASYNC_DIM,
        "async_flush": ASYNC_FLUSH,
        "async_sweeps": ASYNC_SWEEPS,
        "async_requests": ASYNC_REQUESTS,
        "async_best_speedup": async_best,
        "async_rows": async_rows,
    })


def selftest() -> int:
    """CI smoke: one backend-sweep point -- a routed server splits traffic
    across two kernel backends in one run; results are verified against
    numpy and both backends must actually be exercised."""
    import json

    import numpy as np

    mats = mixed_traffic(8, "eigh", (6, 20))
    srv = PCAServer(PCAConfig(T=8, S=4, sweeps=14),
                    policy=BucketPolicy(T=8), max_delay_s=10.0,
                    backend_router=threshold_router(16, large="interpret",
                                                    small=None))
    # warmup pass doubles as the correctness check (compiles both buckets)
    for m, r in zip(mats, srv.solve_many(mats)):
        ref = np.linalg.eigh(m)[0][::-1]
        np.testing.assert_allclose(r.eigenvalues, ref, rtol=1e-3, atol=1e-3)
    routed = sorted({(r.bucket, str(r.backend))
                     for r in srv.stats.records})
    assert len({b for _, b in routed}) == 2, routed
    srv.stats.reset()
    t0 = time.perf_counter()
    srv.solve_many(mats)
    wall = time.perf_counter() - t0
    s = srv.stats.summary()
    assert s["cache_hit_rate"] == 1.0, s   # steady state: no recompiles
    print("serve_throughput selftest ok:", json.dumps({
        "routed_buckets": [f"{bkt}->{be}" for bkt, be in routed],
        "requests_per_s": round(len(mats) / wall, 1),
        "cache_hit_rate": s["cache_hit_rate"],
    }))
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true",
                    help="one backend-sweep smoke point and exit")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        sys.exit(selftest())
    print("name,us_per_call,derived")
    run(fast=not args.full)
