"""Cold start: time-to-first-response for a fresh serving replica.

MANOJAVAM's fabric answers from cycle one because it is pre-built; a JIT
replica spends its first seconds inside XLA instead -- exactly when it was
spawned because traffic already exceeds capacity.  This benchmark measures
what the persistent executable cache (``serving.cache``) and
``PCAServer.warmup`` buy, as the latency of the *first* request a fresh
replica serves:

  cold       no cache dir: the first flush pays the full JIT compile.
  warm_disk  ``cache_dir`` points at a directory a previous replica
             seeded: the first flush deserializes the AOT executable
             (zero XLA work) instead of compiling.
  warmup     ``cache_dir`` warm *and* ``warmup(profile)`` runs before any
             request is accepted (the real deployment shape: warm before
             joining the load balancer): the first flush is a memory hit.

Every mode runs in a **fresh subprocess** -- a replica's cold start cannot
be measured in a process whose jit caches are already warm -- against the
byte-identical burst, and every row carries a sha256 over its results so
the parent can assert the three paths are *bit-for-bit* identical (the
serialize/deserialize round trip must never touch the math).

Emits ``BENCH_cold_start.json``; ``scripts/check_bench.py`` gates the warm
rows' ``ttfr_ms`` against the cold row's (a warm replica that still pays
compile-scale first-request latency is a cache regression).
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time

from .common import REPO_ROOT, emit, emit_json

T = 16
BATCH = 4
SWEEPS = 10
DIM = 14            # one eigh bucket (16, 16) under T -- one executable
REQUESTS = 8
MODES = ("cold", "warm_disk", "warmup")


def _burst(n: int = REQUESTS):
    import numpy as np
    rng = np.random.default_rng(7)
    mats = []
    for _ in range(n):
        a = rng.standard_normal((DIM, DIM)).astype(np.float32)
        mats.append((a + a.T) / 2)
    return mats


def write_profile(path: str) -> None:
    from repro.serving import TrafficProfile
    TrafficProfile.from_shapes(
        [("eigh", (DIM, DIM), REQUESTS)]).save(path)


def replica_row(mode: str, cache_dir: str, profile_path: str) -> dict:
    """One fresh replica's first-request story (run in a fresh process)."""
    import numpy as np
    from repro.core import PCAConfig
    from repro.serving import BucketPolicy, PCAServer, TrafficProfile

    srv = PCAServer(PCAConfig(T=T, S=BATCH, sweeps=SWEEPS),
                    policy=BucketPolicy(T=T), max_delay_s=10.0,
                    cache_dir=(cache_dir if mode != "cold" else None))
    warmup_s = 0.0
    warmed = 0
    if mode == "warmup":
        t0 = time.perf_counter()
        doc = srv.warmup(TrafficProfile.load(profile_path))
        warmup_s = time.perf_counter() - t0
        warmed = doc["executables"]
    mats = _burst()
    # TTFR: the first request's submit-to-result latency -- compile (cold),
    # AOT deserialize (warm_disk) or pure execution (warmup) included
    t0 = time.perf_counter()
    first = srv.submit(mats[0], op="eigh").wait()
    ttfr_s = time.perf_counter() - t0
    rest = srv.solve_many(mats[1:], op="eigh")
    digest = hashlib.sha256()
    for r in [first] + rest:
        digest.update(np.ascontiguousarray(r.eigenvalues).tobytes())
        digest.update(np.ascontiguousarray(r.eigenvectors).tobytes())
    summary = srv.cache_summary()
    disk = summary["disk"] or {}
    return {
        "mode": mode,
        "ttfr_ms": ttfr_s * 1e3,
        "warmup_s": warmup_s,
        "warmup_executables": warmed,
        "requests": len(mats),
        "disk_hits": int(disk.get("hits", 0)),
        "disk_stores": int(disk.get("stores", 0)),
        "burst_sha256": digest.hexdigest(),
    }


def _replica_subprocess(mode: str, cache_dir: str,
                        profile_path: str) -> dict:
    """Run one replica in a fresh process (fresh jit caches, fresh XLA)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO_ROOT / "src") + os.pathsep
                         + str(REPO_ROOT))
    prog = ("import json, sys; "
            "from benchmarks.cold_start import replica_row; "
            "print(json.dumps(replica_row(*sys.argv[1:4])))")
    r = subprocess.run(
        [sys.executable, "-c", prog, mode, cache_dir, profile_path],
        capture_output=True, text=True, env=env, timeout=1200,
        cwd=REPO_ROOT)
    if r.returncode != 0:
        raise RuntimeError(f"cold_start replica ({mode}) failed:\n"
                           f"{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def sweep() -> list:
    """Seed a cache dir once, then measure every mode in a fresh process."""
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        profile_path = os.path.join(tmp, "profile.json")
        cache_dir = os.path.join(tmp, "cache")
        write_profile(profile_path)
        # seed: one throwaway replica compiles + serializes the executable
        # (its own timings are a cold start and are discarded)
        _replica_subprocess("warmup", cache_dir, profile_path)
        for mode in MODES:
            rows.append(_replica_subprocess(mode, cache_dir, profile_path))
    digests = {r["burst_sha256"] for r in rows}
    assert len(digests) == 1, f"cold/warm results diverged: {rows}"
    cold_ms = next(r["ttfr_ms"] for r in rows if r["mode"] == "cold")
    for r in rows:
        r["ttfr_reduction_vs_cold"] = (1.0 - r["ttfr_ms"] / cold_ms
                                       if cold_ms > 0 else 0.0)
    return rows


def run(fast: bool = True) -> None:
    del fast                        # 4 short subprocesses either way
    from repro.serving import aot_supported

    if not aot_supported():
        # memory-tier-only jax: the warm modes would silently re-measure a
        # cold start; emit the fact instead of a misleading comparison
        emit("cold_start_skipped", "0", "jax lacks serialize_executable")
        emit_json("cold_start", {"aot_supported": False, "rows": []})
        return
    rows = sweep()
    for row in rows:
        emit(f"cold_start_{row['mode']}", f"{row['ttfr_ms'] * 1e3:.1f}",
             f"ttfr_ms={row['ttfr_ms']:.1f}"
             f";reduction={row['ttfr_reduction_vs_cold']:.3f}"
             f";disk_hits={row['disk_hits']}")
    by_mode = {r["mode"]: r for r in rows}
    emit_json("cold_start", {
        "aot_supported": True,
        "dim": DIM, "T": T, "batch": BATCH, "sweeps": SWEEPS,
        "requests": REQUESTS,
        "cold_ttfr_ms": by_mode["cold"]["ttfr_ms"],
        "warm_disk_ttfr_reduction":
            by_mode["warm_disk"]["ttfr_reduction_vs_cold"],
        "warmup_ttfr_reduction":
            by_mode["warmup"]["ttfr_reduction_vs_cold"],
        "bitwise_identical": True,  # sweep() asserts it
        "rows": rows,
    })


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
