"""Paper Sec. VIII design-space exploration (Figs. 9-11): execution time,
power and resources vs tile size T and parallelism index S.

Validates the paper's scaling laws on the cycle-approximate model:
exec time ~ 1/T^2 at fixed S (Fig. 9a), ~ 1/S at fixed T (Fig. 9b);
power and resources grow with S*T^2 (Figs. 10-11; DSP = S*T^2/2 exactly
matches Tables I/II).  A measured column sweeps the Pallas mm_engine block
size on CPU (interpret mode) as the kernel-level T analogue."""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.memory_model import FabricConfig, pca_seconds, power_w, resources
from .common import emit, synthetic_dataset, time_call

_M, _N = 20000, 512  # representative workload for the sweeps


def run(fast: bool = True):
    # Fig 9a: T sweep at fixed S=4
    base = None
    for t in (4, 8, 12, 16, 20):
        cfg = FabricConfig(T=t, S=4)
        total = pca_seconds(_M, _N, cfg)["total_s"]
        base = base or total * t * t
        emit(f"fig9a/T{t}_S4", round(total * 1e6, 1),
             f"t2_scaled={total * t * t / base:.3f}")
    # Fig 9b: S sweep at fixed T=4
    base = None
    for s in (8, 12, 16, 20, 24):
        cfg = FabricConfig(T=4, S=s)
        total = pca_seconds(_M, _N, cfg)["total_s"]
        base = base or total * s
        emit(f"fig9b/T4_S{s}", round(total * 1e6, 1),
             f"s_scaled={total * s / base:.3f}")
    # Fig 10: power model
    for t in (4, 8, 12, 16, 20):
        emit(f"fig10a/power_T{t}_S4", "",
             f"watts={power_w(FabricConfig(T=t, S=4)):.3f}")
    for s in (8, 16, 24):
        emit(f"fig10b/power_T4_S{s}", "",
             f"watts={power_w(FabricConfig(T=4, S=s)):.3f}")
    # Fig 11: resources (DSP exact: S*T^2/2)
    for t, s in ((4, 8), (16, 32)):
        r = resources(FabricConfig(T=t, S=s))
        emit(f"fig11/resources_T{t}_S{s}", "",
             f"LUT={r['LUT']:.0f};FF={r['FF']:.0f};"
             f"BRAM={r['BRAM']:.1f};DSP={r['DSP']:.0f}")

    # measured kernel-level analogue: mm_engine block-size sweep
    from repro.kernels import ops
    x = jnp.asarray(synthetic_dataset(1024, 256, 7))
    for blk in ((64, 128) if fast else (32, 64, 128, 256)):
        us = time_call(lambda a: ops.mm_engine_matmul(a.T, a, block=blk), x,
                       reps=2)
        emit(f"dse/mm_engine_block{blk}", round(us, 1), "interpret_mode")
