"""Autotuned serving plan vs the hand-picked default: the tuning dividend.

A heterogeneous synthetic trace (bimodal shape mix -- the regime where
bucket policy, flush size and pipeline depth matter most) is profiled
under the default ``launch.serve_pca`` plan, the serving-plan autotuner
(``repro.serving.autotune``) searches the plan grid against that profile,
and every contender -- default, analytic winner, measured winner -- is
then *measured* with the identical deterministic replay harness.  The
committed ``BENCH_autotune_gain.json`` rows are the trajectory the nightly
CI gate (``scripts/check_bench.py``) enforces: the tuned plan must stay at
or above the default plan's throughput (within tolerance), and neither may
regress run-over-run beyond the tolerance.

Acceptance: the tuned plan clears >=1.2x the default plan's requests/s on
the heterogeneous trace.

Methodology notes: the replay regenerates the profile's traffic
deterministically (same shapes, seeded matrices, seeded arrival shuffle),
every plan sees the byte-identical burst, compilation happens in a warmup
pass (the cost model charges it separately; steady-state serving runs on
the executable cache), and each row keeps its best-of-``PASSES`` wall time
-- the same scheduler-noise policy as ``serve_throughput``.
"""
from __future__ import annotations

import time

from repro.core import PCAConfig
from repro.serving import (ServingPlan, TrafficProfile, autotune, plan_grid,
                           replay, server_for_plan, synthetic_trace)

from .common import emit, emit_json

TRACE_KIND = "bimodal"
TRACE_LO, TRACE_HI = 6, 48
TRACE_SEED = 0
PASSES = 3
MEASURE_TOP_K = 3
CONFIG = PCAConfig(sweeps=10)          # T/S come from each plan
# the hand-picked tuple the autotuner exists to beat: exactly the
# launch.serve_pca CLI defaults (tile T=16, S=4, synchronous, local)
DEFAULT_PLAN = ServingPlan()


def capture_profile(mats) -> TrafficProfile:
    """Profile the trace under the default plan.

    Two passes with telemetry accumulating across both: the first pass
    compiles (its flushes are cache misses -- that is the compile-cost
    calibration signal), the second runs steady-state (cache-hit dispatch
    cost and the device-rate signal).
    """
    srv = server_for_plan(DEFAULT_PLAN, CONFIG)
    for _ in range(2):
        srv.solve_many(mats)
    return TrafficProfile.from_stats(srv.stats,
                                     captured=srv.describe_plan())


def run(fast: bool = True) -> None:
    import jax

    n_req = 64 if fast else 192
    mats = synthetic_trace(TRACE_KIND, n_req, op="eigh",
                           lo=TRACE_LO, hi=TRACE_HI, seed=TRACE_SEED)
    profile = capture_profile(mats)
    t0 = time.perf_counter()
    result = autotune(profile, grid=plan_grid(), config=CONFIG,
                      measure_top_k=MEASURE_TOP_K, seed=TRACE_SEED,
                      passes=PASSES)
    tune_s = time.perf_counter() - t0
    analytic_best = result.scored[0][0]

    # the measured winner often confirms the analytic one; the row is kept
    # either way (distinct identity via the plan label) so the intra-file
    # gate always sees a measured-tuned row
    contenders = [("default", DEFAULT_PLAN), ("analytic", analytic_best),
                  ("measured", result.best)]

    rows = []
    base_rps = None
    for label, plan in contenders:
        r = replay(profile, plan, config=CONFIG, seed=TRACE_SEED,
                   passes=PASSES)
        row = {
            "plan": label,
            "policy": plan.mode,
            "T": plan.T,
            "pow2_cap": plan.pow2_cap if plan.pow2_cap else 0,
            "max_batch": plan.max_batch,
            "inflight": plan.max_inflight,
            "mesh": plan.mesh,
            "trace": TRACE_KIND,
            "n_requests": n_req,
            "device_count": jax.device_count(),
            **r,
        }
        if label == "default":
            base_rps = row["requests_per_s"]
        row["speedup_vs_default"] = (row["requests_per_s"] / base_rps
                                     if base_rps else float("nan"))
        rows.append(row)
        emit(f"autotune_{label}", f"{1e6 / row['requests_per_s']:.1f}",
             f"rps={row['requests_per_s']:.1f}"
             f";plan={plan.describe()}"
             f";waste={row['mean_padding_waste']:.3f}"
             f";speedup={row['speedup_vs_default']:.2f}")

    tuned_speedup = rows[-1]["speedup_vs_default"]
    emit("autotune_tuned_speedup", f"{tuned_speedup:.2f}",
         "acceptance: >=1.2x tuned vs default plan on the bimodal trace")

    emit_json("autotune_gain", {
        "trace": {"kind": TRACE_KIND, "n_requests": n_req,
                  "lo": TRACE_LO, "hi": TRACE_HI, "seed": TRACE_SEED},
        "default_plan": DEFAULT_PLAN.to_json(),
        "tuned_plan": result.best.to_json(),
        "tuned_plan_describe": result.best.describe(),
        "tune_mode": result.mode,
        "tune_wall_s": tune_s,
        "analytic_top": result.to_json()["analytic_top"],
        "measured_refinement": result.measured,
        "tuned_vs_default_speedup": tuned_speedup,
        "rows": rows,
    })


def selftest() -> int:
    """CI smoke: a tiny trace through the full profile -> search -> apply
    lifecycle; the tuned plan must not lose to the default analytically."""
    import json

    mats = synthetic_trace(TRACE_KIND, 16, op="eigh", lo=6, hi=24, seed=0)
    profile = capture_profile(mats)
    result = autotune(profile, config=CONFIG)
    default_cost = result.model.plan_cost(DEFAULT_PLAN, profile)
    best_cost = result.scored[0][1]
    assert best_cost["total_s"] <= default_cost["total_s"], (
        best_cost, default_cost)
    srv = server_for_plan(DEFAULT_PLAN, CONFIG)
    srv.apply_plan(result.best)
    srv.solve_many(mats)
    print("autotune_gain selftest ok:", json.dumps({
        "tuned_plan": result.best.describe(),
        "est_speedup": round(default_cost["total_s"]
                             / best_cost["total_s"], 2)}))
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true",
                    help="tiny profile->search->apply smoke and exit")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        sys.exit(selftest())
    print("name,us_per_call,derived")
    run(fast=not args.full)
