"""Controller regret vs a clairvoyant re-tuner on a regime-shift trace,
and the bandit's measured-evaluation pruning vs the exhaustive grid.

Two claims, two suites:

``regret``  A seeded open-loop trace shifts regime mid-stream (small
    interactive matrices at moderate rate, then a long run of large
    refits).  A ``ServingController``-steered server runs it under a
    ``VirtualClock`` (analytic bandit, pinned cost model -- the whole
    timeline is bit-deterministic), re-profiling every
    ``REPROFILE_EVERY_S`` and hot-swapping behind hysteresis + dwell.
    Regret is scored per ``SCORE_WINDOW_S`` window under ONE fixed
    reference model:

        regret_frac = sum_w (controller_w - oracle_w)
                    / sum_w (default_w - oracle_w)

    where ``oracle_w`` is the cost of the *per-regime* exhaustive-grid
    best fixed plan (the clairvoyant re-tuner: it knows each regime's
    aggregate traffic in advance and swaps exactly at the shift) and
    ``default_w`` the static CLI-default plan -- so 0 is "adapted
    instantly to each regime's best plan" and 1 is "never adapted at
    all".  A per-*window* clairvoyant is not the comparator on purpose:
    with a handful of requests per window its argmin flips on sampling
    noise, and no causal policy can chase it (classic dynamic-regret
    impossibility); best-fixed-plan-per-regime is the standard
    achievable oracle.  The reference model zeroes the compile term --
    swaps prewarm through ``apply_plan(warm_profile=...)``, so charging
    every window a full cold compile would just reward never re-tuning.
    The model is pinned (``ServingController(model=)``) so regret
    measures *adaptation* (lag, hysteresis, dwell), not calibration
    noise; the calibration path is exercised by tests/test_controller.py
    and the serve_pca controller selftest leg.

``prune``   ``bandit_search(measure=True)`` on a captured profile:
    successive halving spends ``measured_evals`` real replay evaluations
    (subsampled-fidelity rungs) where the exhaustive measured grid would
    spend ``grid_size`` -- the measured fraction is the pruning claim.

Acceptance (gated by ``scripts/check_bench.py`` on the committed
``BENCH_controller_regret.json``): regret_frac <= 0.10, swaps <= 3,
measured_evals <= 0.25 * grid_size.
"""
from __future__ import annotations

import dataclasses
import time

from repro.serving import (ControllerSpec, CostModel, ExecutionSpec,
                           SchedulingSpec, ServerSpec, ServingController,
                           ServingPlan, TenantSpec, TrafficFrontend,
                           TrafficProfile, VirtualClock, bandit_search,
                           build_server, generate, merge, plan_grid,
                           profile_of, server_for_plan, synthetic_trace)

from .common import emit, emit_json

SEED = 0
SCORE_WINDOW_S = 2.0                 # regret scoring granularity
CTRL_WINDOW_S = 0.5                  # controller's trailing profile window
REPROFILE_EVERY_S = 0.25
HYSTERESIS = 0.05
MIN_DWELL_S = 0.5
# one fixed scoring function for oracle / default / controller alike -- a
# modeled device slow enough that padding waste matters, with the compile
# term zeroed (swaps prewarm; see module docstring), machine-independent
REF_MODEL = CostModel(device_work_per_s=2e6, compile_s_per_executable=0.0)
DEFAULT_PLAN = ServingPlan()         # the serve_pca CLI default tuple
BUDGET_FRAC = 0.25


def regime_shift_stream(n_small: int, n_big: int):
    """Small interactive traffic, then a long run of large refits; the
    big regime starts right after the small one ends.  Returns the
    merged stream and the shift time."""
    tenant = (TenantSpec("t0"),)
    small = generate("poisson", rate=200.0, n=n_small, tenants=tenant,
                     seed=5, trace="uniform", lo=8, hi=12)
    shift_t = max(a.t for a in small) + 1e-3
    big = [dataclasses.replace(a, t=a.t + shift_t) for a in
           generate("poisson", rate=20.0, n=n_big, tenants=tenant,
                    seed=9, trace="uniform", lo=28, hi=44)]
    return merge(small, big), shift_t


def _chunk_profile(chunk, span_s: float):
    """Offered-load profile of an arrival chunk, normalized to its span
    so plan costs are comparable across chunks."""
    return dataclasses.replace(profile_of(chunk), duration_s=span_s,
                               arrival_rate=len(chunk) / span_s)


def regime_windows(stream, shift_t: float, window_s: float, grid):
    """Score windows with the piecewise-static oracle plan attached.

    Splits the stream at the regime shift, finds each regime's
    exhaustive-grid best fixed plan on its *aggregate* profile, then
    cuts each regime into fixed windows carrying that regime's oracle
    plan.  Returns ``[(t0, t1, window_profile, oracle_plan)]``."""
    t_end = max(a.t for a in stream) + 1e-9
    out = []
    for r0, r1 in ((0.0, shift_t), (shift_t, t_end)):
        chunk = [a for a in stream if r0 <= a.t < r1]
        regime_prof = _chunk_profile(chunk, r1 - r0)
        oracle_plan = min(grid, key=lambda p:
                          REF_MODEL.plan_cost(p, regime_prof)["total_s"])
        t0 = r0
        while t0 < r1:
            t1 = min(t0 + window_s, r1)
            wchunk = [a for a in chunk if t0 <= a.t < t1]
            if wchunk:
                out.append((t0, t1, _chunk_profile(wchunk, t1 - t0),
                            oracle_plan))
            t0 = t1
    return out


def plan_at(timeline, t: float) -> ServingPlan:
    """The plan in force at time ``t`` on a [(t_swap, plan)] timeline."""
    current = DEFAULT_PLAN
    for ts, plan in timeline:
        if ts <= t:
            current = plan
    return current


def window_cost(timeline, t0: float, t1: float, prof) -> float:
    """Time-weighted reference cost of the plans in force over [t0, t1)
    -- a swap mid-window charges the old plan for its share, so slow
    adaptation is penalized in proportion."""
    cuts = sorted({t0, t1, *(ts for ts, _ in timeline if t0 < ts < t1)})
    total = 0.0
    for a, b in zip(cuts, cuts[1:]):
        plan = plan_at(timeline, a)
        total += (REF_MODEL.plan_cost(plan, prof)["total_s"]
                  * (b - a) / (t1 - t0))
    return total


def run(fast: bool = True) -> None:
    grid = plan_grid()
    # regime B is long relative to the controller's adaptation lag
    # (window fill + dwell), so steady-state windows dominate the sum
    n_small, n_big = (400, 600) if fast else (400, 1200)
    stream, shift_t = regime_shift_stream(n_small, n_big)

    # -- regret suite -------------------------------------------------------
    spec = ServerSpec(
        scheduling=SchedulingSpec(T=16, max_batch=4, max_delay_s=0.02),
        execution=ExecutionSpec(sweeps=6),
        controller=ControllerSpec(enabled=True, window_s=CTRL_WINDOW_S,
                                  reprofile_every_s=REPROFILE_EVERY_S,
                                  hysteresis=HYSTERESIS,
                                  min_dwell_s=MIN_DWELL_S))
    srv = build_server(spec, clock=VirtualClock())
    srv.controller.model = REF_MODEL     # pin the scoring function
    srv.controller.grid = list(grid)
    fe = TrafficFrontend(srv, (TenantSpec("t0"),), slo_ms=500.0,
                         admission="none", model=REF_MODEL, seed=1)
    srv.controller.frontend = fe
    t0 = time.perf_counter()
    rep = fe.run(stream, pace=False)
    wall_s = time.perf_counter() - t0
    ctrl = srv.controller

    windows = regime_windows(stream, shift_t, SCORE_WINDOW_S, grid)
    regret_num = 0.0
    regret_den = 0.0
    per_window = []
    for w0, w1, prof, oracle_plan in windows:
        oracle = REF_MODEL.plan_cost(oracle_plan, prof)["total_s"]
        default = REF_MODEL.plan_cost(DEFAULT_PLAN, prof)["total_s"]
        controller = window_cost(ctrl.plan_log, w0, w1, prof)
        regret_num += controller - oracle
        regret_den += default - oracle
        per_window.append({
            "t0": w0, "requests": prof.requests,
            "oracle_s": oracle, "default_s": default,
            "controller_s": controller,
            "oracle_plan": oracle_plan.describe(),
            "plan": plan_at(ctrl.plan_log, w1).describe()})
    regret_frac = regret_num / regret_den if regret_den > 0 else 0.0

    regret_row = {
        "suite": "regret",
        "scenario": "regime_shift",
        "regret_frac": regret_frac,
        "swaps": len(ctrl.swaps),
        "ticks": ctrl.ticks,
        "windows": len(windows),
        "requests": len(stream),
        "served": rep.served,
        "controller_cost_s": regret_num + sum(w["oracle_s"]
                                              for w in per_window),
        "oracle_cost_s": sum(w["oracle_s"] for w in per_window),
        "default_cost_s": sum(w["default_s"] for w in per_window),
        "grid_size": len(grid),
        "hysteresis": HYSTERESIS,
        "min_dwell_s": MIN_DWELL_S,
        "digest": rep.digest,
        "wall_s": wall_s,
    }
    emit("controller_regret", f"{regret_frac:.4f}",
         f"swaps={len(ctrl.swaps)};windows={len(windows)}"
         f";acceptance: regret<=0.10, swaps<=3")

    # -- prune suite --------------------------------------------------------
    # capture a real profile of the big regime (the expensive one, where
    # measuring matters), then let successive halving spend its budget
    mats = synthetic_trace("bimodal", 48 if fast else 96, op="eigh",
                           lo=8, hi=44, seed=SEED)
    psrv = server_for_plan(DEFAULT_PLAN, srv.config)
    for _ in range(2):                   # compile pass + steady-state pass
        psrv.solve_many(mats)
    profile = TrafficProfile.from_stats(psrv.stats,
                                        captured=psrv.describe_plan())
    t0 = time.perf_counter()
    result = bandit_search(profile, grid=grid, budget_frac=BUDGET_FRAC,
                           config=srv.config, seed=SEED, measure=True)
    bandit_s = time.perf_counter() - t0
    measured_frac = (result.measured_evals / result.grid_size
                     if result.grid_size else 0.0)
    prune_row = {
        "suite": "prune",
        "scenario": "bandit_prune",
        "grid_size": result.grid_size,
        "measured_evals": result.measured_evals,
        "measured_frac": measured_frac,
        "exhaustive_evals": result.grid_size,
        "budget_frac": BUDGET_FRAC,
        "best_plan": result.best.describe(),
        "mode": result.mode,
        "wall_s": bandit_s,
    }
    emit("controller_bandit_prune", f"{result.measured_evals}",
         f"grid={result.grid_size};measured_frac={measured_frac:.3f}"
         f";acceptance: measured<=0.25*grid")

    emit_json("controller_regret", {
        "score_window_s": SCORE_WINDOW_S,
        "ctrl_window_s": CTRL_WINDOW_S,
        "reprofile_every_s": REPROFILE_EVERY_S,
        "ref_model_device_work_per_s": REF_MODEL.device_work_per_s,
        "swap_log": [{"t": s["t"], "plan": s["plan"],
                      "predicted_gain": s["predicted_gain"]}
                     for s in ctrl.swaps],
        "per_window": per_window,
        "rows": [regret_row, prune_row],
    })


def selftest() -> int:
    """CI smoke: the regime split must be well-formed and the analytic
    bandit must agree with the exhaustive grid on a regime profile."""
    import json

    stream, shift_t = regime_shift_stream(60, 40)
    grid = plan_grid()
    windows = regime_windows(stream, shift_t, SCORE_WINDOW_S, grid)
    assert len(windows) >= 2, len(windows)
    assert all(w1 > w0 and prof.requests > 0
               for w0, w1, prof, _ in windows)
    result = bandit_search(windows[-1][2], grid=grid, model=REF_MODEL,
                           budget_frac=BUDGET_FRAC, measure=False)
    exhaustive = min(grid, key=lambda p:
                     REF_MODEL.plan_cost(p, windows[-1][2])["total_s"])
    assert result.best == exhaustive, (result.best, exhaustive)
    print("controller_regret selftest ok:", json.dumps({
        "windows": len(windows), "grid": len(grid),
        "analytic_best": result.best.describe()}))
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        sys.exit(selftest())
    print("name,us_per_call,derived")
    run(fast=not args.full)
