"""Paper Fig. 7: energy (peak power x end-to-end latency) per dataset for
both published fabric configurations.  Pure analytical model (power fitted
exactly through the two published design points)."""
from __future__ import annotations

from repro.core.memory_model import ARTIX7, VIRTEX_US, pca_seconds, power_w
from .common import DATASETS, PAPER_CLAIMS, emit


def run(fast: bool = True):
    for name, (m, n) in DATASETS.items():
        for tag, cfg in (("artix7_4_8", ARTIX7), ("virtex_16_32", VIRTEX_US)):
            est = pca_seconds(m, n, cfg)
            emit(f"fig7/{name}/{tag}", round(est["total_s"] * 1e6, 1),
                 f"energy_j={est['energy_j']:.5f};power_w={power_w(cfg):.3f}")
    emit("fig7/paper_claim_cifar10_energy_reduction", "",
         PAPER_CLAIMS["cifar10_energy_reduction_vs_a6000"])
