"""Paper Tables I-III: resource utilisation of the two published
MANOJAVAM configurations (model anchored exactly at the published points)
and the prior-accelerator comparison rows the paper reports."""
from __future__ import annotations

from repro.core.memory_model import ARTIX7, VIRTEX_US, power_w, resources
from .common import emit

# Published rows (paper Tables I, II and III)
PUBLISHED = {
    "manojavam_4_8": dict(LUT=9796, FF=23077, BRAM=30.5, DSP=64,
                          fmax_mhz=200, power_w=1.271),
    "manojavam_16_32": dict(LUT=195814, FF=143777, BRAM=940.5, DSP=4096,
                            fmax_mhz=434, power_w=16.957),
}


def run(fast: bool = True):
    for tag, cfg in (("manojavam_4_8", ARTIX7),
                     ("manojavam_16_32", VIRTEX_US)):
        pub = PUBLISHED[tag]
        mod = resources(cfg)
        emit(f"table3/{tag}/published", "",
             f"LUT={pub['LUT']};DSP={pub['DSP']};power_w={pub['power_w']}")
        emit(f"table3/{tag}/model", "",
             f"LUT={mod['LUT']:.0f};DSP={mod['DSP']:.0f};"
             f"power_w={power_w(cfg):.3f}")
        # DSP formula is exact; LUT/FF/BRAM/power are 2-point fits
        assert mod["DSP"] == pub["DSP"], (tag, mod["DSP"])
    emit("table3/scale_invariance", "",
         "block_streaming=>max_dim_limited_only_by_external_storage")
