"""Goodput under an SLO vs offered load, through saturation.

The headline serving metric shifts here from closed-loop throughput to
**goodput**: SLO-compliant requests/s under an *open-loop* arrival
process (``repro.serving.frontend``).  Two stories, both on the live
server with real pacing and real latencies:

  load sweep   Poisson arrivals at 60/100/150/250% of the measured
               closed-loop capacity, admission control on ("shed") vs
               off ("none").  Past saturation the no-admission server
               queues unboundedly and its goodput collapses; admission
               sheds the infeasible tail and keeps serving inside the
               SLO -- the committed rows must show >= 1.3x goodput at
               the saturating points (gated by ``check_bench.py``).
  fairness     a skewed two-tenant mix at 250% load -- a whale of large
               refits (90% of traffic, relaxed SLO) and a mouse of
               small latency-critical requests (10%, tight SLO) -- under
               WFQ vs FIFO scheduling.  FIFO admits the mouse only when
               the whale's backlog happens to dip under the mouse's
               deadline; WFQ charges each tenant its *own* weighted
               backlog, so the mouse rides alongside.  Committed rows
               must show WFQ worst-tenant goodput >= 2x FIFO's.

Offered rates are set relative to the capacity measured on this machine
at run time, so the *load_pct* rows mean the same thing on any host; the
dimensionless ``shed_frac`` and the intra-file ratio gates carry the
regression signal that absolute rps cannot.

Emits ``BENCH_goodput.json``.  ``--selftest`` runs the deterministic
virtual-clock checks (bit-identical reruns, shed accounting) for the CI
smoke; ``--metrics-out PATH`` additionally exports the fairness run's
tenant-labeled metric families as Prometheus text (the nightly artifact).
"""
from __future__ import annotations

import argparse
import sys
import time

from .common import emit, emit_json

T = 16
SWEEPS = 6
BATCH = 8
INFLIGHT = 2
MAX_DELAY_S = 0.02
LO, HI = 16, 40               # whale / load-sweep dims (uniform)
MOUSE_LO, MOUSE_HI = 16, 24   # mouse dims: small interactive requests
SLO_MS = 200.0
MOUSE_SLO_MS = 50.0
LOADS = (60, 100, 150, 250)
N_LOAD = 240                  # requests per load-sweep run
N_FAIR = 400                  # requests per fairness run
SEED = 3


def _server(clock=None):
    from repro.core import PCAConfig
    from repro.serving import BucketPolicy, PCAServer

    kw = dict(policy=BucketPolicy(T=T), max_delay_s=MAX_DELAY_S,
              max_batch=BATCH, max_inflight=INFLIGHT)
    if clock is not None:
        kw["clock"] = clock
    return PCAServer(PCAConfig(T=T, S=BATCH, sweeps=SWEEPS), **kw)


def _calibrate(srv):
    """Closed-loop capacity (warm, steady-state rps) + a cost model
    calibrated from the same run's telemetry -- the admission
    controller's service predictions come from the hardware it will
    gate, not from defaults."""
    import numpy as np
    from repro.serving import CostModel, TrafficProfile
    from repro.serving.autotune import synthesize

    rng = np.random.default_rng(5)
    mats = [synthesize("eigh", (d, d), rng)
            for d in rng.integers(LO, HI + 1, size=96)]
    for m in mats:                      # warm every bucket's executable
        srv.submit(m)
    srv.drain()
    srv.stats.reset()
    t0 = time.perf_counter()
    for m in mats:
        srv.submit(m)
    srv.drain()
    capacity = len(mats) / (time.perf_counter() - t0)
    model = CostModel.calibrated(TrafficProfile.from_stats(srv.stats))
    srv.stats.reset()
    return capacity, model


def _whale_mouse(capacity):
    from repro.serving import TenantSpec, generate, merge

    whale = TenantSpec("whale")
    mouse = TenantSpec("mouse", slo_ms=MOUSE_SLO_MS)
    rate = 2.5 * capacity
    stream = merge(
        generate("poisson", rate=0.9 * rate, n=int(0.9 * N_FAIR),
                 tenants=(whale,), seed=SEED, trace="uniform",
                 lo=LO, hi=HI),
        generate("poisson", rate=0.1 * rate, n=int(0.1 * N_FAIR),
                 tenants=(mouse,), seed=SEED + 8, trace="uniform",
                 lo=MOUSE_LO, hi=MOUSE_HI))
    return (whale, mouse), stream


def _paced(srv, stream, tenants, scheduler, admission, model,
           accounting=None, passes: int = 2):
    """Best-of-``passes`` paced run: an occasional host stall (GC, a
    stray compile) tanks one replay's goodput; the best pass is the
    machine's honest capability, same policy as ``autotune.replay``.
    ``accounting`` is a zero-arg factory (each pass gets a fresh
    ``TenantAccounting``); when given, returns (report, accounting) of
    the winning pass."""
    from repro.serving import TrafficFrontend

    best = best_acct = None
    for _ in range(max(passes, 1)):
        acct = accounting() if accounting is not None else None
        fe = TrafficFrontend(srv, tenants, slo_ms=SLO_MS,
                             scheduler=scheduler, admission=admission,
                             model=model, accounting=acct, seed=1)
        rep = fe.run(stream, pace=True)
        srv.stats.reset()
        if best is None or rep.goodput_rps > best.goodput_rps:
            best, best_acct = rep, acct
    return (best, best_acct) if accounting is not None else best


def _row(rep, **identity):
    return {
        **identity,
        "requests": rep.requests,
        "offered_rps": rep.offered_rps,
        "goodput_rps": rep.goodput_rps,
        "served_rps": rep.served_rps,
        "shed_frac": rep.shed_frac,
        "served": rep.served,
        "degraded": rep.degraded,
        "shed": rep.shed,
        "worst_tenant_goodput_rps": rep.worst_tenant_goodput_rps,
        "per_tenant": rep.per_tenant,
    }


def load_rows(srv, capacity, model):
    from repro.serving import TenantSpec, generate

    rows = []
    for load in LOADS:
        stream = generate("poisson", rate=capacity * load / 100.0,
                          n=N_LOAD, tenants=(TenantSpec("t0"),),
                          seed=SEED, trace="uniform", lo=LO, hi=HI)
        for admission in ("shed", "none"):
            rep = _paced(srv, stream, (TenantSpec("t0"),), "wfq",
                         admission, model)
            rows.append(_row(rep, suite="load", arrivals="poisson",
                             scheduler="wfq", admission=admission,
                             load_pct=load, slo_ms=SLO_MS))
            emit(f"goodput_load{load}_{admission}",
                 f"{rep.goodput_rps:.1f}",
                 f"goodput_rps={rep.goodput_rps:.1f}"
                 f";shed_frac={rep.shed_frac:.3f}")
    return rows


def fairness_rows(srv, capacity, model, metrics_out=None):
    rows = []
    tenants, stream = _whale_mouse(capacity)
    for scheduler in ("wfq", "fifo"):
        if metrics_out and scheduler == "wfq":
            from repro.obs import TenantAccounting
            rep, acct = _paced(srv, stream, tenants, scheduler, "shed",
                               model, accounting=TenantAccounting)
            import pathlib
            acct.summary(span_s=max(rep.duration_s, 1e-9))
            pathlib.Path(metrics_out).write_text(
                acct.registry.to_prometheus())
        else:
            rep = _paced(srv, stream, tenants, scheduler, "shed", model)
        rows.append(_row(rep, suite="fairness", arrivals="poisson",
                         scheduler=scheduler, admission="shed",
                         load_pct=250, slo_ms=SLO_MS,
                         mouse_slo_ms=MOUSE_SLO_MS))
        emit(f"goodput_fairness_{scheduler}",
             f"{rep.worst_tenant_goodput_rps:.1f}",
             f"worst_tenant_goodput_rps={rep.worst_tenant_goodput_rps:.1f}"
             f";goodput_rps={rep.goodput_rps:.1f}")
    return rows


def run(fast: bool = True, metrics_out=None) -> None:
    del fast                         # the sweep is seconds either way
    srv = _server()
    capacity, model = _calibrate(srv)
    emit("goodput_capacity", f"{capacity:.0f}",
         f"closed_loop_rps={capacity:.1f}")
    rows = load_rows(srv, capacity, model)
    rows += fairness_rows(srv, capacity, model, metrics_out=metrics_out)
    emit_json("goodput", {
        "capacity_rps": capacity,
        "slo_ms": SLO_MS,
        "mouse_slo_ms": MOUSE_SLO_MS,
        "loads_pct": list(LOADS),
        "rows": rows,
    })


def selftest() -> None:
    """Deterministic virtual-clock checks -- the fast CI smoke.

    Asserts: (1) a seeded open-loop run is bit-identical across two
    invocations (admitted/shed split, outcomes, result bytes); (2) shed
    accounting balances; (3) admission control beats unbounded queueing
    on modeled goodput past saturation; (4) WFQ keeps the starved
    tenant's p99 bounded where FIFO does not."""
    from repro.core import PCAConfig
    from repro.serving import (BucketPolicy, CostModel, PCAServer,
                               TenantSpec, TrafficFrontend, VirtualClock,
                               generate, merge)

    whale = TenantSpec("whale")
    mouse = TenantSpec("mouse", slo_ms=30.0)
    stream = merge(
        generate("poisson", rate=360.0, n=180, tenants=(whale,), seed=SEED,
                 trace="uniform", lo=24, hi=40),
        generate("poisson", rate=40.0, n=20, tenants=(mouse,),
                 seed=SEED + 8, trace="uniform", lo=8, hi=12))
    model = CostModel(device_work_per_s=2e6)   # modeled slow device

    def one(scheduler, admission):
        clk = VirtualClock()
        srv = PCAServer(PCAConfig(T=T, S=BATCH, sweeps=SWEEPS),
                        policy=BucketPolicy(T=T), clock=clk,
                        max_delay_s=MAX_DELAY_S, max_batch=BATCH)
        fe = TrafficFrontend(srv, (whale, mouse), slo_ms=100.0,
                             scheduler=scheduler, admission=admission,
                             model=model, seed=1)
        return fe.run(stream, pace=False)

    a, b = one("wfq", "shed"), one("wfq", "shed")
    assert a.digest == b.digest, "seeded open-loop run not deterministic"
    assert (a.served, a.degraded, a.shed, a.throttled) == \
           (b.served, b.degraded, b.shed, b.throttled)
    total = a.served + a.degraded + a.shed + a.throttled
    assert total == a.requests == len(stream), \
        f"shed accounting leak: {total} != {a.requests}"
    assert a.shed > 0, "saturating stream shed nothing"
    none = one("wfq", "none")
    assert a.goodput_rps >= 1.3 * none.goodput_rps, \
        (a.goodput_rps, none.goodput_rps)
    fifo_none = one("fifo", "none")
    wfq_p99 = none.per_tenant["mouse"]["latency_p99_ms"]
    fifo_p99 = fifo_none.per_tenant["mouse"]["latency_p99_ms"]
    assert wfq_p99 < 0.5 * fifo_p99, \
        f"WFQ did not bound starved-tenant p99: {wfq_p99} vs {fifo_p99}"
    print(f"goodput selftest ok: {a.requests} arrivals, "
          f"{a.served} served / {a.shed} shed (deterministic), "
          f"admission {a.goodput_rps / max(none.goodput_rps, 1e-9):.1f}x "
          f"no-admission goodput, mouse p99 wfq {wfq_p99:.0f}ms "
          f"vs fifo {fifo_p99:.0f}ms")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true",
                    help="fast deterministic checks, no BENCH emission")
    ap.add_argument("--metrics-out", default=None,
                    help="write the fairness run's tenant metrics "
                         "(Prometheus text) here")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        selftest()
        sys.exit(0)
    print("name,us_per_call,derived")
    run(fast=not args.full, metrics_out=args.metrics_out)
