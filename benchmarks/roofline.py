"""Roofline accounting for the fused hot path (ISSUE 9 tentpole d).

Two sections:

  analytic   the original (arch x shape) three-term roofline read from the
             dry-run records (CSV only; needs ``repro.launch.dryrun``).

  measured   achieved-vs-peak FLOPs per (op, backend, precision, variant,
             bucket) on *this* host, timed with the production jit path and
             wrapped in ``obs.device_profile`` so a nightly run can attach
             the jax.profiler trace as a CI artifact (set
             ``ROOFLINE_TRACE_DIR``; empty = tracing off).

The measured rows land in ``BENCH_roofline.json`` and are gated by
``scripts/check_bench.py``:

  * fused covariance must beat the unfused block-streamed path by >= 1.15x
    device time on the large bucket (fp32),
  * bf16 operand streaming must beat fp32 by >= 1.3x achieved FLOPs where
    the platform supports it (``bf16_supported`` -- TPU; CPU bf16 matmul
    is emulated and slower, so those rows carry ``false`` and the gate
    skips them).

FLOPs/bytes are *model* numbers (what the math requires, not what XLA
executes): covariance C = X^T X is 2mn^2 FLOPs over mn operand reads +
n^2 accumulator traffic; one fused Jacobi sweep launch with k pivot pairs
rotates two rows + two columns of C and two columns of V, ~18nk FLOPs
over the 2(n^2) matrices.  ``achieved_flops`` = model FLOPs / measured
time; ``frac_of_peak`` divides by a peak calibrated from a large XLA
fp32 matmul on the same host (the realistic ceiling, not the datasheet).
"""
from __future__ import annotations

import json
import os
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from .common import emit, emit_json, time_call

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

# (m, n) per bucket: "large" is the serving tier the perf gate watches
BUCKETS = {"small": (512, 64), "large": (4096, 256)}
UNFUSED_BLOCK = 64        # the server's default streaming block (config T)
SWEEP_N = {"small": 64, "large": 256}


def records(mesh="16x16"):
    out = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") == mesh:
            out.append(r)
    return out


def analytic():
    recs = records()
    if not recs:
        emit("roofline/missing", "", "run repro.launch.dryrun --all first")
        return
    for r in recs:
        cell = f"{r['arch']}/{r['shape']}"
        if "skipped" in r:
            emit(f"roofline/{cell}", "", "skipped=" + r["skipped"][:40])
            continue
        rf = r["roofline"]
        emit(f"roofline/{cell}", "",
             f"compute_s={rf['compute_s']:.4f};memory_s={rf['memory_s']:.4f};"
             f"collective_s={rf['collective_s']:.4f};dom={r['dominant']};"
             f"useful={r.get('useful_flops_ratio') or 0:.3f}")
    n_ok = sum("roofline" in r for r in recs)
    emit("roofline/cells_compiled", "", f"{n_ok}/{len(recs)}")
    mp = records("2x16x16")
    emit("roofline/multipod_cells_compiled", "",
         f"{sum('skipped' not in r for r in mp)}/{len(mp)}")


def calibrate_peak(reps: int = 3) -> float:
    """Achievable fp32 FLOP/s on this host: one big XLA matmul.

    The realistic ceiling every ``frac_of_peak`` is measured against --
    a kernel can only aspire to what XLA itself reaches here."""
    k = 1024
    a = jnp.asarray(np.random.default_rng(0).standard_normal(
        (k, k)).astype(np.float32))
    f = jax.jit(jnp.matmul)
    us = time_call(f, a, a, reps=reps)
    return 2.0 * k ** 3 / (us * 1e-6)


def _cov_rows(bucket: str, peak: float, reps: int, bf16_ok: bool):
    from repro.core.covariance import blocked_covariance
    from repro.core import precision as prec
    from repro.kernels import ops as kops

    m, n = BUCKETS[bucket]
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (m, n)).astype(np.float32))
    flops = 2.0 * m * n * n
    rows = []

    def row(variant, backend, precision, us, bytes_):
        rows.append({
            "op": "covariance", "bucket": bucket, "m": m, "n": n,
            "variant": variant, "backend": backend, "precision": precision,
            "bf16_supported": bf16_ok or precision == "fp32",
            "us_per_call": us,
            "model_flops": flops, "model_bytes": bytes_,
            "achieved_flops": flops / (us * 1e-6),
            "achieved_gbps": bytes_ / (us * 1e-6) / 1e9,
            "frac_of_peak": flops / (us * 1e-6) / peak,
        })

    # unfused baselines: the block-streamed scan at the server default T,
    # once on plain XLA and once with every block matmul routed through
    # the mm_engine kernel backend -- each is what a server with that
    # ``backend`` config runs when ``fused=False``, so the fusion gate
    # compares fused and unfused rows *of the same backend*
    # (each of the m/T launches re-reads + re-writes the n^2 accumulator)
    bytes_unf = 4.0 * (m * n + 2.0 * (m / UNFUSED_BLOCK) * n * n)
    f_unf = jax.jit(lambda a: blocked_covariance(a, block_m=UNFUSED_BLOCK))
    row("unfused", "xla", "fp32", time_call(f_unf, x, reps=reps), bytes_unf)
    mm = lambda a, b: kops.mm_engine_matmul(a, b, block=UNFUSED_BLOCK,
                                            backend="interpret")
    f_unf_k = jax.jit(lambda a: blocked_covariance(
        a, block_m=UNFUSED_BLOCK, matmul_fn=mm))
    row("unfused", "interpret", "fp32", time_call(f_unf_k, x, reps=reps),
        bytes_unf)

    # fused one-HBM-pass kernel: operands stream once, Gram stays on-chip
    block = max(m // 2, UNFUSED_BLOCK)
    for precision in ("fp32", "bf16_fp32acc"):
        opb = jnp.dtype(prec.operand_dtype(precision)).itemsize
        for backend in ("interpret", "ref"):
            f = jax.jit(lambda a, _p=precision, _b=backend: kops.covariance(
                a, block_m=block, precision=_p, backend=_b))
            us = time_call(f, x, reps=reps)
            row("fused", backend, precision, us, opb * m * n + 4.0 * n * n)
    return rows


def _sweep_rows(bucket: str, peak: float, reps: int):
    from repro.core.jacobi import round_robin_rounds
    from repro.kernels import ops as kops, ref as kref

    n = SWEEP_N[bucket]
    rng = np.random.default_rng(2)
    a = rng.standard_normal((n, n)).astype(np.float32)
    C = jnp.asarray((a + a.T) / 2)
    V = jnp.eye(n, dtype=jnp.float32)
    pairs = jnp.asarray(round_robin_rounds(n)[0])
    k = int(pairs.shape[0])
    flops = 18.0 * n * k
    bytes_ = 4.0 * 4 * n * n              # C and V, read + write
    rows = []
    variants = {
        "fused": jax.jit(lambda c, v, p: kops.jacobi_sweep(
            c, v, p, backend="interpret")),
        "unfused": jax.jit(lambda c, v, p: kref.jacobi_sweep_step(c, v, p)),
    }
    for variant, f in variants.items():
        us = time_call(f, C, V, pairs, reps=reps)
        rows.append({
            "op": "jacobi_sweep", "bucket": bucket, "m": n, "n": n,
            "variant": variant,
            "backend": "interpret" if variant == "fused" else "xla",
            "precision": "fp32", "bf16_supported": True,
            "us_per_call": us,
            "model_flops": flops, "model_bytes": bytes_,
            "achieved_flops": flops / (us * 1e-6),
            "achieved_gbps": bytes_ / (us * 1e-6) / 1e9,
            "frac_of_peak": flops / (us * 1e-6) / peak,
        })
    return rows


def trace_pass(trace_dir: str):
    """One call of each fused kernel under ``obs.device_profile`` -- the
    jax.profiler artifact a nightly run uploads.  Deliberately *separate*
    from the timed pass: profiling inflates CPU device times 3-4x, so the
    gated numbers must never be measured under the tracer."""
    from repro import obs
    from repro.core.jacobi import round_robin_rounds
    from repro.kernels import ops as kops

    m, n = BUCKETS["large"]
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (m, n)).astype(np.float32))
    a = np.random.default_rng(2).standard_normal((n, n)).astype(np.float32)
    C = jnp.asarray((a + a.T) / 2)
    V = jnp.eye(n, dtype=jnp.float32)
    pairs = jnp.asarray(round_robin_rounds(n)[0])
    with obs.device_profile(trace_dir):
        for precision in ("fp32", "bf16_fp32acc"):
            jax.block_until_ready(kops.covariance(
                x, block_m=m // 2, precision=precision,
                backend="interpret"))
        jax.block_until_ready(kops.jacobi_sweep(
            C, V, pairs, backend="interpret"))


def measured(fast: bool = True):
    reps = 3 if fast else 7
    # bf16 operand streaming only pays on hardware with native bf16 MXU
    # paths; CPU emulates it slower than fp32, so the bf16 gate is scoped
    # to rows measured on TPU
    bf16_ok = jax.default_backend() == "tpu"
    trace_dir = os.environ.get("ROOFLINE_TRACE_DIR", "")
    peak = calibrate_peak(reps=reps)
    rows = []
    for bucket in BUCKETS:
        rows += _cov_rows(bucket, peak, reps, bf16_ok)
        rows += _sweep_rows(bucket, peak, reps)
    if trace_dir:
        trace_pass(trace_dir)
    for r in rows:
        emit(f"roofline/{r['op']}/{r['bucket']}/{r['variant']}/"
             f"{r['backend']}/{r['precision']}",
             f"{r['us_per_call']:.1f}",
             f"achieved_gflops={r['achieved_flops'] / 1e9:.2f}"
             f";frac_of_peak={r['frac_of_peak']:.4f}")
    emit("roofline/peak_calibrated_gflops", "", f"{peak / 1e9:.1f}")
    emit_json("roofline", {
        "peak_flops": peak,
        "unfused_block": UNFUSED_BLOCK,
        "trace_dir": trace_dir or None,
        "rows": rows,
    })
    return rows


def run(fast: bool = True):
    analytic()
    measured(fast=fast)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
