"""EXPERIMENTS Sec. Roofline source: reads the dry-run records and emits
the three-term roofline per (arch x shape) on the single-pod mesh, plus
the dominant bottleneck and MODEL_FLOPS/HLO_FLOPS utility ratio."""
from __future__ import annotations

import json
import pathlib

from .common import emit

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def records(mesh="16x16"):
    out = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") == mesh:
            out.append(r)
    return out


def run(fast: bool = True):
    recs = records()
    if not recs:
        emit("roofline/missing", "", "run repro.launch.dryrun --all first")
        return
    for r in recs:
        cell = f"{r['arch']}/{r['shape']}"
        if "skipped" in r:
            emit(f"roofline/{cell}", "", "skipped=" + r["skipped"][:40])
            continue
        rf = r["roofline"]
        emit(f"roofline/{cell}", "",
             f"compute_s={rf['compute_s']:.4f};memory_s={rf['memory_s']:.4f};"
             f"collective_s={rf['collective_s']:.4f};dom={r['dominant']};"
             f"useful={r.get('useful_flops_ratio') or 0:.3f}")
    n_ok = sum("roofline" in r for r in recs)
    emit("roofline/cells_compiled", "", f"{n_ok}/{len(recs)}")
    mp = records("2x16x16")
    emit("roofline/multipod_cells_compiled", "",
         f"{sum('skipped' not in r for r in mp)}/{len(mp)}")
