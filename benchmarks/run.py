"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  fig1  -- PCA bottleneck split (paper Fig. 1)
  fig6  -- execution time across datasets (paper Fig. 6)
  fig7  -- energy model (paper Fig. 7)
  fig8  -- Frobenius-norm convergence study (paper Fig. 8 / Sec. VII-D)
  dse   -- T/S design-space exploration (paper Figs. 9-11)
  table3-- resource/config comparison (paper Tables I-III)
  roofline -- analytic (arch x shape) terms from the dry-run records,
              plus measured achieved-vs-peak FLOPs per (op, backend,
              precision, fused/unfused) -> BENCH_roofline.json
  serve -- batched multi-tenant serving throughput (repro.serving)
  autotune -- tuned-vs-default serving-plan gain (serving.autotune)
  cold_start -- fresh-replica TTFR: cold JIT vs warm disk cache vs warmup
  goodput -- open-loop goodput-under-SLO vs offered load (serving.frontend)
  controller -- controller regret vs oracle on a regime shift, plus the
                bandit's measured-eval pruning (serving.controller)
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--full", action="store_true",
                    help="larger sweeps (slow on CPU)")
    args = ap.parse_args()

    from . import (autotune_gain, cold_start, controller_regret, dse,
                   fig1_bottlenecks, fig6_exec_time, fig7_energy,
                   fig8_frobenius, goodput, perf_variants, roofline,
                   serve_throughput, table3_configs)
    suite = {
        "table3": table3_configs,
        "fig8": fig8_frobenius,
        "fig7": fig7_energy,
        "fig6": fig6_exec_time,
        "fig1": fig1_bottlenecks,
        "dse": dse,
        "roofline": roofline,
        "perf": perf_variants,
        "serve": serve_throughput,
        "autotune": autotune_gain,
        "cold_start": cold_start,
        "goodput": goodput,
        "controller": controller_regret,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for name, mod in suite.items():
        if only and name not in only:
            continue
        try:
            mod.run(fast=not args.full)
        except Exception:  # keep the harness running, report at the end
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED,{','.join(failed)},", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
