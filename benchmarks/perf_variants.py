"""EXPERIMENTS §Perf evidence: emits the hillclimb variant records
(experiments/perf/*.json) next to their baselines as CSV rows, plus the
backend-sweep axis -- the same registry op timed on every backend available
on this host (``ref`` XLA, ``interpret`` Pallas-interpreter, and ``pallas``
when a TPU is attached), so backend choice shows up in the perf trajectory
the way deployment-target choice does in the paper (Artix-7 vs Virtex-US+).
"""
from __future__ import annotations

import json
import pathlib

from .common import emit, emit_json, time_call

PERF_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "perf"
DRY_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def available_backends():
    from repro import backends
    return backends.available()


def backend_sweep(fast: bool = True):
    """Time mm_engine_matmul and dle_find_pivot per backend (one shape)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import ops

    m = 128 if fast else 512
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
    c = np.asarray(rng.standard_normal((m, m)), np.float32)
    c = jnp.asarray(c + c.T)

    rows = []
    for be in available_backends():
        mm_us = time_call(
            lambda: ops.mm_engine_matmul(a, b, block=64, backend=be))
        dle_us = time_call(
            lambda: ops.dle_find_pivot(c, tile=64, backend=be))
        rows.append({"backend": be, "m": m,
                     "mm_engine_us": mm_us, "dle_scan_us": dle_us})
        emit(f"perf/backend_sweep/mm_engine_{m}/{be}", round(mm_us, 1),
             "block=64")
        emit(f"perf/backend_sweep/dle_scan_{m}/{be}", round(dle_us, 1),
             "tile=64")
    emit_json("backend_sweep", {"rows": rows})
    return rows


def run(fast: bool = True):
    backend_sweep(fast)
    if not PERF_DIR.exists():
        emit("perf/missing", "", "run the §Perf experiments first")
        return
    for p in sorted(PERF_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if "roofline" in r:
            rf = r["roofline"]
            tag = p.stem.split("__")[-1]
            emit(f"perf/{r['arch']}/{r['shape']}/{tag}", "",
                 f"compute_s={rf['compute_s']:.3f};"
                 f"memory_s={rf['memory_s']:.3f};"
                 f"collective_s={rf['collective_s']:.3f};"
                 f"useful={r.get('useful_flops_ratio') or 0:.3f}")
        elif "baseline" in r and "compressed" in r:
            emit(f"perf/pod_compression/{r['arch']}_L{r['layers']}_r{r['rank']}",
                 "",
                 f"baseline_bytes={r['baseline']['total_bytes']:.3e};"
                 f"compressed_bytes={r['compressed']['total_bytes']:.3e};"
                 f"reduction={r['reduction_factor_total']:.2f}x")
    # baselines of the hillclimbed cells for side-by-side reading
    for arch, shape in (("falcon-mamba-7b", "train_4k"),
                        ("arctic-480b", "train_4k"),
                        ("llama4-maverick-400b-a17b", "train_4k")):
        f = DRY_DIR / f"{arch}__{shape}__sp__float32.json"
        if f.exists():
            r = json.loads(f.read_text())
            rf = r["roofline"]
            emit(f"perf/{arch}/{shape}/baseline", "",
                 f"compute_s={rf['compute_s']:.3f};"
                 f"memory_s={rf['memory_s']:.3f};"
                 f"collective_s={rf['collective_s']:.3f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    backend_sweep(fast=True)
