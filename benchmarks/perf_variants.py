"""EXPERIMENTS §Perf evidence: emits the hillclimb variant records
(experiments/perf/*.json) next to their baselines as CSV rows."""
from __future__ import annotations

import json
import pathlib

from .common import emit

PERF_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "perf"
DRY_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def run(fast: bool = True):
    if not PERF_DIR.exists():
        emit("perf/missing", "", "run the §Perf experiments first")
        return
    for p in sorted(PERF_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if "roofline" in r:
            rf = r["roofline"]
            tag = p.stem.split("__")[-1]
            emit(f"perf/{r['arch']}/{r['shape']}/{tag}", "",
                 f"compute_s={rf['compute_s']:.3f};"
                 f"memory_s={rf['memory_s']:.3f};"
                 f"collective_s={rf['collective_s']:.3f};"
                 f"useful={r.get('useful_flops_ratio') or 0:.3f}")
        elif "baseline" in r and "compressed" in r:
            emit(f"perf/pod_compression/{r['arch']}_L{r['layers']}_r{r['rank']}",
                 "",
                 f"baseline_bytes={r['baseline']['total_bytes']:.3e};"
                 f"compressed_bytes={r['compressed']['total_bytes']:.3e};"
                 f"reduction={r['reduction_factor_total']:.2f}x")
    # baselines of the hillclimbed cells for side-by-side reading
    for arch, shape in (("falcon-mamba-7b", "train_4k"),
                        ("arctic-480b", "train_4k"),
                        ("llama4-maverick-400b-a17b", "train_4k")):
        f = DRY_DIR / f"{arch}__{shape}__sp__float32.json"
        if f.exists():
            r = json.loads(f.read_text())
            rf = r["roofline"]
            emit(f"perf/{arch}/{shape}/baseline", "",
                 f"compute_s={rf['compute_s']:.3f};"
                 f"memory_s={rf['memory_s']:.3f};"
                 f"collective_s={rf['collective_s']:.3f}")
