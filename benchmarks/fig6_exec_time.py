"""Paper Fig. 6: total PCA execution time across the benchmark datasets.

Two columns per dataset: the cycle-approximate MANOJAVAM(16,32) model
(paper Sec. VII-A simulator, Virtex US+ @434 MHz) and a measured JAX-CPU
run on a shape-preserving subsample (measured column marked `measured_sub`
when subsampled).  The paper's headline CIFAR-10 ratio (3.87x vs A6000) is
echoed as reference derived output."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import PCAConfig, fit
from repro.core.memory_model import VIRTEX_US, pca_seconds
from .common import DATASETS, PAPER_CLAIMS, emit, synthetic_dataset, time_call

_SUB = {"mnist-28x28": (4000, 784), "cifar-10": (2000, 512),
        "20-newsgroups": (2000, 512), "breast-cancer": (8000, 7),
        "olivetti": (400, 512)}


def run(fast: bool = True):
    for name, (m, n) in DATASETS.items():
        est = pca_seconds(m, n, VIRTEX_US)
        emit(f"fig6/{name}/manojavam_16_32_model",
             round(est["total_s"] * 1e6, 1),
             f"cov_s={est['covariance_s']:.4f};svd_s={est['svd_s']:.4f}")
        ms, ns = _SUB.get(name, (m, n))
        if fast and ms * ns > 4_000_000:
            ms, ns = min(ms, 2000), min(ns, 256)
        x = synthetic_dataset(ms, ns, seed=hash(name) % 1000)
        cfgj = PCAConfig(T=128, sweeps=10)
        fn = jax.jit(lambda x: fit(x, cfgj).eigenvalues)
        us = time_call(fn, jnp.asarray(x), reps=2)
        tag = "measured" if (ms, ns) == (m, n) else f"measured_sub_{ms}x{ns}"
        emit(f"fig6/{name}/jax_cpu_{tag}", round(us, 1), "")
    emit("fig6/paper_claim_cifar10_speedup_vs_a6000", "",
         PAPER_CLAIMS["cifar10_total_speedup_vs_a6000"])
